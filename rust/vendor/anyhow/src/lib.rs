//! Offline stand-in for the `anyhow` crate.
//!
//! The workspace builds in an offline image, so the real crates.io
//! `anyhow` cannot be fetched. This shim provides the exact subset the
//! crate uses — [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait — with the same calling
//! conventions, so the dependent code is source-compatible with the real
//! crate if it is ever swapped back in.

use std::fmt;

/// A string-backed error value. Context frames are joined with `": "`,
/// matching anyhow's single-line `{:#}` rendering.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Prepend a context frame.
    pub fn context(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::msg(err)
    }
}

/// `anyhow::Result<T>` — the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding context to any displayable error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let err = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(err)?;
        Ok(())
    }

    #[test]
    fn from_std_error_and_macros() {
        assert!(fails_io().unwrap_err().to_string().contains("gone"));
        let e: Error = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn bail_returns() {
        fn f() -> Result<()> {
            bail!("stop {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop 1");
    }
}
