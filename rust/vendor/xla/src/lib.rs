//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The image this workspace builds in has no PJRT shared library and no
//! network to fetch the real binding, so this shim mirrors the minimal
//! API surface used by `anchors_hierarchy::runtime` and fails at runtime
//! with a descriptive error. Every consumer of the runtime already
//! treats engine errors as "fall back to the scalar path", so linking
//! this shim degrades the system gracefully instead of breaking the
//! build. Swap the real `xla` crate back into `Cargo.toml` (same API) to
//! re-enable the AOT tile programs.

use std::path::Path;

/// Error type matching the real crate's `{:?}`-oriented usage.
pub struct XlaError(String);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError("PJRT unavailable: offline xla shim is linked (see rust/vendor/xla)".into())
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the shim.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// A compiled executable (never constructed by the shim).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A device buffer (never constructed by the shim).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple4(self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable())
    }
}

/// Parsed HLO module text (never constructed by the shim).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation graph.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
