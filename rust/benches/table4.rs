//! Bench: regenerate Table 4 (K-means distortion, random vs anchors
//! initialization, before/after 50 Lloyd iterations).

use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::bench::tables;

fn main() {
    let scale: f64 = std::env::var("TABLE4_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.02);
    println!("# Table 4 bench (scale {scale}, 50 iterations)");
    let rows = Bencher::new(0, 1).bench("table4/full-sweep", |_| {
        tables::table4(scale, 50, 30, 20130)
    });
    tables::print_table4(&rows);
}
