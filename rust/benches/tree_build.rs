//! Bench: anchors-hierarchy and tree construction scaling.
//!
//! Measures (a) anchor-set construction distance counts vs the R·k brute
//! force (the §3 efficiency claim), (b) builder wall-clock scaling in R,
//! and (c) the perf target from DESIGN.md: middle-out build of the full
//! 80k-point squiggles dataset.

use anchors_hierarchy::anchors::build_anchors;
use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};

fn main() {
    // (a) anchors distance-count efficiency.
    println!("# anchors construction: counted distances vs R*k brute force");
    for scale in [0.01, 0.05, 0.2] {
        let space = DatasetSpec::scaled(DatasetKind::Squiggles, scale).build();
        let r = space.n();
        let k = (r as f64).sqrt() as usize;
        space.reset_count();
        let points: Vec<u32> = (0..r as u32).collect();
        let set = build_anchors(&space, &points, k, &mut Rng::new(1));
        println!(
            "  squiggles R={r:>6} k={k:>4}: {:>10} dists ({:.1}% of R*k), {} anchors",
            space.dist_count(),
            100.0 * space.dist_count() as f64 / (r * k) as f64,
            set.k()
        );
    }

    // (b) builder scaling.
    println!("# middle-out build wall-clock scaling");
    for scale in [0.05, 0.2, 0.5] {
        let space = DatasetSpec::scaled(DatasetKind::Squiggles, scale).build();
        let name = format!("build/squiggles-{}k", space.n() / 1000);
        Bencher::new(0, 2).bench(&name, |i| {
            middle_out::build(
                &space,
                &MiddleOutConfig {
                    rmin: 30,
                    seed: i as u64,
                    parallelism: Parallelism::Serial,
                    ..Default::default()
                },
            )
            .nodes
            .len()
        });
    }

    // (c) the DESIGN.md perf target: full-size squiggles (80k × 2).
    let space = DatasetSpec::scaled(DatasetKind::Squiggles, 1.0).build();
    let tree = Bencher::new(0, 1).bench("build/squiggles-FULL-80k", |_| {
        middle_out::build(
            &space,
            &MiddleOutConfig { parallelism: Parallelism::Serial, ..Default::default() },
        )
    });
    println!(
        "  full squiggles: {} nodes, {} build dists",
        tree.nodes.len(),
        tree.build_dists
    );
}
