//! Bench: parallel execution layer scaling — middle-out tree build and
//! `Engine::run_batch` throughput at 1/2/4/8 threads on a 50k × 64
//! synthetic Gaussian-mixture dataset.
//!
//! Prints one report line per configuration and overwrites the
//! repo-root `BENCH_parallel.json` baseline (the acceptance target for
//! this subsystem is ≥ 2× build and batch speedup at 4 threads vs 1).

use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::data::Data;
use anchors_hierarchy::dataset::gaussian_mixture;
use anchors_hierarchy::engine::{BallQuery, Index, KmeansQuery, KnnQuery, KnnTarget, Query};
use anchors_hierarchy::metrics::Space;
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use std::fmt::Write as _;
use std::sync::Arc;

const ROWS: usize = 50_000;
const DIMS: usize = 64;
const THREADS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    println!("# parallel scaling: {ROWS} x {DIMS} gaussian mixture");
    let space = Arc::new(Space::euclidean(Data::Dense(gaussian_mixture(
        ROWS, DIMS, 32, 25.0, 7,
    ))));

    // --- tree build scaling ---------------------------------------------
    let mut build_secs = Vec::new();
    for &threads in &THREADS {
        let cfg = MiddleOutConfig {
            rmin: 30,
            seed: 7,
            parallelism: Parallelism::Fixed(threads),
            ..Default::default()
        };
        let bencher = Bencher::new(0, 1);
        let (stats, tree) = bencher.run(&format!("build/middle-out-{threads}t"), |_| {
            middle_out::build(&space, &cfg)
        });
        println!("{}", stats.report());
        assert_eq!(tree.n_points(), ROWS);
        build_secs.push(stats.mean);
    }

    // --- batch-query scaling ---------------------------------------------
    // One shared tree (its cost is measured above); the batch mixes the
    // query families a read-mostly workload would: point knn, ball
    // stats around dataset rows, a couple of small k-means runs.
    let tree = Arc::new(middle_out::build(
        &space,
        &MiddleOutConfig {
            rmin: 30,
            seed: 7,
            parallelism: Parallelism::Fixed(*THREADS.iter().max().unwrap()),
            ..Default::default()
        },
    ));
    let mut row = vec![0f32; space.dim()];
    let mut workload: Vec<Query> = Vec::new();
    for i in 0..48u32 {
        workload.push(Query::Knn(KnnQuery {
            target: KnnTarget::Point(i * 997 % ROWS as u32),
            k: 10,
            use_tree: true,
        }));
    }
    for i in 0..12usize {
        space.fill_row(i * 4099 % ROWS, &mut row);
        workload.push(Query::Ball(BallQuery {
            center: row.clone(),
            radius: 8.0,
            use_tree: true,
        }));
    }
    for _ in 0..4 {
        workload.push(Query::Kmeans(KmeansQuery { k: 16, iters: 2, ..Default::default() }));
    }

    let mut batch_secs = Vec::new();
    for &threads in &THREADS {
        let index = Index::from_parts(Arc::clone(&space), Arc::clone(&tree), None, 7, 30)
            .with_parallelism(Parallelism::Fixed(threads));
        let bencher = Bencher::new(1, 2);
        let (stats, n) = bencher.run(&format!("batch/{}q-{threads}t", workload.len()), |_| {
            index.run_batch(&workload).len()
        });
        println!("{}", stats.report());
        assert_eq!(n, workload.len());
        batch_secs.push(stats.mean);
    }

    // --- record the baseline ----------------------------------------------
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"status\": \"measured\",");
    let _ = writeln!(
        json,
        "  \"dataset\": {{ \"rows\": {ROWS}, \"dims\": {DIMS}, \
         \"kind\": \"gaussian_mixture\", \"seed\": 7 }},"
    );
    let _ = writeln!(json, "  \"batch_queries\": {},", workload.len());
    for (name, secs) in [("build_secs", &build_secs), ("batch_secs", &batch_secs)] {
        let vals: Vec<String> = THREADS
            .iter()
            .zip(secs.iter())
            .map(|(t, s)| format!("    {{ \"threads\": {t}, \"secs\": {s:.6} }}"))
            .collect();
        let _ = writeln!(json, "  \"{name}\": [\n{}\n  ],", vals.join(",\n"));
    }
    let _ = writeln!(
        json,
        "  \"build_speedup_4t\": {:.3},",
        build_secs[0] / build_secs[2]
    );
    let _ = writeln!(
        json,
        "  \"batch_speedup_4t\": {:.3}",
        batch_secs[0] / batch_secs[2]
    );
    let _ = writeln!(json, "}}");
    // Anchor on the manifest dir: cargo runs benches with cwd = rust/,
    // but the committed baseline lives at the repo root.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_parallel.json");
    std::fs::write(path, &json).expect("write BENCH_parallel.json");
    println!(
        "speedup at 4 threads: build {:.2}x, batch {:.2}x  (baseline -> {path})",
        build_secs[0] / build_secs[2],
        batch_secs[0] / batch_secs[2]
    );
}
