//! Bench: sharded-coordinator throughput — jobs/sec for a mixed
//! 8-dataset job stream at shard counts {1, 2, 4, 8}.
//!
//! Each iteration stands up a fresh `ShardedCoordinator` (2 workers per
//! shard), submits the whole stream, waits for every job, and shuts
//! down — so the measurement includes the serving-scale costs the
//! router exists to parallelize: dataset generation, tree builds, and
//! the per-dataset run-lock serialization. With one shard every job
//! funnels through one queue and one cache mutex; with N shards the
//! eight datasets spread across independent shards and only same-dataset
//! jobs serialize.
//!
//! Prints one report line per shard count and overwrites the repo-root
//! `BENCH_shards.json` baseline (committed as `status:"pending"` until
//! run on a machine with a toolchain, per the BENCH_* convention).

use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::coordinator::{JobSpec, JobState, ShardedCoordinator};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    AllPairsQuery, AnomalyQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query,
};
use std::fmt::Write as _;

const SHARDS: [usize; 4] = [1, 2, 4, 8];
const WORKERS_PER_SHARD: usize = 2;
const SCALE: f64 = 0.004;
const JOBS_PER_DATASET: usize = 6;

/// Eight distinct dataset cache keys: four Table-1 kinds × two seeds.
fn datasets() -> Vec<DatasetSpec> {
    let kinds = [
        DatasetKind::Squiggles,
        DatasetKind::Voronoi,
        DatasetKind::Cell,
        DatasetKind::Covtype,
    ];
    let mut specs = Vec::new();
    for seed in [20130u64, 20131] {
        for kind in &kinds {
            specs.push(DatasetSpec { kind: kind.clone(), scale: SCALE, seed });
        }
    }
    specs
}

/// A mixed stream over the 8 datasets: every query family in rotation,
/// interleaved round-robin across datasets so shards stay busy.
fn stream() -> Vec<JobSpec> {
    let datasets = datasets();
    let mut jobs = Vec::new();
    for round in 0..JOBS_PER_DATASET {
        for dataset in &datasets {
            let query = match round % 5 {
                0 => Query::Kmeans(KmeansQuery {
                    k: 8,
                    iters: 3,
                    use_tree: true,
                    ..Default::default()
                }),
                1 => Query::Anomaly(AnomalyQuery { threshold: 10, ..Default::default() }),
                2 => Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
                3 => Query::Knn(KnnQuery {
                    target: KnnTarget::Point(round as u32),
                    k: 5,
                    use_tree: true,
                }),
                _ => Query::Mst(MstQuery { use_tree: true }),
            };
            jobs.push(JobSpec { dataset: dataset.clone(), query, rmin: 30 });
        }
    }
    jobs
}

fn main() {
    let jobs = stream();
    println!(
        "# coordinator throughput: {} jobs over 8 datasets (scale {SCALE}), \
         {WORKERS_PER_SHARD} workers/shard",
        jobs.len()
    );

    let mut rates = Vec::new();
    for &n_shards in &SHARDS {
        let bencher = Bencher::new(1, 3);
        let (stats, completed) = bencher.run(&format!("coordinator/{n_shards}-shards"), |_| {
            let coord = ShardedCoordinator::new(n_shards, WORKERS_PER_SHARD, jobs.len() + 1);
            let ids: Vec<_> = jobs
                .iter()
                .map(|j| coord.submit(j.clone()).expect("capacity covers the stream"))
                .collect();
            let mut done = 0usize;
            for id in ids {
                match coord.wait(id) {
                    JobState::Done(_) => done += 1,
                    JobState::Failed(e) => panic!("job failed: {e}"),
                    _ => unreachable!(),
                }
            }
            let m = coord.shutdown();
            assert_eq!(m.completed as usize, done);
            done
        });
        println!("{}", stats.report());
        assert_eq!(completed, jobs.len());
        rates.push(jobs.len() as f64 / stats.mean);
    }

    // --- record the baseline ----------------------------------------------
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"status\": \"measured\",");
    let _ = writeln!(
        json,
        "  \"stream\": {{ \"jobs\": {}, \"datasets\": 8, \"scale\": {SCALE}, \
         \"workers_per_shard\": {WORKERS_PER_SHARD} }},",
        jobs.len()
    );
    let vals: Vec<String> = SHARDS
        .iter()
        .zip(&rates)
        .map(|(s, r)| format!("    {{ \"shards\": {s}, \"jobs_per_sec\": {r:.3} }}"))
        .collect();
    let _ = writeln!(json, "  \"throughput\": [\n{}\n  ],", vals.join(",\n"));
    let _ = writeln!(json, "  \"speedup_4_shards\": {:.3}", rates[2] / rates[0]);
    let _ = writeln!(json, "}}");
    // Anchor on the manifest dir: cargo runs benches with cwd = rust/,
    // but the committed baseline lives at the repo root.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_shards.json");
    std::fs::write(path, &json).expect("write BENCH_shards.json");
    println!(
        "speedup at 4 shards: {:.2}x  (baseline -> {path})",
        rates[2] / rates[0]
    );
}
