//! Bench: sharded-coordinator throughput — jobs/sec for a mixed
//! 8-dataset job stream at shard counts {1, 2, 4, 8}.
//!
//! Each iteration stands up a fresh `ShardedCoordinator` (2 workers per
//! shard), submits the whole stream, waits for every job, and shuts
//! down — so the measurement includes the serving-scale costs the
//! router exists to parallelize: dataset generation, tree builds, and
//! the per-dataset run-lock serialization. With one shard every job
//! funnels through one queue and one cache mutex; with N shards the
//! eight datasets spread across independent shards and only same-dataset
//! jobs serialize.
//!
//! Prints one report line per shard count and overwrites the repo-root
//! `BENCH_shards.json` baseline (committed as `status:"pending"` until
//! run on a machine with a toolchain, per the BENCH_* convention).
//! Since PR 9 the baseline also records the serving-edge latency
//! histograms (queue-wait / build / end-to-end merged across shards and
//! families) from the final timed iteration at each shard count —
//! p50/p99 under contention is the tail-latency view jobs/sec hides.

use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::coordinator::{JobSpec, JobState, ObsSnapshot, ShardedCoordinator};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    AllPairsQuery, AnomalyQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query,
};
use anchors_hierarchy::obs::HistogramSnapshot;
use std::cell::RefCell;
use std::fmt::Write as _;

const SHARDS: [usize; 4] = [1, 2, 4, 8];
const WORKERS_PER_SHARD: usize = 2;
const SCALE: f64 = 0.004;
const JOBS_PER_DATASET: usize = 6;

/// Eight distinct dataset cache keys: four Table-1 kinds × two seeds.
fn datasets() -> Vec<DatasetSpec> {
    let kinds = [
        DatasetKind::Squiggles,
        DatasetKind::Voronoi,
        DatasetKind::Cell,
        DatasetKind::Covtype,
    ];
    let mut specs = Vec::new();
    for seed in [20130u64, 20131] {
        for kind in &kinds {
            specs.push(DatasetSpec { kind: kind.clone(), scale: SCALE, seed });
        }
    }
    specs
}

/// A mixed stream over the 8 datasets: every query family in rotation,
/// interleaved round-robin across datasets so shards stay busy.
fn stream() -> Vec<JobSpec> {
    let datasets = datasets();
    let mut jobs = Vec::new();
    for round in 0..JOBS_PER_DATASET {
        for dataset in &datasets {
            let query = match round % 5 {
                0 => Query::Kmeans(KmeansQuery {
                    k: 8,
                    iters: 3,
                    use_tree: true,
                    ..Default::default()
                }),
                1 => Query::Anomaly(AnomalyQuery { threshold: 10, ..Default::default() }),
                2 => Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
                3 => Query::Knn(KnnQuery {
                    target: KnnTarget::Point(round as u32),
                    k: 5,
                    use_tree: true,
                }),
                _ => Query::Mst(MstQuery { use_tree: true }),
            };
            jobs.push(JobSpec { dataset: dataset.clone(), query, rmin: 30, deadline_ms: None });
        }
    }
    jobs
}

/// Histogram summary for the baseline JSON: count/mean plus p50/p99
/// bucket upper bounds (`null` when the histogram is empty or the
/// quantile lands in the overflow bucket).
fn hist_json(h: &HistogramSnapshot) -> String {
    let q = |q: f64| {
        h.quantile_upper_bound(q)
            .map_or("null".to_string(), |v| v.to_string())
    };
    format!(
        "{{ \"count\": {}, \"mean_us\": {:.1}, \"p50_us\": {}, \"p99_us\": {} }}",
        h.count,
        h.mean_micros(),
        q(0.5),
        q(0.99)
    )
}

fn main() {
    let jobs = stream();
    println!(
        "# coordinator throughput: {} jobs over 8 datasets (scale {SCALE}), \
         {WORKERS_PER_SHARD} workers/shard",
        jobs.len()
    );

    let mut rates = Vec::new();
    let mut latencies: Vec<(usize, ObsSnapshot)> = Vec::new();
    for &n_shards in &SHARDS {
        let bencher = Bencher::new(1, 3);
        // Each iteration overwrites this with its edge-latency snapshot;
        // what survives the bench run is the final (steadiest) iteration.
        let last_obs: RefCell<Option<ObsSnapshot>> = RefCell::new(None);
        let (stats, completed) = bencher.run(&format!("coordinator/{n_shards}-shards"), |_| {
            let coord = ShardedCoordinator::new(n_shards, WORKERS_PER_SHARD, jobs.len() + 1);
            let ids: Vec<_> = jobs
                .iter()
                .map(|j| coord.submit(j.clone()).expect("capacity covers the stream"))
                .collect();
            let mut done = 0usize;
            for id in ids {
                match coord.wait(id) {
                    JobState::Done(_) => done += 1,
                    JobState::Failed(e) => panic!("job failed: {e}"),
                    _ => unreachable!(),
                }
            }
            *last_obs.borrow_mut() = Some(coord.obs());
            let m = coord.shutdown();
            assert_eq!(m.completed as usize, done);
            done
        });
        println!("{}", stats.report());
        assert_eq!(completed, jobs.len());
        rates.push(jobs.len() as f64 / stats.mean);
        let snap = last_obs.into_inner().expect("at least one timed iteration");
        let e2e_all = snap
            .e2e
            .iter()
            .fold(HistogramSnapshot::default(), |acc, h| acc.merge(h));
        println!(
            "  edge latency ({n_shards} shards): queue-wait p50 {:?}us p99 {:?}us  \
             e2e p50 {:?}us p99 {:?}us",
            snap.queue_wait.quantile_upper_bound(0.5),
            snap.queue_wait.quantile_upper_bound(0.99),
            e2e_all.quantile_upper_bound(0.5),
            e2e_all.quantile_upper_bound(0.99),
        );
        latencies.push((n_shards, snap));
    }

    // --- record the baseline ----------------------------------------------
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"status\": \"measured\",");
    let _ = writeln!(
        json,
        "  \"stream\": {{ \"jobs\": {}, \"datasets\": 8, \"scale\": {SCALE}, \
         \"workers_per_shard\": {WORKERS_PER_SHARD} }},",
        jobs.len()
    );
    let vals: Vec<String> = SHARDS
        .iter()
        .zip(&rates)
        .map(|(s, r)| format!("    {{ \"shards\": {s}, \"jobs_per_sec\": {r:.3} }}"))
        .collect();
    let _ = writeln!(json, "  \"throughput\": [\n{}\n  ],", vals.join(",\n"));
    let lat_rows: Vec<String> = latencies
        .iter()
        .map(|(s, snap)| {
            let e2e_all = snap
                .e2e
                .iter()
                .fold(HistogramSnapshot::default(), |acc, h| acc.merge(h));
            format!(
                "    {{ \"shards\": {s}, \"queue_wait_us\": {}, \"build_us\": {}, \
                 \"e2e_us\": {} }}",
                hist_json(&snap.queue_wait),
                hist_json(&snap.build),
                hist_json(&e2e_all)
            )
        })
        .collect();
    let _ = writeln!(json, "  \"latency\": [\n{}\n  ],", lat_rows.join(",\n"));
    let _ = writeln!(json, "  \"speedup_4_shards\": {:.3}", rates[2] / rates[0]);
    let _ = writeln!(json, "}}");
    // Anchor on the manifest dir: cargo runs benches with cwd = rust/,
    // but the committed baseline lives at the repo root.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_shards.json");
    std::fs::write(path, &json).expect("write BENCH_shards.json");
    println!(
        "speedup at 4 shards: {:.2}x  (baseline -> {path})",
        rates[2] / rates[0]
    );
}
