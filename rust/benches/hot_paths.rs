//! Bench: the micro-level hot paths — scalar distance kernels, blocked
//! leaf-scan kernels (before/after vs the pointwise loops they
//! replaced), the persistent worker pool vs spawn-per-pass, XLA tile
//! throughput, K-means passes, and k-NN queries. This is the profile the
//! docs/EXPERIMENTS.md §Perf iteration log is based on; the leaf-kernel
//! and pool sections overwrite the repo-root `BENCH_hot_paths.json`
//! baseline.

use anchors_hierarchy::algorithms::kde::{self, ErrorBudget, Kernel};
use anchors_hierarchy::algorithms::{ballquery, kmeans, knn};
use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::data::{Data, DenseMatrix};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::metrics::{block, dense_dot, dense_dot_f32, dense_sqdist, Space};
use anchors_hierarchy::parallel::{Executor, Parallelism};
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::runtime::BatchDistanceEngine;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use std::fmt::Write as _;
use std::sync::Arc;

fn random_space(n: usize, d: usize, seed: u64) -> Space {
    let mut rng = Rng::new(seed);
    let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
}

fn main() {
    let b = Bencher::new(1, 5);

    // --- scalar distance kernels -------------------------------------
    for d in [8usize, 54, 256, 1024] {
        let mut rng = Rng::new(d as u64);
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        b.bench(&format!("scalar/dense_sqdist-d{d}-x10k"), |_| {
            let mut acc = 0.0;
            for _ in 0..10_000 {
                acc += dense_sqdist(std::hint::black_box(&a), std::hint::black_box(&c));
            }
            acc
        });
        b.bench(&format!("scalar/dense_dot-d{d}-x10k"), |_| {
            let mut acc = 0.0;
            for _ in 0..10_000 {
                acc += dense_dot(std::hint::black_box(&a), std::hint::black_box(&c));
            }
            acc
        });
    }

    // --- blocked leaf-scan kernels vs pointwise loops -------------------
    // The 50k × 64 hot-path dataset: one full scan per iteration, in the
    // two shapes the leaf scans use (single query; candidate centers).
    const ROWS: usize = 50_000;
    const DIMS: usize = 64;
    let big = random_space(ROWS, DIMS, 11);
    let all_rows: Vec<u32> = (0..ROWS as u32).collect();
    let q: Vec<f32> = {
        let mut rng = Rng::new(12);
        (0..DIMS).map(|_| rng.normal() as f32).collect()
    };
    let q_sq: f64 = q.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let kb = Bencher::new(1, 5);

    let (vec_pointwise, _) = kb.run("leaf/to-vec-pointwise-50k", |_| {
        let mut acc = 0.0f64;
        for p in 0..ROWS {
            acc += big.dist_to_vec(p, &q, q_sq);
        }
        acc
    });
    println!("{}", vec_pointwise.report());
    let (vec_blocked, _) = kb.run("leaf/to-vec-blocked-50k", |_| {
        let mut out: Vec<f64> = Vec::new();
        block::dists_to_vec(&big, &all_rows, &q, q_sq, &mut out);
        out.iter().sum::<f64>()
    });
    println!("{}", vec_blocked.report());

    let centers: Vec<Vec<f32>> = (0..16)
        .map(|i| {
            let mut rng = Rng::new(100 + i);
            (0..DIMS).map(|_| rng.normal() as f32).collect()
        })
        .collect();
    let c_sq: Vec<f64> = centers.iter().map(|c| dense_dot(c, c)).collect();
    let ident: Vec<u32> = (0..centers.len() as u32).collect();
    let (cent_pointwise, _) = kb.run("leaf/to-centers-k16-pointwise-50k", |_| {
        let mut acc = 0.0f64;
        for p in 0..ROWS {
            for (ci, c) in centers.iter().enumerate() {
                acc += big.dist_to_vec(p, c, c_sq[ci]);
            }
        }
        acc
    });
    println!("{}", cent_pointwise.report());
    let (cent_blocked, _) = kb.run("leaf/to-centers-k16-blocked-50k", |_| {
        let mut out: Vec<f64> = Vec::new();
        block::dists_contig_to_centers(&big, 0..ROWS, &ident, &centers, &c_sq, &mut out);
        out.iter().sum::<f64>()
    });
    println!("{}", cent_blocked.report());

    // --- lane structure: memcpy roof and GB/s ---------------------------
    // The laned kernels claim to be bandwidth-bound. One full 50k×64
    // scan reads rows·d·4 bytes of row data; the roof is an in-bench
    // memcpy of the exact same slab (same bytes, zero arithmetic), so
    // each kernel's GB/s reads directly as a fraction of what this
    // machine's memory system gives this loop shape. The 1-accumulator
    // fold is the pre-lane kernel shape — the laned-vs-scalar delta is
    // the point of the restructure (4 independent f64 chains instead of
    // one serial dependence; 8 f32 chains for the filter kernel).
    fn sqdist_1acc(a: &[f32], b: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for (&x, &y) in a.iter().zip(b) {
            let d = x as f64 - y as f64;
            acc += d * d;
        }
        acc
    }
    let m64 = match &big.data {
        Data::Dense(m) => m,
        _ => unreachable!(),
    };
    let slab_bytes = (ROWS * DIMS * 4) as f64;
    let gbs = |mean: f64| slab_bytes / mean / 1e9;
    let mut roof_buf = vec![0f32; ROWS * DIMS];
    let (roof, _) = kb.run("lanes/memcpy-roof-50kx64", |_| {
        let (src, _) = m64.rows_slab(0..ROWS);
        roof_buf.copy_from_slice(std::hint::black_box(src));
        roof_buf[ROWS]
    });
    println!("{}  [{:.2} GB/s roof]", roof.report(), gbs(roof.mean));
    let (lane_1acc, _) = kb.run("lanes/sqdist-1acc-50kx64", |_| {
        let mut acc = 0.0f64;
        for p in 0..ROWS {
            acc += sqdist_1acc(std::hint::black_box(m64.row(p)), &q);
        }
        acc
    });
    println!("{}  [{:.2} GB/s]", lane_1acc.report(), gbs(lane_1acc.mean));
    let (lane_4, _) = kb.run("lanes/sqdist-4lane-50kx64", |_| {
        let mut acc = 0.0f64;
        for p in 0..ROWS {
            acc += dense_sqdist(std::hint::black_box(m64.row(p)), &q);
        }
        acc
    });
    println!("{}  [{:.2} GB/s]", lane_4.report(), gbs(lane_4.mean));
    let (lane_f32, _) = kb.run("lanes/dot-f32-8lane-50kx64", |_| {
        let mut acc = 0.0f32;
        for p in 0..ROWS {
            acc += dense_dot_f32(std::hint::black_box(m64.row(p)), &q);
        }
        acc
    });
    println!("{}  [{:.2} GB/s]", lane_f32.report(), gbs(lane_f32.mean));

    // --- gather vs contiguous leaf scans (tree-order layout) ------------
    // Build real trees and sweep every leaf in the two leaf-scan shapes:
    // "gather" reads each leaf through its original-id list against the
    // unpermuted dataset (the pre-layout path), "contig" streams the
    // leaf's arena rows as one sequential slab. Same distances, same
    // counts — the delta is pure memory behavior. Two regimes: the
    // 50k×64 hot-path set (cache-resident rows, gather cost = pointer
    // chasing) and a 5k×2000 high-dim set (each row is 8 KB; gather
    // cost = TLB/prefetch misses).
    let hi_dim = random_space(5_000, 2_000, 21);
    let mut layout_results: Vec<(String, f64, f64)> = Vec::new();
    for (label, space) in [("50kx64", &big), ("5kx2000", &hi_dim)] {
        let tree = middle_out::build(
            space,
            &MiddleOutConfig { rmin: 64, ..Default::default() },
        );
        let arena = tree.arena();
        let leaves = tree.leaf_ids();
        let lq: Vec<f32> = {
            let mut rng = Rng::new(31);
            (0..space.dim()).map(|_| rng.normal() as f32).collect()
        };
        let lq_sq: f64 = lq.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let lcenters: Vec<Vec<f32>> = (0..16)
            .map(|i| {
                let mut rng = Rng::new(300 + i);
                (0..space.dim()).map(|_| rng.normal() as f32).collect()
            })
            .collect();
        let lc_sq: Vec<f64> = lcenters.iter().map(|c| dense_dot(c, c)).collect();
        let lident: Vec<u32> = (0..lcenters.len() as u32).collect();

        let (vec_gather, _) = kb.run(&format!("leaf/to-vec-gather-{label}"), |_| {
            let mut out: Vec<f64> = Vec::new();
            let mut acc = 0.0f64;
            for &leaf in &leaves {
                block::dists_to_vec(space, tree.points_under(leaf), &lq, lq_sq, &mut out);
                acc += out.iter().sum::<f64>();
            }
            acc
        });
        println!("{}", vec_gather.report());
        let (vec_contig, _) = kb.run(&format!("leaf/to-vec-contig-{label}"), |_| {
            let mut out: Vec<f64> = Vec::new();
            let mut acc = 0.0f64;
            for &leaf in &leaves {
                block::dists_contig_to_vec(arena, tree.node_rows(leaf), &lq, lq_sq, &mut out);
                acc += out.iter().sum::<f64>();
            }
            acc
        });
        println!("{}", vec_contig.report());
        layout_results.push((
            format!("leaf_scan_to_vec_{label}"),
            vec_gather.mean,
            vec_contig.mean,
        ));

        let (cent_gather, _) = kb.run(&format!("leaf/to-centers-k16-gather-{label}"), |_| {
            let mut out: Vec<f64> = Vec::new();
            let mut acc = 0.0f64;
            for &leaf in &leaves {
                block::dists_to_centers(
                    space,
                    tree.points_under(leaf),
                    &lident,
                    &lcenters,
                    &lc_sq,
                    &mut out,
                );
                acc += out.iter().sum::<f64>();
            }
            acc
        });
        println!("{}", cent_gather.report());
        let (cent_contig, _) = kb.run(&format!("leaf/to-centers-k16-contig-{label}"), |_| {
            let mut out: Vec<f64> = Vec::new();
            let mut acc = 0.0f64;
            for &leaf in &leaves {
                block::dists_contig_to_centers(
                    arena,
                    tree.node_rows(leaf),
                    &lident,
                    &lcenters,
                    &lc_sq,
                    &mut out,
                );
                acc += out.iter().sum::<f64>();
            }
            acc
        });
        println!("{}", cent_contig.report());
        layout_results.push((
            format!("leaf_scan_to_centers_k16_{label}"),
            cent_gather.mean,
            cent_contig.mean,
        ));
    }

    // --- f32 filter tier: full-scan ball stats, tier on vs off ----------
    // Same answers bit-for-bit (tests/kernel_lanes.rs proves it); this
    // measures what the tier buys. A pruned row costs one 8-wide f32
    // dot against a 4-byte/dim slab instead of an f64 kernel eval —
    // half the bytes, twice the lanes. Radius at the ~1/3 distance
    // quantile so both sides of the decision boundary carry real work.
    let mut tier_results: Vec<(String, f64, f64)> = Vec::new();
    for (label, space) in [("50kx64", &big), ("5kx2000", &hi_dim)] {
        let mut tier_on = Space::euclidean(space.data.clone());
        tier_on.set_f32_tier(true);
        let tq: Vec<f32> = {
            let mut rng = Rng::new(61);
            (0..space.dim()).map(|_| rng.normal() as f32).collect()
        };
        let tq_sq = dense_dot(&tq, &tq);
        let mut ds: Vec<f64> = (0..space.n())
            .map(|p| space.dist_to_vec_uncounted(p, &tq, tq_sq))
            .collect();
        ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let radius = ds[space.n() / 3];
        let (scan_off, _) = kb.run(&format!("f32tier/ballstats-off-{label}"), |_| {
            ballquery::naive_ball_stats(space, &tq, radius).count
        });
        println!("{}", scan_off.report());
        let (scan_on, _) = kb.run(&format!("f32tier/ballstats-on-{label}"), |_| {
            ballquery::naive_ball_stats(&tier_on, &tq, radius).count
        });
        println!("{}", scan_on.report());
        tier_results.push((
            format!("f32_tier_ballstats_{label}"),
            scan_off.mean,
            scan_on.mean,
        ));
    }

    // --- pruned KDE vs the naive scan (cached sufficient statistics) ----
    // The PR 7 payoff measurement: tree_kde consumes the per-node count
    // to replace whole-subtree scans with one pivot distance whenever the
    // kernel-value interval fits the budget share. Compact-support
    // Epanechnikov prunes far nodes exactly even at zero budget; Gaussian
    // needs a non-zero relative budget to win. Both regimes from the
    // layout section reappear here: 50k×64 (cache-resident rows) and
    // 5k×2000 (8 KB rows — the naive scan is bandwidth-bound).
    let mut kde_results: Vec<(String, f64, f64)> = Vec::new();
    for (label, space) in [("50kx64", &big), ("5kx2000", &hi_dim)] {
        let tree = middle_out::build(
            space,
            &MiddleOutConfig { rmin: 64, ..Default::default() },
        );
        let kq: Vec<f32> = {
            let mut rng = Rng::new(41);
            (0..space.dim()).map(|_| rng.normal() as f32).collect()
        };
        // Data-scale bandwidth (quarter of the root radius): wide enough
        // that the density is non-trivial, narrow enough that distant
        // subtrees are prunable.
        let h = tree.node(tree.root).radius / 4.0;
        let budget = ErrorBudget { eps_abs: 0.0, eps_rel: 0.01 };
        for kernel in [Kernel::Gaussian, Kernel::Epanechnikov] {
            let kname = kernel.name();
            let (naive, _) = kb.run(&format!("kde/naive-{kname}-{label}"), |_| {
                kde::naive_kde(space, &kq, kernel, h).sum
            });
            println!("{}", naive.report());
            let (pruned, _) = kb.run(&format!("kde/pruned-{kname}-{label}"), |_| {
                kde::tree_kde(space, &tree, &kq, kernel, h, budget).sum
            });
            println!("{}", pruned.report());
            kde_results.push((
                format!("kde_pruned_vs_naive_{kname}_{label}"),
                naive.mean,
                pruned.mean,
            ));
        }
    }

    // --- persistent pool vs spawn-per-pass fan-out ----------------------
    // 64 small parallel passes at 4 workers — the per-iteration frontier
    // shape. "Spawn" builds a fresh executor (and pool) per pass, which
    // is what every pass paid before the persistent pool.
    let passes = 64usize;
    let fan = |exec: &Executor| -> usize {
        exec.map_chunks(ROWS, 4096, |r| {
            let mut n = 0usize;
            for p in r {
                n += (big.data.sqnorm(p) > 0.0) as usize;
            }
            n
        })
        .iter()
        .sum()
    };
    let (pool_spawn, _) = kb.run("pool/spawn-per-pass-x64-4t", |_| {
        let mut total = 0usize;
        for _ in 0..passes {
            let exec = Executor::new(Parallelism::Fixed(4));
            total += fan(&exec);
        }
        total
    });
    println!("{}", pool_spawn.report());
    let (pool_persistent, _) = kb.run("pool/persistent-x64-4t", |_| {
        let exec = Executor::new(Parallelism::Fixed(4));
        let mut total = 0usize;
        for _ in 0..passes {
            total += fan(&exec);
        }
        total
    });
    println!("{}", pool_persistent.report());

    // --- record the baseline --------------------------------------------
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"status\": \"measured\",");
    let _ = writeln!(
        json,
        "  \"dataset\": {{ \"rows\": {ROWS}, \"dims\": {DIMS}, \"kind\": \"gaussian\", \"seed\": 11 }},"
    );
    let mut rows: Vec<(String, f64, f64)> = vec![
        ("leaf_to_vec".into(), vec_pointwise.mean, vec_blocked.mean),
        ("leaf_to_centers_k16".into(), cent_pointwise.mean, cent_blocked.mean),
        ("pool_fanout_x64_4t".into(), pool_spawn.mean, pool_persistent.mean),
        ("kernel_sqdist_4lane_50kx64".into(), lane_1acc.mean, lane_4.mean),
        ("kernel_dot_f32_8lane_vs_memcpy_roof".into(), lane_f32.mean, roof.mean),
    ];
    rows.extend(layout_results);
    rows.extend(kde_results);
    rows.extend(tier_results);
    for (name, before, after) in &rows {
        let _ = writeln!(
            json,
            "  \"{name}\": {{ \"before_secs\": {:.6}, \"after_secs\": {:.6}, \"speedup\": {:.3} }},",
            before,
            after,
            before / after
        );
    }
    let _ = writeln!(json, "  \"note\": \"before = pointwise scan / spawn-per-pass / gather leaf scan / naive KDE / 1-acc kernel / tier-off scan; after = blocked kernel / persistent pool / contiguous arena scan / tree-pruned KDE at eps_rel 0.01 / 4-lane kernel / f32-filter-tier scan (leaf_scan_*, kde_*, f32_tier_* rows: 50k×64 and 5k×2000; kernel_dot_f32 row compares against the in-bench memcpy roof, so 'speedup' there = fraction of roof as before/after)\"");
    let _ = writeln!(json, "}}");
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_paths.json");
    std::fs::write(path, &json).expect("write BENCH_hot_paths.json");
    println!("leaf-kernel/pool baseline -> {path}");

    // --- XLA tile throughput ------------------------------------------
    match BatchDistanceEngine::open_default() {
        Ok(engine) => {
            for d in [8usize, 64, 1024] {
                let space = random_space(256, d, 7);
                let rows: Vec<u32> = (0..256).collect();
                let centers: Vec<Vec<f32>> = (0..128)
                    .map(|i| {
                        let mut rng = Rng::new(1000 + i);
                        (0..d).map(|_| rng.normal() as f32).collect()
                    })
                    .collect();
                // Warm the compile cache outside the timing loop.
                let _ = engine.dist2_block(&space, &rows, &centers);
                b.bench(&format!("xla/pairwise-256x128-d{d}"), |_| {
                    engine.dist2_block(&space, &rows, &centers).len()
                });
            }
        }
        Err(e) => println!("xla benches skipped: {e}"),
    }

    // --- K-means passes -------------------------------------------------
    let space = DatasetSpec::scaled(DatasetKind::Cell, 0.1).build();
    let tree = middle_out::build(&space, &MiddleOutConfig::default());
    // Serial: these lines are the single-core hot-path baselines.
    let opts = kmeans::KmeansOpts { parallelism: Parallelism::Serial, ..Default::default() };
    b.bench("kmeans/naive-1pass-k20", |i| {
        kmeans::naive_lloyd(&space, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
            seed: i as u64,
            ..opts.clone()
        })
        .dists
    });
    b.bench("kmeans/tree-1pass-k20", |i| {
        kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
            seed: i as u64,
            ..opts.clone()
        })
        .dists
    });
    if let Ok(engine) = BatchDistanceEngine::open_default() {
        let xla_opts = kmeans::KmeansOpts {
            engine: Some(Arc::new(engine)),
            ..opts
        };
        b.bench("kmeans/naive-1pass-k20-xla", |i| {
            kmeans::naive_lloyd(&space, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
                seed: i as u64,
                ..xla_opts.clone()
            })
            .dists
        });
    }

    // --- k-NN queries ---------------------------------------------------
    let mut rng = Rng::new(99);
    b.bench("knn/tree-k10-x100", |_| {
        let mut acc = 0usize;
        for _ in 0..100 {
            let q = rng.below(space.n());
            acc += knn::tree_knn_point(&space, &tree, q, 10).len();
        }
        acc
    });
}
