//! Bench: the micro-level hot paths — scalar distance kernels, XLA tile
//! throughput, K-means passes, and k-NN queries. This is the profile the
//! EXPERIMENTS.md §Perf iteration log is based on.

use anchors_hierarchy::algorithms::{kmeans, knn};
use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::data::{Data, DenseMatrix};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::metrics::{dense_dot, dense_sqdist, Space};
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::runtime::BatchDistanceEngine;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use std::sync::Arc;

fn random_space(n: usize, d: usize, seed: u64) -> Space {
    let mut rng = Rng::new(seed);
    let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
}

fn main() {
    let b = Bencher::new(1, 5);

    // --- scalar distance kernels -------------------------------------
    for d in [8usize, 54, 256, 1024] {
        let mut rng = Rng::new(d as u64);
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        b.bench(&format!("scalar/dense_sqdist-d{d}-x10k"), |_| {
            let mut acc = 0.0;
            for _ in 0..10_000 {
                acc += dense_sqdist(std::hint::black_box(&a), std::hint::black_box(&c));
            }
            acc
        });
        b.bench(&format!("scalar/dense_dot-d{d}-x10k"), |_| {
            let mut acc = 0.0;
            for _ in 0..10_000 {
                acc += dense_dot(std::hint::black_box(&a), std::hint::black_box(&c));
            }
            acc
        });
    }

    // --- XLA tile throughput ------------------------------------------
    match BatchDistanceEngine::open_default() {
        Ok(engine) => {
            for d in [8usize, 64, 1024] {
                let space = random_space(256, d, 7);
                let rows: Vec<u32> = (0..256).collect();
                let centers: Vec<Vec<f32>> = (0..128)
                    .map(|i| {
                        let mut rng = Rng::new(1000 + i);
                        (0..d).map(|_| rng.normal() as f32).collect()
                    })
                    .collect();
                // Warm the compile cache outside the timing loop.
                let _ = engine.dist2_block(&space, &rows, &centers);
                b.bench(&format!("xla/pairwise-256x128-d{d}"), |_| {
                    engine.dist2_block(&space, &rows, &centers).len()
                });
            }
        }
        Err(e) => println!("xla benches skipped: {e}"),
    }

    // --- K-means passes -------------------------------------------------
    let space = DatasetSpec::scaled(DatasetKind::Cell, 0.1).build();
    let tree = middle_out::build(&space, &MiddleOutConfig::default());
    // Serial: these lines are the single-core hot-path baselines.
    let opts = kmeans::KmeansOpts { parallelism: Parallelism::Serial, ..Default::default() };
    b.bench("kmeans/naive-1pass-k20", |i| {
        kmeans::naive_lloyd(&space, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
            seed: i as u64,
            ..opts.clone()
        })
        .dists
    });
    b.bench("kmeans/tree-1pass-k20", |i| {
        kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
            seed: i as u64,
            ..opts.clone()
        })
        .dists
    });
    if let Ok(engine) = BatchDistanceEngine::open_default() {
        let xla_opts = kmeans::KmeansOpts {
            engine: Some(Arc::new(engine)),
            ..opts
        };
        b.bench("kmeans/naive-1pass-k20-xla", |i| {
            kmeans::naive_lloyd(&space, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
                seed: i as u64,
                ..xla_opts.clone()
            })
            .dists
        });
    }

    // --- k-NN queries ---------------------------------------------------
    let mut rng = Rng::new(99);
    b.bench("knn/tree-k10-x100", |_| {
        let mut acc = 0usize;
        for _ in 0..100 {
            let q = rng.below(space.n());
            acc += knn::tree_knn_point(&space, &tree, q, 10).len();
        }
        acc
    });
}
