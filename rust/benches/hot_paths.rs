//! Bench: the micro-level hot paths — scalar distance kernels, blocked
//! leaf-scan kernels (before/after vs the pointwise loops they
//! replaced), the persistent worker pool vs spawn-per-pass, XLA tile
//! throughput, K-means passes, and k-NN queries. This is the profile the
//! docs/EXPERIMENTS.md §Perf iteration log is based on; the leaf-kernel
//! and pool sections overwrite the repo-root `BENCH_hot_paths.json`
//! baseline.

use anchors_hierarchy::algorithms::{kmeans, knn};
use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::data::{Data, DenseMatrix};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::metrics::{block, dense_dot, dense_sqdist, Space};
use anchors_hierarchy::parallel::{Executor, Parallelism};
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::runtime::BatchDistanceEngine;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use std::fmt::Write as _;
use std::sync::Arc;

fn random_space(n: usize, d: usize, seed: u64) -> Space {
    let mut rng = Rng::new(seed);
    let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
}

fn main() {
    let b = Bencher::new(1, 5);

    // --- scalar distance kernels -------------------------------------
    for d in [8usize, 54, 256, 1024] {
        let mut rng = Rng::new(d as u64);
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        b.bench(&format!("scalar/dense_sqdist-d{d}-x10k"), |_| {
            let mut acc = 0.0;
            for _ in 0..10_000 {
                acc += dense_sqdist(std::hint::black_box(&a), std::hint::black_box(&c));
            }
            acc
        });
        b.bench(&format!("scalar/dense_dot-d{d}-x10k"), |_| {
            let mut acc = 0.0;
            for _ in 0..10_000 {
                acc += dense_dot(std::hint::black_box(&a), std::hint::black_box(&c));
            }
            acc
        });
    }

    // --- blocked leaf-scan kernels vs pointwise loops -------------------
    // The 50k × 64 hot-path dataset: one full scan per iteration, in the
    // two shapes the leaf scans use (single query; candidate centers).
    const ROWS: usize = 50_000;
    const DIMS: usize = 64;
    let big = random_space(ROWS, DIMS, 11);
    let all_rows: Vec<u32> = (0..ROWS as u32).collect();
    let q: Vec<f32> = {
        let mut rng = Rng::new(12);
        (0..DIMS).map(|_| rng.normal() as f32).collect()
    };
    let q_sq: f64 = q.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let kb = Bencher::new(1, 5);

    let (vec_pointwise, _) = kb.run("leaf/to-vec-pointwise-50k", |_| {
        let mut acc = 0.0f64;
        for p in 0..ROWS {
            acc += big.dist_to_vec(p, &q, q_sq);
        }
        acc
    });
    println!("{}", vec_pointwise.report());
    let (vec_blocked, _) = kb.run("leaf/to-vec-blocked-50k", |_| {
        let mut out: Vec<f64> = Vec::new();
        block::dists_to_vec(&big, &all_rows, &q, q_sq, &mut out);
        out.iter().sum::<f64>()
    });
    println!("{}", vec_blocked.report());

    let centers: Vec<Vec<f32>> = (0..16)
        .map(|i| {
            let mut rng = Rng::new(100 + i);
            (0..DIMS).map(|_| rng.normal() as f32).collect()
        })
        .collect();
    let c_sq: Vec<f64> = centers.iter().map(|c| dense_dot(c, c)).collect();
    let ident: Vec<u32> = (0..centers.len() as u32).collect();
    let (cent_pointwise, _) = kb.run("leaf/to-centers-k16-pointwise-50k", |_| {
        let mut acc = 0.0f64;
        for p in 0..ROWS {
            for (ci, c) in centers.iter().enumerate() {
                acc += big.dist_to_vec(p, c, c_sq[ci]);
            }
        }
        acc
    });
    println!("{}", cent_pointwise.report());
    let (cent_blocked, _) = kb.run("leaf/to-centers-k16-blocked-50k", |_| {
        let mut out: Vec<f64> = Vec::new();
        block::dists_range_to_centers(&big, 0..ROWS, &ident, &centers, &c_sq, &mut out);
        out.iter().sum::<f64>()
    });
    println!("{}", cent_blocked.report());

    // --- persistent pool vs spawn-per-pass fan-out ----------------------
    // 64 small parallel passes at 4 workers — the per-iteration frontier
    // shape. "Spawn" builds a fresh executor (and pool) per pass, which
    // is what every pass paid before the persistent pool.
    let passes = 64usize;
    let fan = |exec: &Executor| -> usize {
        exec.map_chunks(ROWS, 4096, |r| {
            let mut n = 0usize;
            for p in r {
                n += (big.data.sqnorm(p) > 0.0) as usize;
            }
            n
        })
        .iter()
        .sum()
    };
    let (pool_spawn, _) = kb.run("pool/spawn-per-pass-x64-4t", |_| {
        let mut total = 0usize;
        for _ in 0..passes {
            let exec = Executor::new(Parallelism::Fixed(4));
            total += fan(&exec);
        }
        total
    });
    println!("{}", pool_spawn.report());
    let (pool_persistent, _) = kb.run("pool/persistent-x64-4t", |_| {
        let exec = Executor::new(Parallelism::Fixed(4));
        let mut total = 0usize;
        for _ in 0..passes {
            total += fan(&exec);
        }
        total
    });
    println!("{}", pool_persistent.report());

    // --- record the baseline --------------------------------------------
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"status\": \"measured\",");
    let _ = writeln!(
        json,
        "  \"dataset\": {{ \"rows\": {ROWS}, \"dims\": {DIMS}, \"kind\": \"gaussian\", \"seed\": 11 }},"
    );
    for (name, before, after) in [
        ("leaf_to_vec", &vec_pointwise, &vec_blocked),
        ("leaf_to_centers_k16", &cent_pointwise, &cent_blocked),
        ("pool_fanout_x64_4t", &pool_spawn, &pool_persistent),
    ] {
        let _ = writeln!(
            json,
            "  \"{name}\": {{ \"before_secs\": {:.6}, \"after_secs\": {:.6}, \"speedup\": {:.3} }},",
            before.mean,
            after.mean,
            before.mean / after.mean
        );
    }
    let _ = writeln!(json, "  \"note\": \"before = pointwise scan / spawn-per-pass; after = blocked kernel / persistent pool\"");
    let _ = writeln!(json, "}}");
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_paths.json");
    std::fs::write(path, &json).expect("write BENCH_hot_paths.json");
    println!("leaf-kernel/pool baseline -> {path}");

    // --- XLA tile throughput ------------------------------------------
    match BatchDistanceEngine::open_default() {
        Ok(engine) => {
            for d in [8usize, 64, 1024] {
                let space = random_space(256, d, 7);
                let rows: Vec<u32> = (0..256).collect();
                let centers: Vec<Vec<f32>> = (0..128)
                    .map(|i| {
                        let mut rng = Rng::new(1000 + i);
                        (0..d).map(|_| rng.normal() as f32).collect()
                    })
                    .collect();
                // Warm the compile cache outside the timing loop.
                let _ = engine.dist2_block(&space, &rows, &centers);
                b.bench(&format!("xla/pairwise-256x128-d{d}"), |_| {
                    engine.dist2_block(&space, &rows, &centers).len()
                });
            }
        }
        Err(e) => println!("xla benches skipped: {e}"),
    }

    // --- K-means passes -------------------------------------------------
    let space = DatasetSpec::scaled(DatasetKind::Cell, 0.1).build();
    let tree = middle_out::build(&space, &MiddleOutConfig::default());
    // Serial: these lines are the single-core hot-path baselines.
    let opts = kmeans::KmeansOpts { parallelism: Parallelism::Serial, ..Default::default() };
    b.bench("kmeans/naive-1pass-k20", |i| {
        kmeans::naive_lloyd(&space, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
            seed: i as u64,
            ..opts.clone()
        })
        .dists
    });
    b.bench("kmeans/tree-1pass-k20", |i| {
        kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
            seed: i as u64,
            ..opts.clone()
        })
        .dists
    });
    if let Ok(engine) = BatchDistanceEngine::open_default() {
        let xla_opts = kmeans::KmeansOpts {
            engine: Some(Arc::new(engine)),
            ..opts
        };
        b.bench("kmeans/naive-1pass-k20-xla", |i| {
            kmeans::naive_lloyd(&space, kmeans::Init::Random, 20, 1, &kmeans::KmeansOpts {
                seed: i as u64,
                ..xla_opts.clone()
            })
            .dists
        });
    }

    // --- k-NN queries ---------------------------------------------------
    let mut rng = Rng::new(99);
    b.bench("knn/tree-k10-x100", |_| {
        let mut acc = 0usize;
        for _ in 0..100 {
            let q = rng.below(space.n());
            acc += knn::tree_knn_point(&space, &tree, q, 10).len();
        }
        acc
    });
}
