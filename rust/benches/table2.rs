//! Bench: regenerate Table 2 (distance-computation counts, naive vs
//! metric-tree, per dataset × operation) and time the sweep.
//!
//! Scale via env: `TABLE2_SCALE` (default 0.02 — benches must terminate;
//! EXPERIMENTS.md records a larger-scale run via the CLI).

use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::bench::tables::{self, Table2Config};
use anchors_hierarchy::dataset::DatasetKind;

fn main() {
    let scale: f64 = std::env::var("TABLE2_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.02);
    let cfg = Table2Config {
        scale,
        kmeans_iters: 5,
        rmin: 30,
        seed: 20130,
        datasets: Some(vec![
            DatasetKind::Squiggles,
            DatasetKind::Voronoi,
            DatasetKind::Cell,
            DatasetKind::Covtype,
            DatasetKind::Reuters { half: true },
            DatasetKind::Reuters { half: false },
            DatasetKind::Gen { dims: 100, components: 3 },
            DatasetKind::Gen { dims: 100, components: 20 },
            DatasetKind::Gen { dims: 1000, components: 3 },
            DatasetKind::Gen { dims: 1000, components: 20 },
        ]),
    };
    println!("# Table 2 bench (scale {scale})");
    let bencher = Bencher::new(0, 1);
    let rows = bencher.bench("table2/full-sweep", |_| tables::table2(&cfg));
    tables::print_table2(&rows);

    // Per-dataset timing at the same scale (one representative each).
    for kind in [DatasetKind::Squiggles, DatasetKind::Cell, DatasetKind::Covtype] {
        let one = Table2Config { datasets: Some(vec![kind.clone()]), ..cfg.clone() };
        Bencher::new(1, 3).bench(&format!("table2/{}", kind.name()), |_| {
            tables::table2(&one).len()
        });
    }
}
