//! Bench: dispatch overhead of the engine facade.
//!
//! `Index::run` must be a zero-cost veneer — the same `tree_lloyd` /
//! `tree_knn` calls, plus one enum match and a couple of Arc clones.
//! This bench times each query family through the facade and directly
//! against the algorithm layer, and reports the relative overhead,
//! which should be well under 1% (noise-dominated).

use anchors_hierarchy::algorithms::{kmeans, knn};
use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{IndexBuilder, KmeansQuery, KnnQuery, KnnTarget, Query};
use anchors_hierarchy::parallel::Parallelism;

fn main() {
    let b = Bencher::new(2, 10);
    let spec = DatasetSpec::scaled(DatasetKind::Squiggles, 0.01); // ≈800 × 2
    // Pin everything serial: this bench isolates dispatch overhead, so
    // facade and direct paths must run on identical (single-core) budgets.
    let index = IndexBuilder::new(spec)
        .rmin(30)
        .parallelism(Parallelism::Serial)
        .build();
    let space = index.space();
    let tree = index.tree(); // pay the build outside the timing loops
    let seed = index.seed();

    // --- K-means: facade vs direct -----------------------------------
    let kq = Query::Kmeans(KmeansQuery { k: 10, iters: 5, ..Default::default() });
    let facade = b.run("engine/kmeans-k10-via-run", |_| index.run(&kq)).0;
    let opts = kmeans::KmeansOpts {
        seed,
        parallelism: Parallelism::Serial,
        ..Default::default()
    };
    let direct = b
        .run("direct/kmeans-k10-tree_lloyd", |_| {
            kmeans::tree_lloyd(space, &tree, kmeans::Init::Random, 10, 5, &opts)
        })
        .0;
    println!("{}", facade.report());
    println!("{}", direct.report());
    report_overhead("kmeans", direct.mean, facade.mean);

    // --- k-NN: facade vs direct (per-query cost is tiny, so any
    //     dispatch overhead would show up loudest here) ----------------
    let n_queries = 200usize.min(space.n());
    let knnq: Vec<Query> = (0..n_queries)
        .map(|i| {
            Query::Knn(KnnQuery { target: KnnTarget::Point(i as u32), k: 5, use_tree: true })
        })
        .collect();
    let facade = b
        .run("engine/knn-x200-via-run_batch", |_| index.run_batch(&knnq).len())
        .0;
    let mut qrow = vec![0f32; space.dim()];
    let direct = b
        .run("direct/knn-x200-tree_knn", |_| {
            let mut total = 0usize;
            for i in 0..n_queries {
                space.fill_row(i, &mut qrow);
                let q_sq = space.data.sqnorm(i);
                total += knn::tree_knn(space, &tree, &qrow, q_sq, 5, Some(i as u32)).len();
            }
            total
        })
        .0;
    println!("{}", facade.report());
    println!("{}", direct.report());
    report_overhead("knn", direct.mean, facade.mean);
}

fn report_overhead(what: &str, direct_mean: f64, facade_mean: f64) {
    let overhead = (facade_mean - direct_mean) / direct_mean * 100.0;
    println!(
        "{what}: facade overhead {overhead:+.2}% (direct {:.3e}s, via Index::run {:.3e}s)\n",
        direct_mean, facade_mean
    );
}
