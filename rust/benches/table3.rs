//! Bench: regenerate Table 3 (anchors-built vs top-down tree, K-means
//! distance ratio) and time both builders (with the exact-radii ablation
//! DESIGN.md calls out).

use anchors_hierarchy::bench::harness::Bencher;
use anchors_hierarchy::bench::tables;
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::top_down;

fn main() {
    let scale: f64 = std::env::var("TABLE3_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.02);
    println!("# Table 3 bench (scale {scale})");
    let rows = Bencher::new(0, 1).bench("table3/full-sweep", |_| {
        tables::table3(scale, 5, 30, 20130)
    });
    tables::print_table3(&rows);

    // Builder wall-clock comparison (ablation: middle-out vs top-down vs
    // middle-out with exact radii).
    for kind in [DatasetKind::Cell, DatasetKind::Covtype] {
        let space = DatasetSpec::scaled(kind.clone(), scale).build();
        let b = Bencher::new(1, 3);
        b.bench(&format!("build/{}/middle-out", kind.name()), |i| {
            middle_out::build(
                &space,
                &MiddleOutConfig {
                    rmin: 30,
                    seed: i as u64,
                    parallelism: Parallelism::Serial,
                    ..Default::default()
                },
            )
            .nodes
            .len()
        });
        b.bench(&format!("build/{}/middle-out-exact", kind.name()), |i| {
            middle_out::build(
                &space,
                &MiddleOutConfig {
                    rmin: 30,
                    seed: i as u64,
                    exact_radii: true,
                    parallelism: Parallelism::Serial,
                    ..Default::default()
                },
            )
            .nodes
            .len()
        });
        b.bench(&format!("build/{}/top-down", kind.name()), |_| {
            top_down::build(&space, 30).nodes.len()
        });
    }
}
