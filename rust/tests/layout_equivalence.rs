//! Equivalence proofs for the tree-order memory layout.
//!
//! The layout refactor (contiguous leaf arenas + zero-gather kernels)
//! claims to change *nothing* observable: every query result
//! bit-identical, every distance count exact. These tests prove it at
//! three levels rather than assuming it:
//!
//! 1. **Kernel level** — for every leaf of a real tree, the contiguous
//!    kernel over the arena rows returns bit-identical distances and
//!    the same count as the gather kernel over the original rows (the
//!    pre-layout scan it replaced).
//! 2. **Boundary level** — a *pre-permutation reference path*: the same
//!    dataset physically permuted into leaf order up front, queried
//!    through an identity-layout copy of the tree (so no id translation
//!    happens at all). Mapping the reference's results through the
//!    layout must reproduce the layout path's results exactly, with
//!    exact per-query distance counts — for every algorithm family with
//!    a leaf scan: knn, ball, anomaly, allpairs, kmeans, EM.
//! 3. **Snapshot level** — serialize → deserialize → re-attach arena
//!    replays knn/kmeans/allpairs bit-identically against a fresh
//!    build, dense + sparse, threads {1, 8} — and the cached-statistics
//!    queries (KDE / kernel regression / ball moments) replay
//!    bit-identically through both the current `AHTREE03` format and a
//!    legacy `AHTREE02` snapshot whose `sum2` is recomputed at
//!    `attach_arena` time.
//!
//! (MST joins level 2 at the *edge set* level only: its Borůvka rounds
//! seed each component's pruning bound from the scan-order-dependent
//! running best, so per-round distance *counts* legitimately depend on
//! point order and are pinned per path — each path must reproduce its
//! own count exactly on a re-run — while the canonical undirected edge
//! set mapped through the layout must agree bit-for-bit.)

use anchors_hierarchy::algorithms::kde::{self, ErrorBudget, Kernel};
use anchors_hierarchy::algorithms::{allpairs, anomaly, ballquery, gaussian, kmeans, knn, mst};
use anchors_hierarchy::data::Data;
use anchors_hierarchy::dataset::{gaussian_mixture, gen_mixture};
use anchors_hierarchy::metrics::{block, dense_dot, Space};
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::{serialize, top_down, Layout, MetricTree};

fn dense_space() -> Space {
    Space::euclidean(Data::Dense(gaussian_mixture(900, 8, 5, 18.0, 77)))
}

fn sparse_space() -> Space {
    Space::euclidean(Data::Sparse(gen_mixture(500, 90, 4, 77)))
}

fn build(space: &Space, rmin: usize) -> MetricTree {
    middle_out::build(space, &MiddleOutConfig { rmin, seed: 9, ..Default::default() })
}

/// The pre-permutation reference: the dataset physically copied into
/// tree order (its own fresh distance counter) plus a clone of the tree
/// whose layout is the identity — leaf scans read the permuted data
/// directly and results come back in arena-row ids, exactly what the
/// old gather path would produce on the permuted dataset.
fn reference_pair(space: &Space, tree: &MetricTree) -> (Space, MetricTree) {
    let permuted = space.select_rows(&tree.layout.inv);
    let space2 = Space::new(permuted.data.clone(), space.metric);
    let n = tree.layout.inv.len() as u32;
    let ident: Vec<u32> = (0..n).collect();
    let mut tree2 = MetricTree {
        nodes: tree.nodes.clone(),
        root: tree.root,
        rmin: tree.rmin,
        build_dists: tree.build_dists,
        layout: Layout { perm: ident.clone(), inv: ident },
        arena: None,
    };
    tree2.attach_arena(&space2);
    (space2, tree2)
}

fn query_vec(dim: usize, seed: u64) -> (Vec<f32>, f64) {
    let mut rng = Rng::new(seed);
    let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 4.0).collect();
    let q_sq = dense_dot(&q, &q);
    (q, q_sq)
}

fn given_seeds(dim: usize, k: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k)
        .map(|_| (0..dim).map(|_| rng.normal() as f32 * 6.0).collect())
        .collect()
}

// ---------------------------------------------------------------------
// Level 1: per-leaf kernel oracle.
// ---------------------------------------------------------------------

#[test]
fn contig_leaf_kernels_match_gather_reference_per_leaf() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 20);
        let arena = tree.arena();
        let (q, q_sq) = query_vec(space.dim(), 3);
        let centroids = given_seeds(space.dim(), 6, 4);
        let c_sq: Vec<f64> = centroids.iter().map(|c| dense_dot(c, c)).collect();
        let cand: Vec<u32> = vec![0, 2, 3, 5];
        let (mut gather, mut contig) = (Vec::new(), Vec::new());
        let leaves = tree.leaf_ids();
        for &leaf in &leaves {
            let ids = tree.points_under(leaf);
            let rows = tree.node_rows(leaf);

            // Single-query shape (knn / ball / anomaly leaves).
            space.reset_count();
            block::dists_to_vec(&space, ids, &q, q_sq, &mut gather);
            let gather_count = space.dist_count();
            space.reset_count();
            block::dists_contig_to_vec(arena, rows.clone(), &q, q_sq, &mut contig);
            assert_eq!(space.dist_count(), gather_count, "{label} leaf {leaf} to_vec count");
            assert_eq!(gather.len(), contig.len());
            for (g, c) in gather.iter().zip(&contig) {
                assert_eq!(g.to_bits(), c.to_bits(), "{label} leaf {leaf} to_vec");
            }

            // Multi-center shape (kmeans leaf_assign / EM leaves).
            space.reset_count();
            block::dists_to_centers(&space, ids, &cand, &centroids, &c_sq, &mut gather);
            let gather_count = space.dist_count();
            space.reset_count();
            block::dists_contig_to_centers(arena, rows, &cand, &centroids, &c_sq, &mut contig);
            assert_eq!(space.dist_count(), gather_count, "{label} leaf {leaf} centers count");
            for (g, c) in gather.iter().zip(&contig) {
                assert_eq!(g.to_bits(), c.to_bits(), "{label} leaf {leaf} centers");
            }
        }

        // Leaf-leaf shape (allpairs blocks): first leaf vs last leaf.
        let (a, b) = (leaves[0], *leaves.last().unwrap());
        space.reset_count();
        block::dists_rows(&space, tree.points_under(a), tree.points_under(b), &mut gather);
        let gather_count = space.dist_count();
        space.reset_count();
        block::dists_contig_rows(arena, tree.node_rows(a), tree.node_rows(b), &mut contig);
        assert_eq!(space.dist_count(), gather_count, "{label} leaf-leaf count");
        assert_eq!(gather.len(), contig.len());
        for (g, c) in gather.iter().zip(&contig) {
            assert_eq!(g.to_bits(), c.to_bits(), "{label} leaf-leaf");
        }
    }
}

// ---------------------------------------------------------------------
// Level 2: pre-permutation reference path, per algorithm family.
// ---------------------------------------------------------------------

#[test]
fn knn_matches_pre_permutation_reference() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (space2, tree2) = reference_pair(&space, &tree);
        let inv = &tree.layout.inv;

        // Vector targets.
        for seed in 0..6u64 {
            let (q, q_sq) = query_vec(space.dim(), 100 + seed);
            let before = space.dist_count();
            let got = knn::tree_knn(&space, &tree, &q, q_sq, 7, None);
            let got_dists = space.dist_count() - before;
            let before = space2.dist_count();
            let reference = knn::tree_knn(&space2, &tree2, &q, q_sq, 7, None);
            let ref_dists = space2.dist_count() - before;
            assert_eq!(got_dists, ref_dists, "{label} q{seed}: distance count");
            assert_eq!(got.len(), reference.len());
            for (g, r) in got.iter().zip(&reference) {
                assert_eq!(g.id, inv[r.id as usize], "{label} q{seed}: id");
                assert_eq!(g.dist.to_bits(), r.dist.to_bits(), "{label} q{seed}: dist");
            }
        }

        // Point targets (exercises the skip-row split).
        for q in [0usize, 7, space.n() - 1] {
            let before = space.dist_count();
            let got = knn::tree_knn_point(&space, &tree, q, 5);
            let got_dists = space.dist_count() - before;
            let q_row = tree.layout.perm[q] as usize;
            let before = space2.dist_count();
            let reference = knn::tree_knn_point(&space2, &tree2, q_row, 5);
            let ref_dists = space2.dist_count() - before;
            assert_eq!(got_dists, ref_dists, "{label} point {q}: distance count");
            for (g, r) in got.iter().zip(&reference) {
                assert_eq!(g.id, inv[r.id as usize], "{label} point {q}: id");
                assert_eq!(g.dist.to_bits(), r.dist.to_bits(), "{label} point {q}: dist");
            }
        }
    }
}

#[test]
fn ball_stats_match_pre_permutation_reference() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (space2, tree2) = reference_pair(&space, &tree);
        for (seed, radius) in [(1u64, 2.0), (2, 8.0), (3, 40.0)] {
            let (center, _) = query_vec(space.dim(), 200 + seed);
            let got = ballquery::tree_ball_stats(&space, &tree, &center, radius);
            let reference = ballquery::tree_ball_stats(&space2, &tree2, &center, radius);
            assert_eq!(got.count, reference.count, "{label} r={radius}: count");
            assert_eq!(got.mean, reference.mean, "{label} r={radius}: mean");
            assert_eq!(
                got.total_variance.to_bits(),
                reference.total_variance.to_bits(),
                "{label} r={radius}: variance"
            );
            assert_eq!(got.dists, reference.dists, "{label} r={radius}: distance count");
        }
    }
}

#[test]
fn anomaly_sweep_matches_pre_permutation_reference() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (space2, tree2) = reference_pair(&space, &tree);
        let params = anomaly::AnomalyParams { radius: 4.0, threshold: 12 };
        let got = anomaly::tree_sweep(&space, &tree, &params);
        let reference = anomaly::tree_sweep(&space2, &tree2, &params);
        assert_eq!(got.n_anomalies, reference.n_anomalies, "{label}: anomaly total");
        assert_eq!(got.dists, reference.dists, "{label}: distance count");
        for (q, &flag) in got.flags.iter().enumerate() {
            let row = tree.layout.perm[q] as usize;
            assert_eq!(flag, reference.flags[row], "{label}: flag of point {q}");
        }
    }
}

#[test]
fn allpairs_match_pre_permutation_reference() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (space2, tree2) = reference_pair(&space, &tree);
        let inv = &tree.layout.inv;
        for tau in [0.8, 3.0] {
            let got = allpairs::tree_close_pairs(&space, &tree, tau);
            let reference = allpairs::tree_close_pairs(&space2, &tree2, tau);
            assert_eq!(got.dists, reference.dists, "{label} tau={tau}: distance count");
            let mut mapped: Vec<(u32, u32)> = reference
                .pairs
                .iter()
                .map(|&(i, j)| {
                    let (a, b) = (inv[i as usize], inv[j as usize]);
                    (a.min(b), a.max(b))
                })
                .collect();
            mapped.sort_unstable();
            assert_eq!(got.pairs, mapped, "{label} tau={tau}: pair set");
        }
    }
}

#[test]
fn kmeans_matches_pre_permutation_reference() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (space2, tree2) = reference_pair(&space, &tree);
        let seeds = given_seeds(space.dim(), 6, 31);
        for threads in [1usize, 8] {
            let opts = kmeans::KmeansOpts {
                parallelism: Parallelism::Fixed(threads),
                ..Default::default()
            };
            let got = kmeans::tree_lloyd(
                &space,
                &tree,
                kmeans::Init::Given(seeds.clone()),
                seeds.len(),
                5,
                &opts,
            );
            let reference = kmeans::tree_lloyd(
                &space2,
                &tree2,
                kmeans::Init::Given(seeds.clone()),
                seeds.len(),
                5,
                &opts,
            );
            assert_eq!(got.centroids, reference.centroids, "{label} {threads}t: centers");
            assert_eq!(
                got.distortion.to_bits(),
                reference.distortion.to_bits(),
                "{label} {threads}t: distortion"
            );
            assert_eq!(got.dists, reference.dists, "{label} {threads}t: distance count");
        }
    }
}

#[test]
fn gaussian_em_matches_pre_permutation_reference() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (space2, tree2) = reference_pair(&space, &tree);
        let seeds = given_seeds(space.dim(), 4, 57);
        for tau in [0.0, 0.05] {
            let mut got_mix = gaussian::Mixture::from_seeds(seeds.clone());
            let mut ref_mix = gaussian::Mixture::from_seeds(seeds.clone());
            for step in 0..3 {
                let before = space.dist_count();
                let got_ll = gaussian::tree_em_step(&space, &tree, &mut got_mix, tau);
                let got_dists = space.dist_count() - before;
                let before = space2.dist_count();
                let ref_ll = gaussian::tree_em_step(&space2, &tree2, &mut ref_mix, tau);
                let ref_dists = space2.dist_count() - before;
                assert_eq!(
                    got_ll.to_bits(),
                    ref_ll.to_bits(),
                    "{label} tau={tau} step {step}: loglik"
                );
                assert_eq!(got_dists, ref_dists, "{label} tau={tau} step {step}: count");
            }
            assert_eq!(got_mix.means, ref_mix.means, "{label} tau={tau}: means");
            assert_eq!(got_mix.weights, ref_mix.weights, "{label} tau={tau}: weights");
            assert_eq!(got_mix.variances, ref_mix.variances, "{label} tau={tau}: variances");
        }
    }
}

/// MST arena consistency: the canonical undirected edge set of the
/// layout path equals the pre-permutation reference's mapped through the
/// layout, with bit-identical weights — and each path's distance count
/// reproduces exactly on a re-run (the counts themselves legitimately
/// differ *between* paths; see the module doc).
#[test]
fn mst_edge_set_matches_pre_permutation_reference() {
    fn canonical(edges: &[mst::Edge]) -> Vec<(u32, u32, u64)> {
        let mut out: Vec<(u32, u32, u64)> = edges
            .iter()
            .map(|e| (e.a.min(e.b), e.a.max(e.b), e.dist.to_bits()))
            .collect();
        out.sort_unstable();
        out
    }
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (space2, tree2) = reference_pair(&space, &tree);
        let inv = &tree.layout.inv;

        let before = space.dist_count();
        let got = mst::tree_mst(&space, &tree);
        let got_dists = space.dist_count() - before;
        let reference = mst::tree_mst(&space2, &tree2);

        let mapped: Vec<mst::Edge> = reference
            .iter()
            .map(|e| mst::Edge { a: inv[e.a as usize], b: inv[e.b as usize], dist: e.dist })
            .collect();
        assert_eq!(canonical(&got), canonical(&mapped), "{label}: MST edge set");

        // Each path pins its own distance count exactly.
        let before = space.dist_count();
        let again = mst::tree_mst(&space, &tree);
        assert_eq!(canonical(&got), canonical(&again), "{label}: MST re-run edges");
        assert_eq!(space.dist_count() - before, got_dists, "{label}: MST re-run count");
    }
}

// ---------------------------------------------------------------------
// Level 3: snapshot roundtrip replays queries bit-identically.
// ---------------------------------------------------------------------

#[test]
fn snapshot_roundtrip_replays_queries_identically() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let mut buf = Vec::new();
        serialize::write_tree(&tree, &mut buf).unwrap();
        let mut back = serialize::read_tree(&mut buf.as_slice()).unwrap();
        back.attach_arena(&space);
        back.validate(&space).unwrap();

        let (q, q_sq) = query_vec(space.dim(), 500);
        let seeds = given_seeds(space.dim(), 5, 43);
        for threads in [1usize, 8] {
            // knn
            let before = space.dist_count();
            let a = knn::tree_knn(&space, &tree, &q, q_sq, 6, None);
            let a_dists = space.dist_count() - before;
            let before = space.dist_count();
            let b = knn::tree_knn(&space, &back, &q, q_sq, 6, None);
            let b_dists = space.dist_count() - before;
            assert_eq!(a, b, "{label} {threads}t: knn result");
            assert_eq!(a_dists, b_dists, "{label} {threads}t: knn count");

            // kmeans (the only family here with a parallel pass).
            let opts = kmeans::KmeansOpts {
                parallelism: Parallelism::Fixed(threads),
                ..Default::default()
            };
            let a = kmeans::tree_lloyd(
                &space,
                &tree,
                kmeans::Init::Given(seeds.clone()),
                seeds.len(),
                4,
                &opts,
            );
            let b = kmeans::tree_lloyd(
                &space,
                &back,
                kmeans::Init::Given(seeds.clone()),
                seeds.len(),
                4,
                &opts,
            );
            assert_eq!(a.centroids, b.centroids, "{label} {threads}t: kmeans centers");
            assert_eq!(
                a.distortion.to_bits(),
                b.distortion.to_bits(),
                "{label} {threads}t: kmeans distortion"
            );
            assert_eq!(a.dists, b.dists, "{label} {threads}t: kmeans count");

            // allpairs
            let a = allpairs::tree_close_pairs(&space, &tree, 1.5);
            let b = allpairs::tree_close_pairs(&space, &back, 1.5);
            assert_eq!(a.pairs, b.pairs, "{label} {threads}t: allpairs pairs");
            assert_eq!(a.dists, b.dists, "{label} {threads}t: allpairs count");
        }
    }
}

/// The cached-statistics queries replay bit-identically (results AND
/// distance counts) through an `AHTREE03` roundtrip, and through a
/// legacy `AHTREE02` snapshot whose `sum2` decoration is recomputed by
/// `attach_arena` — the recompute is bit-exact, so the replays are too.
#[test]
fn snapshot_roundtrip_replays_stats_queries_identically() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = build(&space, 16);
        let (center, _) = query_vec(space.dim(), 600);
        let budget = ErrorBudget { eps_abs: 0.0, eps_rel: 0.02 };
        let run = |t: &MetricTree| {
            (
                kde::tree_kde(&space, t, &center, Kernel::Gaussian, 8.0, budget),
                kde::tree_kernel_regression(
                    &space,
                    t,
                    &center,
                    0,
                    Kernel::Epanechnikov,
                    12.0,
                    budget,
                ),
                ballquery::tree_ball_moments(&space, t, &center, 10.0),
            )
        };
        let want = run(&tree);

        // Current format: sum2 persisted, bit-equal after the roundtrip.
        let mut buf = Vec::new();
        serialize::write_tree(&tree, &mut buf).unwrap();
        assert_eq!(&buf[..8], b"AHTREE03", "{label}: snapshot magic");
        let mut back = serialize::read_tree(&mut buf.as_slice()).unwrap();
        back.attach_arena(&space);
        back.validate(&space).unwrap();
        for (i, (a, b)) in tree.nodes.iter().zip(&back.nodes).enumerate() {
            assert_eq!(a.sum2, b.sum2, "{label}: node {i} sum2 after roundtrip");
        }
        assert_eq!(want, run(&back), "{label}: AHTREE03 replay");

        // Legacy format: no sum2 on disk, recomputed at attach time.
        let mut v2 = Vec::new();
        serialize::write_tree_v2(&tree, &mut v2).unwrap();
        assert_eq!(&v2[..8], b"AHTREE02", "{label}: legacy magic");
        let mut legacy = serialize::read_tree(&mut v2.as_slice()).unwrap();
        assert!(
            legacy.nodes.iter().all(|n| n.sum2.is_empty()),
            "{label}: legacy load must not invent sum2"
        );
        legacy.attach_arena(&space);
        legacy.validate(&space).unwrap();
        for (i, (a, b)) in tree.nodes.iter().zip(&legacy.nodes).enumerate() {
            assert_eq!(a.sum2, b.sum2, "{label}: node {i} sum2 recompute");
        }
        assert_eq!(want, run(&legacy), "{label}: AHTREE02 replay");

        // Damaged snapshots are rejected with errors, not panics:
        // truncation anywhere, and a bit flip inside the first node's
        // sum2 run (header is 28 bytes; the record leads with
        // u32 dim, f32×dim pivot, f64 pivot_sq, f64 radius, u32 count,
        // f64×dim sum, f64 sumsq before the sum2 trailer).
        for cut in [buf.len() - 5, buf.len() / 3] {
            assert!(
                serialize::read_tree(&mut &buf[..cut]).is_err(),
                "{label}: truncation at {cut} accepted"
            );
        }
        let d = space.dim();
        let sum2_at = 28 + 4 + 4 * d + 8 + 8 + 4 + 8 * d + 8;
        let mut corrupt = buf.clone();
        corrupt[sum2_at + 7] ^= 0x40; // exponent bit of sum2[0]
        assert!(
            serialize::read_tree(&mut corrupt.as_slice()).is_err(),
            "{label}: corrupt stat trailer accepted"
        );
    }
}

// ---------------------------------------------------------------------
// Layout structure on both builders and on subset trees.
// ---------------------------------------------------------------------

#[test]
fn layout_validates_on_both_builders_and_subsets() {
    let space = dense_space();
    let mid = build(&space, 16);
    mid.validate(&space).unwrap();
    let td = top_down::build(&space, 16);
    td.validate(&space).unwrap();

    // Subset tree: perm marks outside points as unmapped; points_under
    // still yields exactly the subset.
    let subset: Vec<u32> = (0..space.n() as u32).filter(|p| p % 3 != 0).collect();
    let sub = middle_out::build_subset(
        &space,
        subset.clone(),
        &MiddleOutConfig { rmin: 12, ..Default::default() },
    );
    sub.validate(&space).unwrap();
    let mut owned = sub.points_under(sub.root).to_vec();
    owned.sort_unstable();
    assert_eq!(owned, subset);
    for p in (0..space.n() as u32).filter(|p| p % 3 == 0) {
        assert_eq!(sub.layout.perm[p as usize], u32::MAX, "outside point {p} mapped");
    }

    // points_under is a zero-copy view consistent with node_rows on
    // every node, leaves and interiors alike.
    for id in 0..mid.nodes.len() as u32 {
        let rows = mid.node_rows(id);
        assert_eq!(mid.points_under(id).len(), rows.len(), "node {id} view length");
    }
}
