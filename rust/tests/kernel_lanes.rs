//! Lane/tail oracle for the lane-structured kernels and the f32 filter
//! tier.
//!
//! The kernel refactor claims a single canonical summation order — 4
//! independent f64 lanes (8 f32 lanes for the filter kernel), tail into
//! lane 0, fixed combine — shared by every path. These tests prove the
//! claims the rest of the repo leans on, at every awkward lane
//! remainder (d mod 4 ∈ {0,1,2,3}, d mod 8 likewise, d = 0, and a
//! high-dimensional d = 2000):
//!
//! 1. **Kernel level** — each kernel bit-matches an independently
//!    written reference fold of the canonical order, and repeat calls
//!    are bit-stable.
//! 2. **Path level** — gather ≡ contig per leaf and naive ≡ tree for
//!    knn, on dense and sparse data, stay bit-identical (the laned
//!    order is one order, used everywhere).
//! 3. **Tier level** — with `set_f32_tier(true)` on an identical copy
//!    of the data, knn / ball stats / ball moments / anomaly answers
//!    are **bit-identical** to tier-off, on trees built at threads
//!    {1, 8}, while the (f64_evals, f32_evals) split is deterministic:
//!    exact same pair on every re-run and at every thread count.
//!    Tier-off, `f32_evals` stays 0.
//! 4. **Engine level** — `IndexBuilder::with_f32_tier` flows to the
//!    space, `QueryResult`s match tier-off bit-for-bit, and the index
//!    reports the f32 eval counter separately.

use anchors_hierarchy::algorithms::{anomaly, ballquery, knn};
use anchors_hierarchy::data::Data;
use anchors_hierarchy::dataset::{gaussian_mixture, gen_mixture, DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{BallStatsQuery, IndexBuilder, KnnQuery, KnnTarget, Query};
use anchors_hierarchy::metrics::{block, dense_dot, dense_dot_f32, dense_l1, dense_sqdist, Space};
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::MetricTree;

/// Every lane-remainder class for both lane widths, plus degenerate and
/// high-dimensional extremes.
const DIMS: [usize; 10] = [0, 1, 3, 7, 8, 9, 63, 64, 65, 2000];

fn vec_pair(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let a = (0..d).map(|_| rng.normal() as f32 * 3.0).collect();
    let b = (0..d).map(|_| rng.normal() as f32 * 3.0).collect();
    (a, b)
}

// ---------------------------------------------------------------------
// Level 1: reference folds of the canonical order, written from the
// spec (not the kernel source): 4 f64 lanes / 8 f32 lanes, lane i takes
// element i of each chunk, tail folds into lane 0, fixed combine.
// ---------------------------------------------------------------------

fn ref_fold4(a: &[f32], b: &[f32], term: impl Fn(f32, f32) -> f64) -> f64 {
    let mut acc = [0.0f64; 4];
    let main = a.len() / 4 * 4;
    for c in 0..main / 4 {
        for l in 0..4 {
            acc[l] += term(a[c * 4 + l], b[c * 4 + l]);
        }
    }
    for j in main..a.len() {
        acc[0] += term(a[j], b[j]);
    }
    ((acc[0] + acc[1]) + acc[2]) + acc[3]
}

fn ref_dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let main = a.len() / 8 * 8;
    for c in 0..main / 8 {
        for l in 0..8 {
            acc[l] += a[c * 8 + l] * b[c * 8 + l];
        }
    }
    for j in main..a.len() {
        acc[0] += a[j] * b[j];
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

#[test]
fn kernels_match_reference_fold_and_are_bit_stable_at_every_tail() {
    for d in DIMS {
        let (a, b) = vec_pair(d, 11 + d as u64);
        let want_dot = ref_fold4(&a, &b, |x, y| x as f64 * y as f64);
        let want_sq = ref_fold4(&a, &b, |x, y| {
            let dd = x as f64 - y as f64;
            dd * dd
        });
        let want_l1 = ref_fold4(&a, &b, |x, y| (x as f64 - y as f64).abs());
        let want_32 = ref_dot_f32(&a, &b);
        for run in 0..3 {
            assert_eq!(dense_dot(&a, &b).to_bits(), want_dot.to_bits(), "d={d} dot run {run}");
            assert_eq!(dense_sqdist(&a, &b).to_bits(), want_sq.to_bits(), "d={d} sqdist run {run}");
            assert_eq!(dense_l1(&a, &b).to_bits(), want_l1.to_bits(), "d={d} l1 run {run}");
            assert_eq!(dense_dot_f32(&a, &b).to_bits(), want_32.to_bits(), "d={d} f32 run {run}");
        }
    }
}

// ---------------------------------------------------------------------
// Level 2: one order everywhere — gather ≡ contig per leaf, naive ≡
// tree for knn, across lane remainders, dense and sparse.
// ---------------------------------------------------------------------

fn dense_space(n: usize, d: usize, seed: u64) -> Space {
    Space::euclidean(Data::Dense(gaussian_mixture(n, d, 3, 12.0, seed)))
}

fn sparse_space(n: usize, d: usize, seed: u64) -> Space {
    Space::euclidean(Data::Sparse(gen_mixture(n, d, 3, seed)))
}

fn build(space: &Space, rmin: usize, threads: usize) -> MetricTree {
    middle_out::build(
        space,
        &MiddleOutConfig {
            rmin,
            seed: 9,
            parallelism: Parallelism::Fixed(threads),
            ..Default::default()
        },
    )
}

fn query(space: &Space, seed: u64) -> (Vec<f32>, f64) {
    let mut rng = Rng::new(seed);
    let q: Vec<f32> = (0..space.dim()).map(|_| rng.normal() as f32 * 3.0).collect();
    let q_sq = dense_dot(&q, &q);
    (q, q_sq)
}

fn spaces() -> Vec<(Space, String)> {
    let mut out = Vec::new();
    for d in [1usize, 3, 7, 8, 9, 63, 64, 65] {
        out.push((dense_space(300, d, 40 + d as u64), format!("dense d={d}")));
    }
    out.push((dense_space(60, 2000, 99), "dense d=2000".into()));
    for d in [9usize, 63] {
        out.push((sparse_space(250, d, 50 + d as u64), format!("sparse d={d}")));
    }
    out
}

#[test]
fn gather_equals_contig_and_naive_equals_tree_across_dims() {
    for (space, label) in spaces() {
        let tree = build(&space, 12, 1);
        let arena = tree.arena();
        let (q, q_sq) = query(&space, 7);
        let (mut gather, mut contig) = (Vec::new(), Vec::new());
        for &leaf in &tree.leaf_ids() {
            let ids = tree.points_under(leaf);
            space.reset_count();
            block::dists_to_vec(&space, ids, &q, q_sq, &mut gather);
            let gather_count = space.dist_count();
            space.reset_count();
            block::dists_contig_to_vec(arena, tree.node_rows(leaf), &q, q_sq, &mut contig);
            assert_eq!(space.dist_count(), gather_count, "{label} leaf {leaf} count");
            assert_eq!(gather.len(), contig.len(), "{label} leaf {leaf} len");
            for (g, c) in gather.iter().zip(&contig) {
                assert_eq!(g.to_bits(), c.to_bits(), "{label} leaf {leaf}");
            }
        }

        // naive ≡ tree: same neighbor set, bit-identical distances.
        let k = 6.min(space.n());
        let naive = knn::naive_knn(&space, &q, q_sq, k, None);
        let tr = knn::tree_knn(&space, &tree, &q, q_sq, k, None);
        assert_eq!(naive.len(), tr.len(), "{label} knn len");
        for (a, b) in naive.iter().zip(&tr) {
            assert_eq!(a.id, b.id, "{label} knn id");
            assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "{label} knn dist");
        }
    }
}

// ---------------------------------------------------------------------
// Level 3: the f32 tier is a pure evaluation-strategy knob.
// ---------------------------------------------------------------------

struct SuiteOut {
    knn_vec: Vec<knn::Neighbor>,
    knn_point: Vec<knn::Neighbor>,
    stats: ballquery::BallStats,
    moments: ballquery::BallMoments,
    anomaly_flags: Vec<bool>,
    f64_evals: u64,
    f32_evals: u64,
}

/// A radius that puts real points on both sides of the decision
/// boundary (so the filter both prunes and passes).
fn mid_radius(space: &Space, q: &[f32], q_sq: f64) -> f64 {
    let mut ds: Vec<f64> =
        (0..space.n()).map(|p| space.dist_to_vec_uncounted(p, q, q_sq)).collect();
    ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ds[space.n() / 3].max(1e-6)
}

fn run_suite(space: &Space, tree: &MetricTree, q: &[f32], q_sq: f64, radius: f64) -> SuiteOut {
    space.reset_count();
    let k = 6.min(space.n());
    let knn_vec = knn::tree_knn(space, tree, q, q_sq, k, None);
    let knn_point = knn::tree_knn_point(space, tree, 2.min(space.n() - 1), k);
    let stats = ballquery::tree_ball_stats(space, tree, q, radius);
    let moments = ballquery::tree_ball_moments(space, tree, q, radius);
    let params = anomaly::AnomalyParams { radius, threshold: 8 };
    let sweep = anomaly::tree_sweep(space, tree, &params);
    SuiteOut {
        knn_vec,
        knn_point,
        stats,
        moments,
        anomaly_flags: sweep.flags,
        f64_evals: space.dist_count(),
        f32_evals: space.f32_dist_count(),
    }
}

fn assert_answers_bit_identical(on: &SuiteOut, off: &SuiteOut, what: &str) {
    // Results must be bit-identical; the `dists` telemetry fields are
    // *expected* to differ (tier-on does fewer f64 evals), so answers
    // are compared field by field.
    assert_eq!(on.knn_vec.len(), off.knn_vec.len(), "{what}: knn len");
    for (a, b) in on.knn_vec.iter().zip(&off.knn_vec) {
        assert_eq!(a.id, b.id, "{what}: knn id");
        assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "{what}: knn dist");
    }
    for (a, b) in on.knn_point.iter().zip(&off.knn_point) {
        assert_eq!(a.id, b.id, "{what}: knn-point id");
        assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "{what}: knn-point dist");
    }
    assert_eq!(on.stats.count, off.stats.count, "{what}: ball count");
    assert_eq!(on.stats.mean, off.stats.mean, "{what}: ball mean");
    assert_eq!(
        on.stats.total_variance.to_bits(),
        off.stats.total_variance.to_bits(),
        "{what}: ball variance"
    );
    assert_eq!(on.moments.count, off.moments.count, "{what}: moments count");
    assert_eq!(on.moments.mean, off.moments.mean, "{what}: moments mean");
    assert_eq!(on.moments.variance, off.moments.variance, "{what}: moments variance");
    assert_eq!(on.anomaly_flags, off.anomaly_flags, "{what}: anomaly flags");
}

#[test]
fn f32_tier_answers_bit_identical_with_deterministic_eval_split() {
    for (space_off, label) in spaces() {
        // Identical bits, opposite tier flags.
        let mut space_on = Space::euclidean(space_off.data.clone());
        space_on.set_f32_tier(true);
        assert!(!space_off.f32_tier() && space_on.f32_tier());

        let (q, q_sq) = query(&space_off, 17);
        let radius = mid_radius(&space_off, &q, q_sq);

        let mut on_split_at: Option<(u64, u64)> = None;
        for threads in [1usize, 8] {
            // The tier never touches tree building: identical trees.
            let t_off = build(&space_off, 12, threads);
            let t_on = build(&space_on, 12, threads);
            assert_eq!(t_off.build_dists, t_on.build_dists, "{label} {threads}t: build dists");

            let off = run_suite(&space_off, &t_off, &q, q_sq, radius);
            assert_eq!(off.f32_evals, 0, "{label} {threads}t: tier-off f32 evals");

            let on = run_suite(&space_on, &t_on, &q, q_sq, radius);
            assert_answers_bit_identical(&on, &off, &format!("{label} {threads}t"));
            assert!(on.f32_evals > 0, "{label} {threads}t: filter never engaged");
            assert!(
                on.f64_evals < off.f64_evals,
                "{label} {threads}t: tier-on pruned nothing ({} vs {})",
                on.f64_evals,
                off.f64_evals
            );

            // The (f64, f32) split is deterministic: exact same pair on
            // a re-run, and at every thread count (the trees are
            // identical and the queries serial).
            let again = run_suite(&space_on, &t_on, &q, q_sq, radius);
            assert_answers_bit_identical(&again, &off, &format!("{label} {threads}t rerun"));
            assert_eq!(
                (again.f64_evals, again.f32_evals),
                (on.f64_evals, on.f32_evals),
                "{label} {threads}t: eval split drifted on re-run"
            );
            match on_split_at {
                None => on_split_at = Some((on.f64_evals, on.f32_evals)),
                Some(first) => assert_eq!(
                    first,
                    (on.f64_evals, on.f32_evals),
                    "{label}: eval split differs across thread counts"
                ),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Level 4: the engine knob.
// ---------------------------------------------------------------------

#[test]
fn engine_f32_tier_knob_is_exact_and_separately_accounted() {
    let spec = DatasetSpec::scaled(DatasetKind::Cell, 0.01);
    let workload = [
        Query::Knn(KnnQuery { target: KnnTarget::Point(3), k: 5, use_tree: true }),
        Query::Knn(KnnQuery { target: KnnTarget::Point(7), k: 4, use_tree: false }),
        Query::BallStats(BallStatsQuery {
            center: vec![0.25; DatasetKind::Cell.dims()],
            radius: 2.0,
            use_tree: true,
        }),
    ];
    let run = |tier: bool| {
        let index = IndexBuilder::new(spec.clone())
            .rmin(16)
            .with_f32_tier(tier)
            .build();
        assert_eq!(index.f32_tier(), tier, "builder knob did not reach the space");
        let results: Vec<_> = workload.iter().map(|query| index.run(query)).collect();
        (results, index.f32_dist_count())
    };
    let (off_results, off_f32) = run(false);
    let (on_results, on_f32) = run(true);
    assert_eq!(off_f32, 0, "tier-off index did f32 evals");
    assert!(on_f32 > 0, "tier-on index never used the filter");
    assert_eq!(off_results, on_results, "tier changed an engine answer");
}
