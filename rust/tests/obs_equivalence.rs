//! Observability acceptance suite: the [`QueryStats`] counters are part
//! of the determinism contract — a pure function of (dataset, query),
//! NOT of the execution schedule. Checked here:
//!
//! - every one of the eleven query families returns populated counters
//!   through `Index::run_traced`;
//! - the counters are bit-identical across thread counts {1, 8}, across
//!   coordinator shard counts {1, 4}, and across repeated runs;
//! - toggling the exact f32 filter tier changes *only* the
//!   `f32_reject` prune cell — every other counter is tier-invariant;
//! - the `obs::FAMILIES` table and `Query::kind` agree exactly;
//! - serving-edge snapshot merging ([`ObsSnapshot::merge`]) is
//!   order-invariant, on synthetic snapshots and on real shard output.

use anchors_hierarchy::algorithms::kde::Kernel;
use anchors_hierarchy::coordinator::{JobSpec, JobState, ObsSnapshot, ShardedCoordinator};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    AllPairsQuery, AnomalyQuery, BallQuery, BallStatsQuery, GaussianEmQuery, Index, IndexBuilder,
    KdeQuery, KernelRegressionQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query,
    XmeansQuery,
};
use anchors_hierarchy::obs::{self, Histogram, HistogramSnapshot, PruneRule, QueryStats};
use anchors_hierarchy::parallel::Parallelism;

fn index_with(threads: usize) -> Index {
    IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.002))
        .rmin(16)
        .parallelism(Parallelism::Fixed(threads))
        .build()
}

/// One query per family — all eleven `obs::FAMILIES` entries, tree
/// paths on (the instrumented traversals), 2-dim centers to match the
/// squiggles dataset.
fn all_families() -> Vec<Query> {
    let center = vec![0.0f32, 0.0];
    vec![
        Query::Kmeans(KmeansQuery { k: 3, iters: 3, use_tree: true, ..Default::default() }),
        Query::Xmeans(XmeansQuery { k_min: 1, k_max: 4 }),
        Query::Anomaly(AnomalyQuery { threshold: 5, use_tree: true, ..Default::default() }),
        Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
        Query::Ball(BallQuery { center: center.clone(), radius: 1.0, use_tree: true }),
        Query::BallStats(BallStatsQuery { center: center.clone(), radius: 1.0, use_tree: true }),
        Query::Kde(KdeQuery {
            center: center.clone(),
            kernel: Kernel::Gaussian,
            bandwidth: 1.0,
            eps_abs: 0.0,
            eps_rel: 0.01,
            use_tree: true,
        }),
        Query::KernelRegression(KernelRegressionQuery {
            center,
            target_dim: 1,
            kernel: Kernel::Gaussian,
            bandwidth: 1.0,
            eps_abs: 0.0,
            eps_rel: 0.01,
            use_tree: true,
        }),
        Query::GaussianEm(GaussianEmQuery { k: 2, steps: 2, use_tree: true, ..Default::default() }),
        Query::Knn(KnnQuery { target: KnnTarget::Point(3), k: 4, use_tree: true }),
        Query::Mst(MstQuery { use_tree: true }),
    ]
}

#[test]
fn families_table_matches_query_kinds() {
    let queries = all_families();
    assert_eq!(queries.len(), obs::FAMILIES.len(), "one query per family");
    for (i, q) in queries.iter().enumerate() {
        let fi = obs::family_index(q.kind())
            .unwrap_or_else(|| panic!("{} missing from obs::FAMILIES", q.kind()));
        assert_eq!(obs::FAMILIES[fi], q.kind());
        assert_eq!(fi, i, "all_families() lists families in table order");
    }
}

#[test]
fn every_family_returns_populated_stats() {
    let index = index_with(1);
    for q in all_families() {
        let (result, stats) = index.run_traced(&q);
        assert_eq!(result.kind(), q.kind());
        assert_ne!(stats, QueryStats::default(), "{}: empty QueryStats", q.kind());
        assert!(
            stats.nodes_visited > 0,
            "{}: tree query visited no nodes: {stats:?}",
            q.kind()
        );
        // Ball-type and budgeted queries may legitimately resolve every
        // node wholesale (no leaf scan); these families cannot.
        if matches!(q.kind(), "kmeans" | "xmeans" | "anomaly" | "em" | "knn" | "mst") {
            assert!(stats.leaf_rows > 0, "{}: no leaf rows scanned: {stats:?}", q.kind());
        }
    }
}

#[test]
fn stats_bit_identical_across_thread_counts() {
    let serial = index_with(1);
    let parallel = index_with(8);
    for q in all_families() {
        let (_, a) = serial.run_traced(&q);
        let (_, b) = parallel.run_traced(&q);
        assert_eq!(a, b, "{}: QueryStats diverged between 1 and 8 threads", q.kind());
    }
}

#[test]
fn stats_bit_identical_across_repeated_runs() {
    let index = index_with(4);
    for q in all_families() {
        let (_, a) = index.run_traced(&q);
        let (_, b) = index.run_traced(&q);
        assert_eq!(a, b, "{}: QueryStats diverged between repeated runs", q.kind());
    }
}

/// Zero the one cell the f32 tier is *allowed* to populate.
fn without_f32_cell(stats: &QueryStats) -> QueryStats {
    let mut s = stats.clone();
    s.pruned[PruneRule::F32Reject as usize] = 0;
    s
}

#[test]
fn f32_tier_changes_only_the_f32_reject_cell() {
    let build = |tier: bool| {
        IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.002))
            .rmin(16)
            .parallelism(Parallelism::Fixed(1))
            .with_f32_tier(tier)
            .build()
    };
    let off = build(false);
    let on = build(true);
    // The threshold-scan families wired to the tier in PR 8.
    let center = vec![0.0f32, 0.0];
    let queries = vec![
        Query::Ball(BallQuery { center: center.clone(), radius: 1.0, use_tree: true }),
        Query::BallStats(BallStatsQuery { center, radius: 1.0, use_tree: true }),
        Query::Knn(KnnQuery { target: KnnTarget::Point(3), k: 4, use_tree: true }),
        Query::Anomaly(AnomalyQuery { threshold: 5, use_tree: true, ..Default::default() }),
    ];
    let mut rejects = 0u64;
    for q in &queries {
        let (_, a) = off.run_traced(q);
        let (_, b) = on.run_traced(q);
        assert_eq!(
            a.pruned_by(PruneRule::F32Reject),
            0,
            "{}: tier-off run recorded f32 rejects",
            q.kind()
        );
        assert_eq!(
            without_f32_cell(&a),
            without_f32_cell(&b),
            "{}: tier toggle changed a counter other than f32_reject",
            q.kind()
        );
        rejects += b.pruned_by(PruneRule::F32Reject);
    }
    assert!(rejects > 0, "tier-on runs recorded no conclusive f32 rejects at all");
}

#[test]
fn stats_bit_identical_across_shard_counts() {
    let specs = || {
        vec![
            JobSpec {
                dataset: DatasetSpec::scaled(DatasetKind::Squiggles, 0.003),
                query: Query::Kmeans(KmeansQuery {
                    k: 3,
                    iters: 2,
                    use_tree: true,
                    ..Default::default()
                }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: DatasetSpec::scaled(DatasetKind::Voronoi, 0.002),
                query: Query::Knn(KnnQuery { target: KnnTarget::Point(0), k: 5, use_tree: true }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: DatasetSpec::scaled(DatasetKind::Cell, 0.005),
                query: Query::Mst(MstQuery { use_tree: true }),
                rmin: 16,
                deadline_ms: None,
            },
        ]
    };
    let run = |shards: usize| -> Vec<QueryStats> {
        let coord = ShardedCoordinator::new(shards, 2, 16);
        let ids: Vec<_> = specs().into_iter().map(|s| coord.submit(s).unwrap()).collect();
        ids.into_iter()
            .map(|id| match coord.wait(id) {
                JobState::Done(r) => r.stats,
                other => panic!("job {id} did not complete: {other:?}"),
            })
            .collect()
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(one, four, "per-job QueryStats diverged between 1 and 4 shards");
}

fn hist_of(vals: &[u64]) -> HistogramSnapshot {
    let h = Histogram::new();
    for &v in vals {
        h.record(v);
    }
    h.snapshot()
}

#[test]
fn obs_snapshot_merge_is_order_invariant() {
    let mk = |latencies: &[u64], visited: u64| ObsSnapshot {
        queue_wait: hist_of(latencies),
        build: hist_of(latencies),
        run: vec![hist_of(latencies)],
        e2e: vec![hist_of(latencies); 2],
        stats: vec![QueryStats { nodes_visited: visited, ..Default::default() }],
    };
    let a = mk(&[3, 50, 900], 7);
    let b = mk(&[1], 11);
    let c = mk(&[40_000, 40_001], 0);
    let abc = a.merge(&b).merge(&c);
    let cba = c.merge(&b).merge(&a);
    let bca = b.merge(&c.merge(&a));
    assert_eq!(abc, cba);
    assert_eq!(abc, bca);
    assert_eq!(abc.queue_wait.count, 6);
    // Unequal vector lengths pad with empties instead of truncating.
    assert_eq!(abc.e2e.len(), 2);
    assert_eq!(abc.stats[0].nodes_visited, 18);
    // Merging the identity changes nothing.
    assert_eq!(abc.merge(&ObsSnapshot::default()), abc);
}

#[test]
fn sharded_coordinator_obs_folds_order_invariantly() {
    let coord = ShardedCoordinator::new(4, 2, 16);
    let ids: Vec<_> = [
        (DatasetKind::Squiggles, 0.003),
        (DatasetKind::Voronoi, 0.002),
        (DatasetKind::Cell, 0.005),
    ]
    .into_iter()
    .map(|(kind, scale)| {
        coord
            .submit(JobSpec {
                dataset: DatasetSpec::scaled(kind, scale),
                query: Query::Knn(KnnQuery { target: KnnTarget::Point(0), k: 3, use_tree: true }),
                rmin: 16,
                deadline_ms: None,
            })
            .unwrap()
    })
    .collect();
    for id in ids {
        assert!(matches!(coord.wait(id), JobState::Done(_)));
    }
    let per_shard = coord.shard_obs();
    let forward = per_shard
        .iter()
        .fold(ObsSnapshot::default(), |acc, o| acc.merge(o));
    let reverse = per_shard
        .iter()
        .rev()
        .fold(ObsSnapshot::default(), |acc, o| acc.merge(o));
    assert_eq!(forward, reverse, "shard merge order changed the aggregate");
    assert_eq!(forward, coord.obs(), "ShardedCoordinator::obs is the shard fold");
    let knn = obs::family_index("knn").unwrap();
    assert_eq!(forward.run[knn].count, 3, "three knn jobs recorded");
    assert!(forward.stats[knn].nodes_visited > 0);
}
