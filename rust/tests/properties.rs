//! Property-based integration tests: randomized invariants over the whole
//! stack, using the in-tree property driver (`anchors_hierarchy::proptest`).
//!
//! Each property runs N random cases; failures print a replay seed.

use anchors_hierarchy::algorithms::{allpairs, anomaly, kmeans, knn};
use anchors_hierarchy::anchors::build_anchors;
use anchors_hierarchy::data::{Data, DenseMatrix, SparseMatrix};
use anchors_hierarchy::metrics::Space;
use anchors_hierarchy::prop_assert;
use anchors_hierarchy::proptest::check;
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::top_down;

/// Random dense space: mixture of a few clusters, random dims/sizes.
fn random_dense(rng: &mut Rng) -> Space {
    let n = 30 + rng.below(270);
    let d = 1 + rng.below(12);
    let k = 1 + rng.below(6);
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.uniform(-30.0, 30.0)).collect())
        .collect();
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let c = &centers[rng.below(k)];
            c.iter().map(|&v| (v + rng.normal() * 2.0) as f32).collect()
        })
        .collect();
    Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
}

/// Random sparse binary space.
fn random_sparse(rng: &mut Rng) -> Space {
    let n = 30 + rng.below(150);
    let d = 50 + rng.below(300);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            let nnz = 1 + rng.below(10);
            let mut idx = rng.sample_indices(d, nnz.min(d));
            idx.sort_unstable();
            idx.into_iter().map(|j| (j as u32, 1.0f32)).collect()
        })
        .collect();
    Space::euclidean(Data::Sparse(SparseMatrix::from_rows(d, &rows)))
}

fn random_space(rng: &mut Rng) -> Space {
    if rng.bool(0.25) {
        random_sparse(rng)
    } else {
        random_dense(rng)
    }
}

#[test]
fn prop_middle_out_tree_invariants() {
    check("middle-out tree invariants", 30, |rng| {
        let space = random_space(rng);
        let cfg = MiddleOutConfig {
            rmin: 2 + rng.below(40),
            seed: rng.next_u64(),
            exact_radii: rng.bool(0.3),
            ..Default::default()
        };
        let tree = middle_out::build(&space, &cfg);
        tree.validate(&space).map_err(|e| format!("{cfg:?}: {e}"))
    });
}

#[test]
fn prop_top_down_tree_invariants() {
    check("top-down tree invariants", 30, |rng| {
        let space = random_space(rng);
        let tree = top_down::build(&space, 2 + rng.below(40));
        tree.validate(&space)
    });
}

#[test]
fn prop_anchor_ownership_is_nearest() {
    check("anchors: every point owned by its nearest anchor", 25, |rng| {
        let space = random_space(rng);
        let points: Vec<u32> = (0..space.n() as u32).collect();
        let k = 2 + rng.below(12);
        let set = build_anchors(&space, &points, k, rng);
        for (ai, a) in set.anchors.iter().enumerate() {
            for &(_, p) in &a.owned {
                let own = space.dist_uncounted(p as usize, a.pivot as usize);
                for b in &set.anchors {
                    let other = space.dist_uncounted(p as usize, b.pivot as usize);
                    prop_assert!(
                        own <= other + 1e-9,
                        "point {p} (anchor {ai}): own {own} > other {other}"
                    );
                }
            }
        }
        // Partition check.
        let total: usize = set.anchors.iter().map(|a| a.len()).sum();
        prop_assert!(total == points.len(), "partition broken: {total}");
        Ok(())
    });
}

#[test]
fn prop_kmeans_tree_equals_naive() {
    check("kmeans: tree == naive (distortion and centroids)", 20, |rng| {
        let space = random_space(rng);
        let tree = middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 4 + rng.below(30),
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let k = 1 + rng.below(8);
        let iters = 1 + rng.below(6);
        let opts = kmeans::KmeansOpts { seed: rng.next_u64(), ..Default::default() };
        let a = kmeans::naive_lloyd(&space, kmeans::Init::Random, k, iters, &opts);
        let b = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, k, iters, &opts);
        prop_assert!(
            (a.distortion - b.distortion).abs() <= 1e-5 * (1.0 + a.distortion.abs()),
            "distortion {} vs {}",
            a.distortion,
            b.distortion
        );
        for (ca, cb) in a.centroids.iter().zip(&b.centroids) {
            for (x, y) in ca.iter().zip(cb) {
                prop_assert!((x - y).abs() < 1e-3, "centroid {x} vs {y}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_anomaly_tree_equals_naive() {
    check("anomaly: tree verdicts == naive verdicts", 20, |rng| {
        let space = random_space(rng);
        let tree = middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 4 + rng.below(30),
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let threshold = 1 + rng.below(20) as u64;
        // Radius spanning trivial to generous.
        let radius = rng.uniform(0.1, 30.0);
        let params = anomaly::AnomalyParams { radius, threshold };
        let a = anomaly::naive_sweep(&space, &params);
        let b = anomaly::tree_sweep(&space, &tree, &params);
        prop_assert!(
            a.flags == b.flags,
            "verdicts differ at r={radius} t={threshold}"
        );
        Ok(())
    });
}

#[test]
fn prop_allpairs_tree_equals_naive() {
    check("allpairs: tree pair set == naive pair set", 20, |rng| {
        let space = random_space(rng);
        let tree = middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 4 + rng.below(20),
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let tau = rng.uniform(0.05, 20.0);
        let a = allpairs::naive_close_pairs(&space, tau);
        let b = allpairs::tree_close_pairs(&space, &tree, tau);
        prop_assert!(
            a.pairs == b.pairs,
            "pair sets differ at tau={tau}: {} vs {}",
            a.pairs.len(),
            b.pairs.len()
        );
        Ok(())
    });
}

#[test]
fn prop_knn_tree_equals_naive() {
    check("knn: tree hits == naive hits", 20, |rng| {
        let space = random_dense(rng);
        let tree = middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 4 + rng.below(20),
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let k = 1 + rng.below(10);
        let d = space.dim();
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-30.0, 30.0) as f32).collect();
        let q_sq = q.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let a = knn::naive_knn(&space, &q, q_sq, k, None);
        let b = knn::tree_knn(&space, &tree, &q, q_sq, k, None);
        prop_assert!(a.len() == b.len(), "result sizes differ");
        for (x, y) in a.iter().zip(&b) {
            prop_assert!(
                (x.dist - y.dist).abs() < 1e-9,
                "knn dists differ: {} vs {}",
                x.dist,
                y.dist
            );
        }
        Ok(())
    });
}

#[test]
fn prop_triangle_inequality_on_generated_datasets() {
    // The entire edifice rests on the metric axioms — verify them on
    // samples from every generator family.
    check("metric axioms across dataset generators", 12, |rng| {
        use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
        let kinds = [
            DatasetKind::Squiggles,
            DatasetKind::Voronoi,
            DatasetKind::Cell,
            DatasetKind::Covtype,
            DatasetKind::Reuters { half: false },
            DatasetKind::Gen { dims: 100, components: 3 },
        ];
        let kind = kinds[rng.below(kinds.len())].clone();
        let space = DatasetSpec { kind, scale: 0.002, seed: rng.next_u64() }.build();
        for _ in 0..60 {
            let (i, j, k) = (
                rng.below(space.n()),
                rng.below(space.n()),
                rng.below(space.n()),
            );
            let (dij, djk, dik) = (
                space.dist_uncounted(i, j),
                space.dist_uncounted(j, k),
                space.dist_uncounted(i, k),
            );
            prop_assert!(
                dik <= dij + djk + 1e-6,
                "triangle violated: d({i},{k})={dik} > {dij}+{djk}"
            );
            prop_assert!(
                (dij - space.dist_uncounted(j, i)).abs() < 1e-9,
                "symmetry violated"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_distance_counter_consistency() {
    // Tree + naive runs must account distances without leaks: counter
    // deltas match the returned `dists` fields exactly.
    check("distance accounting is leak-free", 15, |rng| {
        let space = random_dense(rng);
        let tree = middle_out::build(
            &space,
            &MiddleOutConfig { rmin: 8, seed: rng.next_u64(), ..Default::default() },
        );
        let before = space.dist_count();
        let opts = kmeans::KmeansOpts { seed: rng.next_u64(), ..Default::default() };
        let r = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, 3, 3, &opts);
        let delta = space.dist_count() - before;
        prop_assert!(
            delta == r.dists,
            "counter delta {delta} != reported {}",
            r.dists
        );
        Ok(())
    });
}
