//! JSON wire-format integration tests: every [`Query`] / [`QueryResult`]
//! variant survives a serde round-trip through the crate's `json`
//! module — including *real* results produced by the dispatcher — and
//! the whole path is exercised end-to-end against the TCP
//! [`Server`] / [`Client`] pair.

use anchors_hierarchy::coordinator::server::{Client, Server};
use anchors_hierarchy::coordinator::{shard, ShardedCoordinator};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::algorithms::kde::Kernel;
use anchors_hierarchy::engine::{
    wire, AllPairsQuery, AnomalyQuery, BallQuery, BallStatsQuery, GaussianEmQuery, IndexBuilder,
    InitKind, KdeQuery, KernelRegressionQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query,
    QueryResult, XmeansQuery,
};
use anchors_hierarchy::json::{self, Value};
use std::sync::Arc;

fn every_query() -> Vec<Query> {
    vec![
        Query::Kmeans(KmeansQuery { k: 3, iters: 2, init: InitKind::Anchors, use_tree: true }),
        Query::Xmeans(XmeansQuery { k_min: 1, k_max: 4 }),
        Query::Anomaly(AnomalyQuery {
            threshold: 5,
            radius: Some(0.8),
            target_frac: 0.1,
            use_tree: false,
        }),
        Query::AllPairs(AllPairsQuery { tau: 0.4, use_tree: true }),
        Query::Ball(BallQuery { center: vec![0.0, 0.0], radius: 1.5, use_tree: true }),
        Query::GaussianEm(GaussianEmQuery {
            k: 2,
            steps: 2,
            tau: 0.0,
            init: InitKind::Random,
            use_tree: true,
        }),
        Query::Knn(KnnQuery { target: KnnTarget::Point(1), k: 3, use_tree: true }),
        Query::Mst(MstQuery { use_tree: true }),
        Query::BallStats(BallStatsQuery { center: vec![0.5, -0.25], radius: 2.0, use_tree: true }),
        Query::Kde(KdeQuery {
            center: vec![0.0, 0.5],
            kernel: Kernel::Gaussian,
            bandwidth: 1.5,
            eps_abs: 0.0,
            eps_rel: 0.02,
            use_tree: true,
        }),
        Query::KernelRegression(KernelRegressionQuery {
            center: vec![0.25, 0.0],
            target_dim: 1,
            kernel: Kernel::Epanechnikov,
            bandwidth: 2.0,
            eps_abs: 0.5,
            eps_rel: 0.0,
            use_tree: true,
        }),
    ]
}

#[test]
fn every_query_variant_roundtrips_through_json_text() {
    for q in every_query() {
        let text = json::write(&wire::query_to_json(&q));
        let back = wire::query_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(q, back, "query mangled by the wire: {text}");
    }
}

#[test]
fn every_real_result_roundtrips_through_json_text() {
    // Results produced by the actual dispatcher — not synthetic values —
    // must survive text serialization bit-for-bit (PartialEq on f64s).
    let index = IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.002))
        .rmin(16)
        .build();
    for q in every_query() {
        let result = index.run(&q);
        // The stats queries promise finite, NaN-free bound fields — the
        // wire format has no encoding for NaN, so this is load-bearing.
        match &result {
            QueryResult::Kde { sum, density, error_bound } => {
                assert!(sum.is_finite() && density.is_finite() && error_bound.is_finite());
            }
            QueryResult::KernelRegression {
                prediction,
                weight_sum,
                weighted_sum,
                weight_error_bound,
                value_error_bound,
            } => {
                assert!(
                    prediction.is_finite()
                        && weight_sum.is_finite()
                        && weighted_sum.is_finite()
                        && weight_error_bound.is_finite()
                        && value_error_bound.is_finite(),
                    "NaN/∞ leaked into a kreg result: {result:?}"
                );
            }
            QueryResult::BallStats { variance, total_variance, .. } => {
                assert!(total_variance.is_finite());
                assert!(variance.iter().all(|v| v.is_finite()));
            }
            _ => {}
        }
        let text = json::write(&wire::result_to_json(&result));
        let back = wire::result_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(result, back, "result mangled by the wire for {q:?}: {text}");
    }
}

// `PALLAS_SHARDS`-aware (1 shard by default): the CI `PALLAS_SHARDS=4`
// pass runs this whole wire suite against the sharded router.
fn start_server() -> (Server, Arc<ShardedCoordinator>) {
    let coord = Arc::new(ShardedCoordinator::new(shard::default_shards().unwrap(), 2, 32));
    let server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    (server, coord)
}

/// Submit every op over TCP, wait for it, and check the returned output
/// parses back into the QueryResult variant matching the submitted op.
#[test]
fn all_ops_execute_end_to_end_over_tcp() {
    let (server, _coord) = start_server();
    let mut client = Client::connect(server.addr()).unwrap();
    for query in every_query() {
        // The submit request is transport fields + the wire form of the
        // query, flattened into one object.
        let Value::Obj(query_fields) = wire::query_to_json(&query) else {
            panic!("query wire form must be an object");
        };
        let mut fields = vec![
            ("cmd", Value::Str("submit".into())),
            ("dataset", Value::Str("squiggles".into())),
            ("scale", Value::Num(0.002)),
            ("rmin", Value::Num(16.0)),
        ];
        let owned: Vec<(String, Value)> = query_fields.into_iter().collect();
        for (k, v) in &owned {
            fields.push((k.as_str(), v.clone()));
        }
        let resp = client.call(&Client::request(fields)).unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{query:?} → {resp:?}");
        let id = resp.get("id").unwrap().as_f64().unwrap();
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        assert_eq!(
            done.get("state").and_then(Value::as_str),
            Some("done"),
            "{query:?} → {done:?}"
        );
        let output = done.get("output").expect("done response carries output");
        let result = wire::result_from_json(output)
            .unwrap_or_else(|e| panic!("unparseable output for {query:?}: {e}"));
        assert_eq!(result.kind(), query.kind(), "op/result kind mismatch");
        assert!(done.get("dists").unwrap().as_f64().unwrap() >= 0.0);
    }
}

#[test]
fn server_rejects_malformed_queries_without_dropping_connection() {
    let (server, _coord) = start_server();
    let mut client = Client::connect(server.addr()).unwrap();
    for bad in [
        r#"{"cmd":"submit","dataset":"squiggles","op":"knn"}"#, // no point/vector
        r#"{"cmd":"submit","dataset":"squiggles","op":"ball"}"#, // no center
        r#"{"cmd":"submit","dataset":"squiggles","op":"warp"}"#, // unknown op
        r#"{"cmd":"submit","dataset":"squiggles","op":"kmeans","init":"best"}"#,
        r#"{"cmd":"submit","dataset":"squiggles","op":"kde"}"#, // no center
        r#"{"cmd":"submit","dataset":"squiggles","op":"kde","center":[0,0],"kernel":"box"}"#,
        r#"{"cmd":"submit","dataset":"squiggles","op":"ballstats"}"#, // no center
    ] {
        let resp = client.call(&json::parse(bad).unwrap()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{bad} → {resp:?}");
    }
    // Connection still alive and serving.
    let resp = client
        .call(&Client::request(vec![("cmd", Value::Str("ping".into()))]))
        .unwrap();
    assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
}
