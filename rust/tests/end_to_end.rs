//! Cross-layer integration tests: artifacts → PJRT runtime → algorithms,
//! plus CLI-level table generation smoke checks.
//!
//! Tests that need `artifacts/` skip gracefully when it is absent (CI
//! runs `make artifacts` first; `cargo test` alone still passes).

use anchors_hierarchy::algorithms::kmeans;
use anchors_hierarchy::bench::tables::{self, Table2Config};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::runtime::BatchDistanceEngine;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use std::sync::Arc;

fn engine() -> Option<Arc<BatchDistanceEngine>> {
    BatchDistanceEngine::open_default().ok().map(Arc::new)
}

#[test]
fn xla_kmeans_matches_scalar_kmeans() {
    let Some(engine) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // Dense dataset, moderate width (38 → padded to 64).
    let space = DatasetSpec::scaled(DatasetKind::Cell, 0.02).build();
    let tree = middle_out::build(&space, &MiddleOutConfig::default());
    for k in [3usize, 20] {
        let scalar_opts = kmeans::KmeansOpts { seed: 7, ..Default::default() };
        let xla_opts = kmeans::KmeansOpts {
            seed: 7,
            engine: Some(engine.clone()),
            ..Default::default()
        };
        let a = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, k, 5, &scalar_opts);
        let b = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, k, 5, &xla_opts);
        // f32 tiles vs f64 scalars: assignments identical in practice,
        // distortion agrees to f32 tolerance.
        assert!(
            (a.distortion - b.distortion).abs() <= 1e-3 * (1.0 + a.distortion),
            "k={k}: scalar {} vs xla {}",
            a.distortion,
            b.distortion
        );
        // Identical accounting: both paths count the same distances.
        assert_eq!(a.dists, b.dists, "k={k}: accounting diverged");
    }
}

#[test]
fn xla_naive_kmeans_matches_scalar() {
    let Some(engine) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let space = DatasetSpec::scaled(DatasetKind::Squiggles, 0.01).build();
    let scalar = kmeans::naive_lloyd(
        &space,
        kmeans::Init::Random,
        10,
        3,
        &kmeans::KmeansOpts { seed: 3, ..Default::default() },
    );
    let xla = kmeans::naive_lloyd(
        &space,
        kmeans::Init::Random,
        10,
        3,
        &kmeans::KmeansOpts { seed: 3, engine: Some(engine), ..Default::default() },
    );
    assert!(
        (scalar.distortion - xla.distortion).abs() <= 1e-3 * (1.0 + scalar.distortion),
        "{} vs {}",
        scalar.distortion,
        xla.distortion
    );
    assert_eq!(scalar.dists, xla.dists);
}

#[test]
fn table2_shape_reproduces_paper_qualitatively() {
    // The paper's central qualitative results at test scale:
    //   2-d structured data  → strong speedups,
    //   cell/covtype         → real speedups,
    //   reuters              → speedup ≤ ~1 (anti-speedup),
    //   reuters50 ≤ reuters100 (less data → worse for the tree).
    let cfg = Table2Config {
        scale: 0.01,
        kmeans_iters: 3,
        rmin: 25,
        seed: 20130,
        datasets: Some(vec![
            DatasetKind::Squiggles,
            DatasetKind::Cell,
            DatasetKind::Reuters { half: true },
            DatasetKind::Reuters { half: false },
        ]),
    };
    let rows = tables::table2(&cfg);
    let speedup = |ds: &str, op: &str| {
        rows.iter()
            .find(|r| r.dataset == ds && r.op == op)
            .map(|r| r.speedup())
            .unwrap()
    };
    assert!(speedup("squiggles", "k=3") > 3.0, "squiggles k=3 too slow");
    assert!(speedup("squiggles", "allpairs") > 5.0);
    assert!(speedup("cell", "k=20") > 1.2, "cell k=20: {}", speedup("cell", "k=20"));
    assert!(
        speedup("reuters100", "k=20") < 1.5,
        "reuters should not meaningfully accelerate"
    );
    // reuters50 no better than reuters100 for kmeans (paper: worse).
    assert!(
        speedup("reuters50", "k=20") <= speedup("reuters100", "k=20") * 1.3,
        "halving reuters should not improve the tree"
    );
}

#[test]
fn table3_anchors_tree_not_worse_than_topdown() {
    // Paper Table 3: factors 1.2–2.8 (anchors wins). At our test scale we
    // assert the weaker invariant: anchors-built trees are at par or
    // better on average.
    let rows = tables::table3(0.008, 3, 25, 20130);
    let avg: f64 =
        rows.iter().map(|r| r.factor()).sum::<f64>() / rows.len() as f64;
    assert!(
        avg > 0.9,
        "anchors tree much worse than top-down on average: {avg}"
    );
}

#[test]
fn table4_anchor_init_wins_on_clustered_data() {
    let rows = tables::table4(0.01, 20, 25, 20130);
    for r in rows.iter().filter(|r| r.dataset == "cell" || r.dataset == "squiggles") {
        assert!(
            r.start_benefit() > 1.0,
            "{} k={}: start benefit {}",
            r.dataset,
            r.k,
            r.start_benefit()
        );
    }
    // Reuters: anchors shouldn't be dramatically better (paper: ~1.0 end
    // benefit everywhere, start benefit < 2).
    for r in rows.iter().filter(|r| r.dataset == "reuters100") {
        assert!(
            r.end_benefit() < 1.5,
            "reuters end benefit suspiciously high: {}",
            r.end_benefit()
        );
    }
}

#[test]
fn figure1_first_split_separates_classes() {
    let r = tables::figure1(2000, 20130);
    let (a, b) = r.metric_first_split_purity;
    // The paper reports ~99% at R = 100k; at the 2k test size the split
    // is slightly noisier — require decisively-better-than-chance.
    assert!(a > 0.9 && b > 0.9, "metric split: {a:.3}/{b:.3}");
    // kd-tree: near-chance early, needing many levels.
    assert!(r.kd_purity_by_depth[1].1 < 0.8);
    if let Some(d) = r.kd_depth_to_match {
        assert!(d >= 3, "kd-tree matched too easily (depth {d})");
    }
}

#[test]
fn dataset_sizes_match_table1_at_full_scale() {
    // Spec-level check (no generation): Table 1 row counts & dims.
    use anchors_hierarchy::dataset::table2_datasets;
    for kind in table2_datasets() {
        let spec = DatasetSpec::new(kind.clone());
        match kind.name().as_str() {
            "squiggles" | "voronoi" => {
                assert_eq!(spec.rows(), 80_000);
                assert_eq!(kind.dims(), 2);
            }
            "cell" => {
                assert_eq!(spec.rows(), 39_972);
                assert_eq!(kind.dims(), 38);
            }
            "covtype" => {
                assert_eq!(spec.rows(), 150_000);
                assert_eq!(kind.dims(), 54);
            }
            "reuters100" => {
                assert_eq!(spec.rows(), 10_077);
                assert_eq!(kind.dims(), 4_732);
            }
            _ => {}
        }
    }
}
