//! Engine-facade integration tests: every [`Query`] variant dispatches
//! through [`Index::run`] to the matching [`QueryResult`] variant, and
//! [`Index::run_batch`] is bitwise-identical to sequential `run` calls
//! on the shared index.

use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    AllPairsQuery, AnomalyQuery, BallQuery, GaussianEmQuery, Index, IndexBuilder, KmeansQuery,
    KnnQuery, KnnTarget, MstQuery, Query, QueryResult, XmeansQuery,
};

fn tiny_index() -> Index {
    // ≈160 rows × 2 dims: every family finishes fast, including x-means.
    IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.002))
        .rmin(16)
        .build()
}

/// One query of every family, exercising both naive and tree paths.
fn all_families(use_tree: bool) -> Vec<Query> {
    vec![
        Query::Kmeans(KmeansQuery { k: 3, iters: 3, use_tree, ..Default::default() }),
        Query::Xmeans(XmeansQuery { k_min: 1, k_max: 4 }),
        Query::Anomaly(AnomalyQuery { threshold: 5, use_tree, ..Default::default() }),
        Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree }),
        Query::Ball(BallQuery { center: vec![0.0, 0.0], radius: 1.0, use_tree }),
        Query::GaussianEm(GaussianEmQuery { k: 2, steps: 2, use_tree, ..Default::default() }),
        Query::Knn(KnnQuery { target: KnnTarget::Point(3), k: 4, use_tree }),
        Query::Mst(MstQuery { use_tree }),
    ]
}

#[test]
fn every_query_variant_dispatches_to_matching_result() {
    for use_tree in [true, false] {
        let index = tiny_index();
        let queries = all_families(use_tree);
        assert_eq!(queries.len(), 8, "all eight algorithm families covered");
        for query in &queries {
            let result = index.run(query);
            assert_eq!(
                result.kind(),
                query.kind(),
                "query {query:?} produced a {} result",
                result.kind()
            );
        }
    }
}

#[test]
fn run_batch_is_bitwise_identical_to_sequential_runs() {
    let index = tiny_index();
    let queries = all_families(true);
    let batch = index.run_batch(&queries);
    let sequential: Vec<QueryResult> = queries.iter().map(|q| index.run(q)).collect();
    assert_eq!(batch.len(), sequential.len());
    for (q, (a, b)) in queries.iter().zip(batch.iter().zip(&sequential)) {
        assert_eq!(a, b, "batch vs sequential diverged for {q:?}");
    }
}

#[test]
fn naive_and_tree_kmeans_agree_through_the_facade() {
    let index = tiny_index();
    let naive = index.run(&Query::Kmeans(KmeansQuery {
        k: 4,
        iters: 5,
        use_tree: false,
        ..Default::default()
    }));
    let tree = index.run(&Query::Kmeans(KmeansQuery {
        k: 4,
        iters: 5,
        use_tree: true,
        ..Default::default()
    }));
    let (
        QueryResult::Kmeans { distortion: dn, .. },
        QueryResult::Kmeans { distortion: dt, .. },
    ) = (&naive, &tree)
    else {
        panic!("wrong result variants");
    };
    assert!((dn - dt).abs() <= 1e-6 * (1.0 + dn), "naive {dn} vs tree {dt}");
}

#[test]
fn naive_and_tree_agree_exactly_for_discrete_outputs() {
    let index = tiny_index();
    for (naive_q, tree_q) in [
        (
            Query::Anomaly(AnomalyQuery { threshold: 5, use_tree: false, ..Default::default() }),
            Query::Anomaly(AnomalyQuery { threshold: 5, use_tree: true, ..Default::default() }),
        ),
        (
            Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: false }),
            Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
        ),
    ] {
        let a = index.run(&naive_q);
        let b = index.run(&tree_q);
        assert_eq!(a, b, "naive vs tree diverged for {naive_q:?}");
    }
}

#[test]
fn naive_and_tree_knn_agree_on_distances() {
    // Ids can legitimately differ on exact distance ties at the
    // k-boundary (visit-order dependent), so compare like the knn
    // property tests do: element-wise distances.
    let index = tiny_index();
    let naive = index.run(&Query::Knn(KnnQuery {
        target: KnnTarget::Point(7),
        k: 5,
        use_tree: false,
    }));
    let tree = index.run(&Query::Knn(KnnQuery {
        target: KnnTarget::Point(7),
        k: 5,
        use_tree: true,
    }));
    let (QueryResult::Knn { neighbors: a }, QueryResult::Knn { neighbors: b }) = (&naive, &tree)
    else {
        panic!("wrong result variants");
    };
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert!((x.dist - y.dist).abs() < 1e-9, "knn dists differ: {} vs {}", x.dist, y.dist);
    }
}

#[test]
fn tree_path_saves_distances_on_the_shared_index() {
    let index = tiny_index();
    index.tree(); // pay the build up front so the comparison is pure query cost
    let naive_q = Query::Kmeans(KmeansQuery {
        k: 6,
        iters: 6,
        use_tree: false,
        ..Default::default()
    });
    let tree_q = Query::Kmeans(KmeansQuery { k: 6, iters: 6, use_tree: true, ..Default::default() });
    let before = index.dist_count();
    index.run(&naive_q);
    let naive_dists = index.dist_count() - before;
    let before = index.dist_count();
    index.run(&tree_q);
    let tree_dists = index.dist_count() - before;
    assert!(
        tree_dists < naive_dists,
        "tree {tree_dists} vs naive {naive_dists} distances"
    );
}

#[test]
fn knn_vector_target_sees_the_point_it_copies() {
    let index = tiny_index();
    let space = index.space();
    let mut row = vec![0f32; space.dim()];
    space.fill_row(5, &mut row);
    let by_vec = index.run(&Query::Knn(KnnQuery {
        target: KnnTarget::Vector(row),
        k: 4,
        use_tree: true,
    }));
    let QueryResult::Knn { neighbors } = by_vec else { panic!("wrong variant") };
    // The vector query sees point 5 itself at distance 0.
    assert_eq!(neighbors[0].id, 5);
    assert!(neighbors[0].dist <= 1e-6);
}
