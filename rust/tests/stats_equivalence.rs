//! Cached-statistics oracle suite: the second-moment decorations
//! (`Node::sum2`) and every query built on them — bounded-error KDE,
//! bounded-error kernel regression, exact ball moments — are checked
//! against naive O(n) oracles over (dense | sparse) × rmin {16, 64} ×
//! threads {1, 8}:
//!
//! - the decoration itself is bit-identical across thread counts;
//! - tree-pruned KDE / regression estimates land within the requested
//!   budget of the naive oracle AND within their own reported bounds;
//! - ball-moment counts equal brute force exactly (integer), moments
//!   match to float-association tolerance, and entire results —
//!   including exact distance counts — are bit-reproducible across
//!   repeated runs and across thread counts.

use anchors_hierarchy::algorithms::ballquery::{self, BallMoments};
use anchors_hierarchy::algorithms::kde::{self, ErrorBudget, Kernel};
use anchors_hierarchy::data::Data;
use anchors_hierarchy::dataset::{gaussian_mixture, gen_mixture};
use anchors_hierarchy::metrics::Space;
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::MetricTree;

const RMINS: [usize; 2] = [16, 64];
const THREADS: [usize; 2] = [1, 8];

fn spaces() -> Vec<(Space, &'static str)> {
    vec![
        (
            Space::euclidean(Data::Dense(gaussian_mixture(1500, 12, 5, 20.0, 99))),
            "dense",
        ),
        (Space::euclidean(Data::Sparse(gen_mixture(600, 100, 4, 99))), "sparse"),
    ]
}

fn build(space: &Space, rmin: usize, threads: usize) -> MetricTree {
    middle_out::build(
        space,
        &MiddleOutConfig {
            rmin,
            seed: 9,
            parallelism: Parallelism::Fixed(threads),
            ..Default::default()
        },
    )
}

/// Query points spanning the pruning regimes: dataset centroid (dense
/// neighborhood), a mild off-center shift, and a point outside the root
/// ball (everything prunes for compact kernels).
fn query_centers(space: &Space, tree: &MetricTree) -> Vec<Vec<f32>> {
    let all: Vec<u32> = (0..space.n() as u32).collect();
    let centroid = space.centroid(&all);
    let r = tree.node(tree.root).radius as f32;
    let mut shifted = centroid.clone();
    for v in shifted.iter_mut() {
        *v += 0.15 * r;
    }
    let mut outside = centroid.clone();
    outside[0] += 1.5 * r;
    vec![centroid, shifted, outside]
}

/// Bandwidths derived from the data scale (root radius), so the same
/// code exercises dense low-dim and sparse high-dim geometry.
fn bandwidths(tree: &MetricTree) -> [f64; 2] {
    let r = tree.node(tree.root).radius.max(1e-6);
    [r / 4.0, r]
}

const BUDGETS: [ErrorBudget; 4] = [
    ErrorBudget { eps_abs: 0.0, eps_rel: 0.0 },
    ErrorBudget { eps_abs: 0.5, eps_rel: 0.0 },
    ErrorBudget { eps_abs: 0.0, eps_rel: 0.02 },
    ErrorBudget { eps_abs: 2.0, eps_rel: 0.05 },
];

/// The decoration itself: per-node `sum2` is present, dimensioned, and
/// bit-identical across thread counts at every rmin, on dense and
/// sparse data (the tree-level determinism contract extends to the new
/// cached statistic).
#[test]
fn sum2_decoration_bit_identical_across_threads_and_rmin() {
    for (space, label) in spaces() {
        for &rmin in &RMINS {
            let reference = build(&space, rmin, THREADS[0]);
            reference.validate(&space).unwrap();
            for &threads in &THREADS[1..] {
                let tree = build(&space, rmin, threads);
                assert_eq!(
                    reference.nodes.len(),
                    tree.nodes.len(),
                    "{label} rmin {rmin}: node count, {threads} threads"
                );
                for (i, (na, nb)) in reference.nodes.iter().zip(&tree.nodes).enumerate() {
                    assert_eq!(
                        na.sum2.len(),
                        space.dim(),
                        "{label} rmin {rmin}: node {i} sum2 dimension"
                    );
                    assert_eq!(
                        na.sum2, nb.sum2,
                        "{label} rmin {rmin}: node {i} sum2, {threads} threads"
                    );
                }
            }
        }
    }
}

/// Tree-pruned KDE vs the naive oracle: for every (kernel, bandwidth,
/// budget) configuration the estimate is within the requested budget of
/// the exact sum, within its own reported error bound, and the bound
/// itself respects the budget.
#[test]
fn tree_kde_within_budget_of_naive_oracle() {
    for (space, label) in spaces() {
        for &rmin in &RMINS {
            let tree = build(&space, rmin, 1);
            for center in query_centers(&space, &tree) {
                for kernel in [Kernel::Gaussian, Kernel::Epanechnikov] {
                    for h in bandwidths(&tree) {
                        let exact = kde::naive_kde(&space, &center, kernel, h);
                        for budget in BUDGETS {
                            let fast = kde::tree_kde(&space, &tree, &center, kernel, h, budget);
                            let allowed =
                                budget.eps_abs + budget.eps_rel * exact.sum + 1e-9;
                            let err = (fast.sum - exact.sum).abs();
                            let what = format!(
                                "{label} rmin {rmin} {kernel:?} h {h:.3} \
                                 budget ({}, {})",
                                budget.eps_abs, budget.eps_rel
                            );
                            assert!(
                                err <= allowed,
                                "{what}: |{} - {}| = {err} > {allowed}",
                                fast.sum,
                                exact.sum
                            );
                            assert!(
                                err <= fast.error_bound + 1e-9 * (1.0 + exact.sum),
                                "{what}: error {err} exceeds reported bound {}",
                                fast.error_bound
                            );
                            assert!(
                                fast.error_bound <= allowed,
                                "{what}: reported bound {} exceeds budget {allowed}",
                                fast.error_bound
                            );
                            assert!(fast.error_bound.is_finite() && fast.density.is_finite());
                        }
                    }
                }
            }
        }
    }
}

/// Tree-pruned Nadaraya-Watson vs the naive oracle: the weight sum is
/// within the reported weight bound, and the prediction is within the
/// reported value bound whenever that bound is informative (it
/// saturates at `f64::MAX` when the weight interval touches zero).
#[test]
fn tree_kernel_regression_within_reported_bounds_of_naive_oracle() {
    for (space, label) in spaces() {
        for &rmin in &RMINS {
            let tree = build(&space, rmin, 1);
            let targets = [0usize, space.dim() - 1];
            for center in query_centers(&space, &tree) {
                for kernel in [Kernel::Gaussian, Kernel::Epanechnikov] {
                    for h in bandwidths(&tree) {
                        for &t in &targets {
                            let exact =
                                kde::naive_kernel_regression(&space, &center, t, kernel, h);
                            for budget in BUDGETS {
                                let fast = kde::tree_kernel_regression(
                                    &space, &tree, &center, t, kernel, h, budget,
                                );
                                let what = format!(
                                    "{label} rmin {rmin} {kernel:?} h {h:.3} target {t} \
                                     budget ({}, {})",
                                    budget.eps_abs, budget.eps_rel
                                );
                                let werr = (fast.weight_sum - exact.weight_sum).abs();
                                assert!(
                                    werr <= fast.weight_error_bound
                                        + 1e-9 * (1.0 + exact.weight_sum),
                                    "{what}: weight error {werr} exceeds bound {}",
                                    fast.weight_error_bound
                                );
                                assert!(
                                    !fast.prediction.is_nan()
                                        && !fast.value_error_bound.is_nan(),
                                    "{what}: NaN leaked into the result"
                                );
                                if fast.value_error_bound < f64::MAX {
                                    let verr = (fast.prediction - exact.prediction).abs();
                                    assert!(
                                        verr <= fast.value_error_bound
                                            + 1e-9 * (1.0 + exact.prediction.abs()),
                                        "{what}: value error {verr} exceeds bound {}",
                                        fast.value_error_bound
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Ball moments vs brute force: the count is *exactly* equal (it is an
/// integer — no float slack allowed), the mean and per-dimension
/// variance agree to float-association tolerance (the tree consumes
/// cached whole-node sums, so it sums in a different order than the
/// naive dataset-order scan — bit equality of the moments is
/// structurally impossible, and the count is the bit-exact part of the
/// contract), and the total variance equals the trace of the per-dim
/// variances.
#[test]
fn ball_moments_match_brute_force() {
    for (space, label) in spaces() {
        for &rmin in &RMINS {
            let tree = build(&space, rmin, 1);
            let root_r = tree.node(tree.root).radius;
            for center in query_centers(&space, &tree) {
                for frac in [0.1, 0.35, 1.1] {
                    let radius = root_r * frac;
                    let exact = ballquery::naive_ball_moments(&space, &center, radius);
                    let fast = ballquery::tree_ball_moments(&space, &tree, &center, radius);
                    let what = format!("{label} rmin {rmin} radius {radius:.3}");
                    assert_eq!(fast.count, exact.count, "{what}: count");
                    for j in 0..space.dim() {
                        let m = f64::from(exact.mean[j]);
                        assert!(
                            (f64::from(fast.mean[j]) - m).abs() <= 1e-4 * (1.0 + m.abs()),
                            "{what}: mean[{j}] {} vs {}",
                            fast.mean[j],
                            exact.mean[j]
                        );
                        assert!(
                            (fast.variance[j] - exact.variance[j]).abs()
                                <= 1e-3 * (1.0 + exact.variance[j]),
                            "{what}: variance[{j}] {} vs {}",
                            fast.variance[j],
                            exact.variance[j]
                        );
                    }
                    let trace: f64 = fast.variance.iter().sum();
                    assert!(
                        (fast.total_variance - trace).abs()
                            <= 1e-6 * (1.0 + trace.abs()),
                        "{what}: total variance {} vs trace {trace}",
                        fast.total_variance
                    );
                }
            }
        }
    }
}

/// Reproducibility: the full result structs — estimates, bounds, node
/// telemetry, AND exact distance counts — are `==` across repeated runs
/// and across trees built at different thread counts. Distance
/// accounting is part of the contract, not a diagnostic.
#[test]
fn stats_queries_bit_reproducible_across_runs_and_thread_counts() {
    for (space, label) in spaces() {
        for &rmin in &RMINS {
            let trees: Vec<MetricTree> =
                THREADS.iter().map(|&t| build(&space, rmin, t)).collect();
            let center = &query_centers(&space, &trees[0])[1];
            let h = bandwidths(&trees[0])[0];
            let budget = ErrorBudget { eps_abs: 0.0, eps_rel: 0.02 };
            let radius = trees[0].node(trees[0].root).radius * 0.35;

            let run = |tree: &MetricTree| {
                let kde_r = kde::tree_kde(&space, tree, center, Kernel::Gaussian, h, budget);
                let kreg_r = kde::tree_kernel_regression(
                    &space,
                    tree,
                    center,
                    0,
                    Kernel::Epanechnikov,
                    h,
                    budget,
                );
                let ball_r: BallMoments =
                    ballquery::tree_ball_moments(&space, tree, center, radius);
                (kde_r, kreg_r, ball_r)
            };

            let reference = run(&trees[0]);
            let again = run(&trees[0]);
            assert_eq!(reference, again, "{label} rmin {rmin}: repeated run drifted");
            for (tree, &threads) in trees.iter().zip(&THREADS).skip(1) {
                let other = run(tree);
                assert_eq!(
                    reference, other,
                    "{label} rmin {rmin}: results (incl. dist counts) differ on the \
                     {threads}-thread tree"
                );
            }
        }
    }
}
