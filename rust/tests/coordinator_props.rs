//! Property tests on the coordinator's routing / batching / state
//! invariants: no job lost, no job duplicated, backpressure holds, and
//! results are deterministic functions of the spec.
//!
//! The second half pins the sharded router's contract
//! ([`ShardedCoordinator`]): the shard count is a pure throughput knob
//! — results *and per-job distance counts* are identical at shards
//! {1, 2, 4} — and no job is lost or duplicated under concurrent
//! submit / wait / shutdown across shards.

use anchors_hierarchy::coordinator::{
    Coordinator, JobSpec, JobState, ShardedCoordinator, SubmitError,
};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::algorithms::kde::Kernel;
use anchors_hierarchy::engine::{
    AllPairsQuery, AnomalyQuery, BallStatsQuery, InitKind, KdeQuery, KernelRegressionQuery,
    KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query, QueryResult,
};
use anchors_hierarchy::prop_assert;
use anchors_hierarchy::proptest::check;
use anchors_hierarchy::rng::Rng;

fn random_spec(rng: &mut Rng) -> JobSpec {
    let kinds = [
        DatasetKind::Squiggles,
        DatasetKind::Voronoi,
        DatasetKind::Cell,
    ];
    let dataset = DatasetSpec {
        kind: kinds[rng.below(kinds.len())].clone(),
        scale: 0.002 + rng.f64() * 0.002,
        seed: 1 + rng.below(3) as u64, // few distinct datasets → cache hits
    };
    let use_tree = rng.bool(0.7);
    let query = match rng.below(5) {
        0 => Query::Kmeans(KmeansQuery {
            k: 2 + rng.below(6),
            iters: 1 + rng.below(3),
            init: if rng.bool(0.5) { InitKind::Anchors } else { InitKind::Random },
            use_tree,
        }),
        1 => Query::Anomaly(AnomalyQuery {
            threshold: 3 + rng.below(10) as u64,
            radius: None,
            target_frac: 0.1,
            use_tree,
        }),
        2 => Query::AllPairs(AllPairsQuery { tau: rng.uniform(0.2, 2.0), use_tree }),
        3 => Query::Knn(KnnQuery {
            target: KnnTarget::Point(rng.below(16) as u32),
            k: 1 + rng.below(8),
            use_tree,
        }),
        _ => Query::Mst(MstQuery { use_tree }),
    };
    JobSpec { dataset, query, rmin: 8 + rng.below(24), deadline_ms: None }
}

#[test]
fn prop_no_lost_or_duplicated_jobs() {
    check("coordinator: every accepted job terminal exactly once", 6, |rng| {
        let workers = 1 + rng.below(4);
        let coord = Coordinator::new(workers, 64);
        let n_jobs = 5 + rng.below(10);
        let mut ids = Vec::new();
        for _ in 0..n_jobs {
            match coord.submit(random_spec(rng)) {
                Ok(id) => ids.push(id),
                Err(e) => return Err(format!("submit failed below capacity: {e:?}")),
            }
        }
        // Ids are unique.
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert!(sorted.len() == ids.len(), "duplicate job ids");
        // Every job terminates, exactly one terminal state observed.
        for id in &ids {
            let state = coord.wait(*id);
            prop_assert!(state.is_terminal(), "wait returned non-terminal");
            if let JobState::Failed(e) = state {
                return Err(format!("job failed: {e}"));
            }
        }
        let m = coord.shutdown();
        prop_assert!(
            m.submitted == ids.len() as u64,
            "submitted {} != {}",
            m.submitted,
            ids.len()
        );
        prop_assert!(
            m.completed + m.failed == m.submitted,
            "terminal count mismatch: {} + {} != {}",
            m.completed,
            m.failed,
            m.submitted
        );
        Ok(())
    });
}

#[test]
fn prop_backpressure_cap_holds() {
    check("coordinator: queue never exceeds capacity", 5, |rng| {
        let capacity = 1 + rng.below(4);
        let coord = Coordinator::new(1, capacity);
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        for _ in 0..capacity * 6 {
            // Observable queue length must never exceed the cap.
            prop_assert!(
                coord.queue_len() <= capacity,
                "queue {} > cap {capacity}",
                coord.queue_len()
            );
            match coord.submit(random_spec(rng)) {
                Ok(_) => accepted += 1,
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => return Err(format!("{e:?}")),
            }
        }
        let m = coord.shutdown();
        prop_assert!(m.submitted == accepted, "metrics disagree on accepted");
        prop_assert!(m.rejected == rejected, "metrics disagree on rejected");
        Ok(())
    });
}

#[test]
fn prop_results_deterministic_in_spec() {
    check("coordinator: same spec → same result", 5, |rng| {
        let spec = random_spec(rng);
        let run = |spec: JobSpec| -> QueryResult {
            let coord = Coordinator::new(2, 8);
            let id = coord.submit(spec).unwrap();
            match coord.wait(id) {
                JobState::Done(r) => r.output,
                JobState::Failed(e) => panic!("job failed: {e}"),
                _ => unreachable!(),
            }
        };
        let a = run(spec.clone());
        let b = run(spec.clone());
        // Outputs are deterministic (same dataset seed, same algorithm
        // seed derivation) — distortions and counts must match exactly.
        prop_assert!(a == b, "nondeterministic result: {a:?} vs {b:?}");
        Ok(())
    });
}

/// The acceptance bar for the sharded router: for any mixed
/// multi-dataset job stream, shard counts 1, 2 and 4 produce identical
/// `QueryResult`s *and* exactly identical per-job distance counts.
///
/// One worker per shard keeps the accounting comparison exact: each
/// shard drains FIFO, and since all jobs for one `(dataset, rmin)` pair
/// route to one shard, the same job in the stream pays the one-time
/// dataset/tree build at every shard count.
#[test]
fn prop_shard_count_is_a_pure_throughput_knob() {
    check("sharded: results and per-job dists identical at 1/2/4 shards", 4, |rng| {
        let n_jobs = 6 + rng.below(6);
        let mut specs: Vec<JobSpec> = (0..n_jobs).map(|_| random_spec(rng)).collect();
        // Quantize scale and rmin so the stream *shares* (dataset, rmin)
        // pairs — the interesting case for per-job accounting: the job
        // that pays the one-time dataset/tree build must be the same
        // one at every shard count.
        for (i, s) in specs.iter_mut().enumerate() {
            s.dataset.scale = [0.002, 0.003][i % 2];
            s.rmin = [12, 24][(i / 2) % 2];
        }
        let run = |n_shards: usize| -> Result<Vec<(u64, QueryResult)>, String> {
            let coord = ShardedCoordinator::new(n_shards, 1, 64);
            let ids: Vec<_> = specs
                .iter()
                .map(|s| coord.submit(s.clone()))
                .collect::<Result<_, _>>()
                .map_err(|e| format!("submit failed below capacity: {e:?}"))?;
            let outcomes = ids
                .iter()
                .map(|id| match coord.wait(*id) {
                    JobState::Done(r) => Ok((r.dists, r.output)),
                    JobState::Failed(e) => Err(format!("job failed: {e}")),
                    _ => unreachable!("wait returned non-terminal"),
                })
                .collect::<Result<Vec<_>, _>>()?;
            coord.shutdown();
            Ok(outcomes)
        };
        let base = run(1)?;
        for n_shards in [2usize, 4] {
            let got = run(n_shards)?;
            for (i, (a, b)) in base.iter().zip(&got).enumerate() {
                prop_assert!(
                    a.1 == b.1,
                    "job {i}: result diverged at {n_shards} shards"
                );
                prop_assert!(
                    a.0 == b.0,
                    "job {i}: dists {} at 1 shard vs {} at {n_shards}",
                    a.0,
                    b.0
                );
            }
        }
        Ok(())
    });
}

/// The same bar for the cached-statistics queries: a mixed KDE /
/// kernel-regression / ball-stats stream over multiple datasets
/// produces identical results (estimates, error bounds, moments — f64
/// `==`, so bit-equal) and identical per-job distance counts at shard
/// counts 1, 2 and 4. Query centers are sized per dataset via
/// [`DatasetKind::dims`] so every job is well-formed.
#[test]
fn prop_stats_stream_identical_across_shard_counts() {
    check("sharded: kde/kreg/ballstats identical at 1/2/4 shards", 4, |rng| {
        let kinds = [DatasetKind::Squiggles, DatasetKind::Voronoi, DatasetKind::Cell];
        let n_jobs = 6 + rng.below(6);
        let specs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                let kind = kinds[rng.below(kinds.len())].clone();
                let dim = kind.dims();
                let center: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 2.0).collect();
                let use_tree = rng.bool(0.8);
                let kernel =
                    if rng.bool(0.5) { Kernel::Gaussian } else { Kernel::Epanechnikov };
                let query = match rng.below(3) {
                    0 => Query::Kde(KdeQuery {
                        center,
                        kernel,
                        bandwidth: rng.uniform(0.5, 4.0),
                        eps_abs: 0.0,
                        eps_rel: rng.uniform(0.0, 0.05),
                        use_tree,
                    }),
                    1 => Query::KernelRegression(KernelRegressionQuery {
                        center,
                        target_dim: rng.below(dim),
                        kernel,
                        bandwidth: rng.uniform(0.5, 4.0),
                        eps_abs: rng.uniform(0.0, 0.5),
                        eps_rel: 0.0,
                        use_tree,
                    }),
                    _ => Query::BallStats(BallStatsQuery {
                        center,
                        radius: rng.uniform(0.5, 5.0),
                        use_tree,
                    }),
                };
                JobSpec {
                    // Quantized scale/rmin, like the generic shard test:
                    // the stream must share (dataset, rmin) pairs so the
                    // one-time build lands on the same job at every
                    // shard count.
                    dataset: DatasetSpec { kind, scale: [0.002, 0.003][i % 2], seed: 1 },
                    query,
                    rmin: [12, 24][(i / 2) % 2],
                    deadline_ms: None,
                }
            })
            .collect();
        let run = |n_shards: usize| -> Result<Vec<(u64, QueryResult)>, String> {
            let coord = ShardedCoordinator::new(n_shards, 1, 64);
            let ids: Vec<_> = specs
                .iter()
                .map(|s| coord.submit(s.clone()))
                .collect::<Result<_, _>>()
                .map_err(|e| format!("submit failed below capacity: {e:?}"))?;
            let outcomes = ids
                .iter()
                .map(|id| match coord.wait(*id) {
                    JobState::Done(r) => Ok((r.dists, r.output)),
                    JobState::Failed(e) => Err(format!("job failed: {e}")),
                    _ => unreachable!("wait returned non-terminal"),
                })
                .collect::<Result<Vec<_>, _>>()?;
            coord.shutdown();
            Ok(outcomes)
        };
        let base = run(1)?;
        for n_shards in [2usize, 4] {
            let got = run(n_shards)?;
            for (i, (a, b)) in base.iter().zip(&got).enumerate() {
                prop_assert!(a.1 == b.1, "stats job {i}: result diverged at {n_shards} shards");
                prop_assert!(
                    a.0 == b.0,
                    "stats job {i}: dists {} at 1 shard vs {} at {n_shards}",
                    a.0,
                    b.0
                );
            }
        }
        Ok(())
    });
}

/// No job lost or duplicated when many threads submit and wait
/// concurrently against a sharded coordinator, racing its shutdown.
#[test]
fn prop_sharded_no_lost_or_duplicated_jobs_under_concurrency() {
    check("sharded: concurrent submit/wait/shutdown loses nothing", 4, |rng| {
        let n_shards = 1 + rng.below(4);
        let workers = 1 + rng.below(3);
        let coord = std::sync::Arc::new(ShardedCoordinator::new(n_shards, workers, 256));
        let n_threads = 2 + rng.below(3);
        let jobs_per_thread = 3 + rng.below(5);
        // Pre-generate specs on the test's RNG (the submitter threads
        // must not share it).
        let spec_sets: Vec<Vec<JobSpec>> = (0..n_threads)
            .map(|_| (0..jobs_per_thread).map(|_| random_spec(rng)).collect())
            .collect();
        let mut all_ids = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = spec_sets
                .into_iter()
                .map(|specs| {
                    let coord = std::sync::Arc::clone(&coord);
                    scope.spawn(move || {
                        let ids: Vec<_> = specs
                            .into_iter()
                            .map(|s| coord.submit(s).expect("below capacity"))
                            .collect();
                        // Wait for our own jobs from this thread, like a
                        // real client would.
                        for id in &ids {
                            assert!(coord.wait(*id).is_terminal());
                        }
                        ids
                    })
                })
                .collect();
            for h in handles {
                all_ids.extend(h.join().expect("submitter thread panicked"));
            }
        });
        let expected = all_ids.len() as u64;
        let mut sorted = all_ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert!(sorted.len() == all_ids.len(), "duplicate global job ids");
        let coord = std::sync::Arc::into_inner(coord).expect("all clones joined");
        let m = coord.shutdown();
        prop_assert!(m.submitted == expected, "submitted {} != {expected}", m.submitted);
        prop_assert!(
            m.completed + m.failed == m.submitted,
            "terminal count mismatch: {} + {} != {}",
            m.completed,
            m.failed,
            m.submitted
        );
        Ok(())
    });
}

/// Cancellation: a queued job moves to `Failed("cancelled")` exactly
/// once, running/terminal jobs are untouchable, and the metrics
/// invariant `completed + failed == submitted` survives cancels.
#[test]
fn sharded_cancel_semantics() {
    // One shard, one worker: the first (expensive) job holds the worker
    // while the rest sit in the queue.
    let coord = ShardedCoordinator::new(1, 1, 16);
    let mut rng = Rng::new(0xCA);
    let busy = coord.submit(random_spec(&mut rng)).unwrap();
    let queued: Vec<_> = (0..4)
        .map(|_| coord.submit(random_spec(&mut rng)).unwrap())
        .collect();
    let victim = queued[2];
    let cancelled = coord.cancel(victim);
    if cancelled {
        // Double-cancel may honestly answer true again while the job is
        // still live (the Failed promise covers both callers), but it
        // must not double-count — pinned by the metrics sum below.
        let _ = coord.cancel(victim);
        let JobState::Failed(e) = coord.wait(victim) else {
            panic!("cancelled job not failed");
        };
        assert_eq!(e, "cancelled");
    }
    // Unknown ids are not cancellable.
    assert!(!coord.cancel(0xDEAD_BEEF));
    for id in queued.iter().chain([&busy]) {
        assert!(coord.wait(*id).is_terminal());
    }
    // A terminal job is not cancellable.
    assert!(!coord.cancel(busy));
    let m = coord.shutdown();
    assert_eq!(m.submitted, 5);
    assert_eq!(m.completed + m.failed, m.submitted);
    // The victim was either still queued (cancelled) or already claimed
    // (cancelled_running) — exactly one of the two counters moved, and
    // exactly once even after the double-cancel above.
    assert_eq!(m.cancelled + m.cancelled_running, u64::from(cancelled));
    if cancelled {
        assert!(m.failed >= 1);
    }
}

#[test]
fn mixed_concurrent_load_completes() {
    // Stress: many jobs across datasets on several workers.
    let coord = Coordinator::new(4, 128);
    let mut rng = Rng::new(0xC0);
    let ids: Vec<_> = (0..40)
        .map(|_| coord.submit(random_spec(&mut rng)).unwrap())
        .collect();
    for id in ids {
        match coord.wait(id) {
            JobState::Done(_) => {}
            JobState::Failed(e) => panic!("job {id} failed: {e}"),
            _ => unreachable!(),
        }
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 40);
    assert_eq!(m.failed, 0);
}
