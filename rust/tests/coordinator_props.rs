//! Property tests on the coordinator's routing / batching / state
//! invariants: no job lost, no job duplicated, backpressure holds, and
//! results are deterministic functions of the spec.

use anchors_hierarchy::coordinator::{Coordinator, JobSpec, JobState, SubmitError};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    AllPairsQuery, AnomalyQuery, InitKind, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query,
    QueryResult,
};
use anchors_hierarchy::prop_assert;
use anchors_hierarchy::proptest::check;
use anchors_hierarchy::rng::Rng;

fn random_spec(rng: &mut Rng) -> JobSpec {
    let kinds = [
        DatasetKind::Squiggles,
        DatasetKind::Voronoi,
        DatasetKind::Cell,
    ];
    let dataset = DatasetSpec {
        kind: kinds[rng.below(kinds.len())].clone(),
        scale: 0.002 + rng.f64() * 0.002,
        seed: 1 + rng.below(3) as u64, // few distinct datasets → cache hits
    };
    let use_tree = rng.bool(0.7);
    let query = match rng.below(5) {
        0 => Query::Kmeans(KmeansQuery {
            k: 2 + rng.below(6),
            iters: 1 + rng.below(3),
            init: if rng.bool(0.5) { InitKind::Anchors } else { InitKind::Random },
            use_tree,
        }),
        1 => Query::Anomaly(AnomalyQuery {
            threshold: 3 + rng.below(10) as u64,
            radius: None,
            target_frac: 0.1,
            use_tree,
        }),
        2 => Query::AllPairs(AllPairsQuery { tau: rng.uniform(0.2, 2.0), use_tree }),
        3 => Query::Knn(KnnQuery {
            target: KnnTarget::Point(rng.below(16) as u32),
            k: 1 + rng.below(8),
            use_tree,
        }),
        _ => Query::Mst(MstQuery { use_tree }),
    };
    JobSpec { dataset, query, rmin: 8 + rng.below(24) }
}

#[test]
fn prop_no_lost_or_duplicated_jobs() {
    check("coordinator: every accepted job terminal exactly once", 6, |rng| {
        let workers = 1 + rng.below(4);
        let coord = Coordinator::new(workers, 64);
        let n_jobs = 5 + rng.below(10);
        let mut ids = Vec::new();
        for _ in 0..n_jobs {
            match coord.submit(random_spec(rng)) {
                Ok(id) => ids.push(id),
                Err(e) => return Err(format!("submit failed below capacity: {e:?}")),
            }
        }
        // Ids are unique.
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert!(sorted.len() == ids.len(), "duplicate job ids");
        // Every job terminates, exactly one terminal state observed.
        for id in &ids {
            let state = coord.wait(*id);
            prop_assert!(state.is_terminal(), "wait returned non-terminal");
            if let JobState::Failed(e) = state {
                return Err(format!("job failed: {e}"));
            }
        }
        let m = coord.shutdown();
        prop_assert!(
            m.submitted == ids.len() as u64,
            "submitted {} != {}",
            m.submitted,
            ids.len()
        );
        prop_assert!(
            m.completed + m.failed == m.submitted,
            "terminal count mismatch: {} + {} != {}",
            m.completed,
            m.failed,
            m.submitted
        );
        Ok(())
    });
}

#[test]
fn prop_backpressure_cap_holds() {
    check("coordinator: queue never exceeds capacity", 5, |rng| {
        let capacity = 1 + rng.below(4);
        let coord = Coordinator::new(1, capacity);
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        for _ in 0..capacity * 6 {
            // Observable queue length must never exceed the cap.
            prop_assert!(
                coord.queue_len() <= capacity,
                "queue {} > cap {capacity}",
                coord.queue_len()
            );
            match coord.submit(random_spec(rng)) {
                Ok(_) => accepted += 1,
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => return Err(format!("{e:?}")),
            }
        }
        let m = coord.shutdown();
        prop_assert!(m.submitted == accepted, "metrics disagree on accepted");
        prop_assert!(m.rejected == rejected, "metrics disagree on rejected");
        Ok(())
    });
}

#[test]
fn prop_results_deterministic_in_spec() {
    check("coordinator: same spec → same result", 5, |rng| {
        let spec = random_spec(rng);
        let run = |spec: JobSpec| -> QueryResult {
            let coord = Coordinator::new(2, 8);
            let id = coord.submit(spec).unwrap();
            match coord.wait(id) {
                JobState::Done(r) => r.output,
                JobState::Failed(e) => panic!("job failed: {e}"),
                _ => unreachable!(),
            }
        };
        let a = run(spec.clone());
        let b = run(spec.clone());
        // Outputs are deterministic (same dataset seed, same algorithm
        // seed derivation) — distortions and counts must match exactly.
        prop_assert!(a == b, "nondeterministic result: {a:?} vs {b:?}");
        Ok(())
    });
}

#[test]
fn mixed_concurrent_load_completes() {
    // Stress: many jobs across datasets on several workers.
    let coord = Coordinator::new(4, 128);
    let mut rng = Rng::new(0xC0);
    let ids: Vec<_> = (0..40)
        .map(|_| coord.submit(random_spec(&mut rng)).unwrap())
        .collect();
    for id in ids {
        match coord.wait(id) {
            JobState::Done(_) => {}
            JobState::Failed(e) => panic!("job {id} failed: {e}"),
            _ => unreachable!(),
        }
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 40);
    assert_eq!(m.failed, 0);
}
