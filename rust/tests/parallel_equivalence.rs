//! Serial ≡ parallel equivalence: the determinism contract of
//! `crate::parallel` holds end to end. For thread counts {1, 2, 8}, on
//! dense and sparse synthetic datasets, the parallel execution layer
//! must produce the **same tree shape (byte-identical nodes), the same
//! k-means centers (bit-equal), and the same exact distance counts** as
//! the serial schedule — parallelism is a wall-clock knob, never a
//! semantics knob.

use anchors_hierarchy::algorithms::{kmeans, xmeans};
use anchors_hierarchy::data::Data;
use anchors_hierarchy::dataset::{gaussian_mixture, gen_mixture, DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    BallQuery, BallStatsQuery, IndexBuilder, KdeQuery, KernelRegressionQuery, KmeansQuery,
    KnnQuery, KnnTarget, MstQuery, Query,
};
use anchors_hierarchy::metrics::Space;
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::{top_down, MetricTree};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn dense_space() -> Space {
    Space::euclidean(Data::Dense(gaussian_mixture(1800, 16, 6, 20.0, 42)))
}

fn sparse_space() -> Space {
    Space::euclidean(Data::Sparse(gen_mixture(700, 120, 4, 42)))
}

/// Byte-level equality of two trees: arena layout, ball geometry,
/// cached sufficient statistics, leaf row ranges and the tree-order
/// permutation.
fn assert_trees_identical(a: &MetricTree, b: &MetricTree, what: &str) {
    assert_eq!(a.root, b.root, "{what}: root id");
    assert_eq!(a.nodes.len(), b.nodes.len(), "{what}: node count");
    assert_eq!(a.build_dists, b.build_dists, "{what}: build distance count");
    for (i, (na, nb)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        assert_eq!(na.pivot, nb.pivot, "{what}: node {i} pivot");
        assert_eq!(
            na.radius.to_bits(),
            nb.radius.to_bits(),
            "{what}: node {i} radius"
        );
        assert_eq!(na.count, nb.count, "{what}: node {i} count");
        assert_eq!(na.sum, nb.sum, "{what}: node {i} cached sum");
        assert_eq!(
            na.sumsq.to_bits(),
            nb.sumsq.to_bits(),
            "{what}: node {i} cached sumsq"
        );
        assert_eq!(na.sum2, nb.sum2, "{what}: node {i} cached sum2");
        assert_eq!(na.children, nb.children, "{what}: node {i} children");
        assert_eq!(na.row_start, nb.row_start, "{what}: node {i} row range");
    }
    assert_eq!(a.layout.perm, b.layout.perm, "{what}: layout perm");
    assert_eq!(a.layout.inv, b.layout.inv, "{what}: layout inv");
}

#[test]
fn middle_out_tree_identical_across_thread_counts_dense() {
    let space = dense_space();
    let build = |threads: usize| {
        space.reset_count();
        middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 16,
                seed: 7,
                parallelism: Parallelism::Fixed(threads),
                ..Default::default()
            },
        )
    };
    let reference = build(1);
    reference.validate(&space).unwrap();
    for &threads in &THREAD_COUNTS[1..] {
        let tree = build(threads);
        assert_trees_identical(&reference, &tree, &format!("dense middle-out, {threads} threads"));
    }
}

#[test]
fn middle_out_tree_identical_across_thread_counts_sparse() {
    let space = sparse_space();
    let build = |threads: usize| {
        middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 12,
                seed: 3,
                parallelism: Parallelism::Fixed(threads),
                ..Default::default()
            },
        )
    };
    let reference = build(1);
    reference.validate(&space).unwrap();
    for &threads in &THREAD_COUNTS[1..] {
        let tree = build(threads);
        assert_trees_identical(&reference, &tree, &format!("sparse middle-out, {threads} threads"));
    }
}

#[test]
fn top_down_tree_identical_across_thread_counts() {
    let space = dense_space();
    let reference = top_down::build_par(&space, 16, Parallelism::Fixed(1));
    for &threads in &THREAD_COUNTS[1..] {
        let tree = top_down::build_par(&space, 16, Parallelism::Fixed(threads));
        assert_trees_identical(&reference, &tree, &format!("top-down, {threads} threads"));
    }
}

/// K-means: same centers (bit-equal), same distortion, same exact
/// distance counts — naive and tree paths, dense and sparse data.
#[test]
fn kmeans_centers_and_counts_identical_across_thread_counts() {
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let tree = middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 16,
                seed: 5,
                parallelism: Parallelism::Serial,
                ..Default::default()
            },
        );
        let run = |threads: usize| {
            let opts = kmeans::KmeansOpts {
                parallelism: Parallelism::Fixed(threads),
                ..Default::default()
            };
            let naive = kmeans::naive_lloyd(&space, kmeans::Init::Random, 6, 5, &opts);
            let tree_r = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, 6, 5, &opts);
            (naive, tree_r)
        };
        let (n_ref, t_ref) = run(1);
        for &threads in &THREAD_COUNTS[1..] {
            let (n, t) = run(threads);
            assert_eq!(n_ref.centroids, n.centroids, "{label} naive centers, {threads} threads");
            assert_eq!(
                n_ref.distortion.to_bits(),
                n.distortion.to_bits(),
                "{label} naive distortion, {threads} threads"
            );
            assert_eq!(n_ref.dists, n.dists, "{label} naive dist count, {threads} threads");
            assert_eq!(t_ref.centroids, t.centroids, "{label} tree centers, {threads} threads");
            assert_eq!(
                t_ref.distortion.to_bits(),
                t.distortion.to_bits(),
                "{label} tree distortion, {threads} threads"
            );
            assert_eq!(t_ref.dists, t.dists, "{label} tree dist count, {threads} threads");
        }
    }
}

#[test]
fn xmeans_identical_across_thread_counts() {
    let space = dense_space();
    let tree = middle_out::build(
        &space,
        &MiddleOutConfig {
            rmin: 16,
            seed: 11,
            parallelism: Parallelism::Serial,
            ..Default::default()
        },
    );
    let run = |threads: usize| {
        let opts = kmeans::KmeansOpts {
            parallelism: Parallelism::Fixed(threads),
            ..Default::default()
        };
        xmeans::xmeans(&space, &tree, 1, 10, &opts)
    };
    let reference = run(1);
    for &threads in &THREAD_COUNTS[1..] {
        let r = run(threads);
        assert_eq!(reference.k, r.k, "{threads} threads");
        assert_eq!(reference.centroids, r.centroids, "{threads} threads");
        assert_eq!(reference.bic.to_bits(), r.bic.to_bits(), "{threads} threads");
        assert_eq!(reference.dists, r.dists, "{threads} threads");
    }
}

/// `Engine::run_batch` dispatches across a worker pool; the results (and
/// the index's total distance count) must match the serial index exactly.
#[test]
fn run_batch_identical_across_thread_counts() {
    let workload: Vec<Query> = vec![
        Query::Kmeans(KmeansQuery { k: 4, iters: 3, ..Default::default() }),
        Query::Knn(KnnQuery { target: KnnTarget::Point(3), k: 5, ..Default::default() }),
        Query::Ball(BallQuery { center: vec![0.0; 2], radius: 2.0, use_tree: true }),
        Query::Mst(MstQuery { use_tree: true }),
        Query::Kmeans(KmeansQuery { k: 7, iters: 2, use_tree: false, ..Default::default() }),
        Query::Kde(KdeQuery { center: vec![0.5, -0.5], bandwidth: 1.5, ..Default::default() }),
        Query::KernelRegression(KernelRegressionQuery {
            center: vec![0.0; 2],
            target_dim: 1,
            bandwidth: 2.0,
            ..Default::default()
        }),
        Query::BallStats(BallStatsQuery { center: vec![0.0; 2], radius: 2.0, use_tree: true }),
    ];
    let run = |threads: usize| {
        let index = IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.004))
            .rmin(16)
            .parallelism(Parallelism::Fixed(threads))
            .build();
        let results = index.run_batch(&workload);
        (results, index.dist_count())
    };
    let (ref_results, ref_dists) = run(1);
    for &threads in &THREAD_COUNTS[1..] {
        let (results, dists) = run(threads);
        assert_eq!(ref_results, results, "{threads} threads");
        assert_eq!(ref_dists, dists, "total distance count, {threads} threads");
    }
}

/// Pool reuse: one `Executor` (and its persistent worker pool) shared
/// across repeated builds and query batches keeps every distance count
/// exact — the pool amortizes thread spawn, never accounting.
#[test]
fn pool_reuse_keeps_counts_exact_across_repeated_builds() {
    use anchors_hierarchy::parallel::Executor;
    let space = dense_space();
    let cfg = MiddleOutConfig {
        rmin: 16,
        seed: 7,
        parallelism: Parallelism::Fixed(4),
        ..Default::default()
    };
    // Fresh-executor reference.
    let reference = middle_out::build(&space, &cfg);
    // One executor, three consecutive builds: identical trees and
    // identical per-build distance counts every time.
    let exec = Executor::new(Parallelism::Fixed(4));
    for round in 0..3 {
        let tree = middle_out::build_ex(&space, &cfg, &exec);
        assert_trees_identical(&reference, &tree, &format!("pool-reuse build {round}"));
        assert_eq!(
            tree.build_dists, reference.build_dists,
            "pool-reuse build {round} distance count"
        );
    }
    assert!(exec.pool_started(), "parallel build never touched the pool");
}

#[test]
fn pool_reuse_keeps_counts_exact_across_repeated_batches() {
    let workload: Vec<Query> = vec![
        Query::Knn(KnnQuery { target: KnnTarget::Point(1), k: 4, ..Default::default() }),
        Query::Kmeans(KmeansQuery { k: 3, iters: 2, ..Default::default() }),
        Query::Ball(BallQuery { center: vec![0.0; 2], radius: 1.5, use_tree: true }),
        Query::Kmeans(KmeansQuery { k: 5, iters: 2, use_tree: false, ..Default::default() }),
    ];
    let index = IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.004))
        .rmin(16)
        .parallelism(Parallelism::Fixed(4))
        .build();
    index.tree(); // pay the build outside the measured deltas
    let before = index.dist_count();
    let first = index.run_batch(&workload);
    let first_delta = index.dist_count() - before;
    // Same index, same pool, three more rounds: bit-equal results and
    // the exact same distance delta each round.
    for round in 0..3 {
        let before = index.dist_count();
        let again = index.run_batch(&workload);
        assert_eq!(first, again, "batch results drifted on round {round}");
        assert_eq!(
            index.dist_count() - before,
            first_delta,
            "batch distance delta drifted on round {round}"
        );
    }
}

/// Kernel equivalence: the blocked leaf-scan kernels of
/// `metrics::block` return bit-identical distances and consume exactly
/// the same distance count as the scalar path, on dense and sparse data.
#[test]
fn blocked_kernels_bit_identical_to_scalar_dense_and_sparse() {
    use anchors_hierarchy::metrics::{block, dense_dot};
    for (space, label) in [(dense_space(), "dense"), (sparse_space(), "sparse")] {
        let d = space.dim();
        let q: Vec<f32> = (0..d).map(|j| ((j * 7 % 13) as f32) * 0.25 - 1.0).collect();
        let q_sq: f64 = q.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let rows: Vec<u32> = (0..space.n() as u32).step_by(3).collect();

        // Single-query shape (knn / ball / anomaly leaf scans).
        space.reset_count();
        let mut blocked = Vec::new();
        block::dists_to_vec(&space, &rows, &q, q_sq, &mut blocked);
        let blocked_count = space.dist_count();
        space.reset_count();
        for (i, &p) in rows.iter().enumerate() {
            let s = space.dist_to_vec(p as usize, &q, q_sq);
            assert_eq!(blocked[i].to_bits(), s.to_bits(), "{label} dists_to_vec row {p}");
        }
        assert_eq!(space.dist_count(), blocked_count, "{label} dists_to_vec count");

        // Multi-center shape (k-means leaf assignment / naive pass).
        let centroids: Vec<Vec<f32>> = (0..5)
            .map(|c| (0..d).map(|j| ((c + j) % 5) as f32 * 0.5 - 1.0).collect())
            .collect();
        let c_sq: Vec<f64> = centroids.iter().map(|c| dense_dot(c, c)).collect();
        let cand: Vec<u32> = vec![0, 1, 3, 4];
        space.reset_count();
        block::dists_to_centers(&space, &rows, &cand, &centroids, &c_sq, &mut blocked);
        let blocked_count = space.dist_count();
        space.reset_count();
        let mut at = 0usize;
        for &p in &rows {
            for &c in &cand {
                let s = space.dist_to_vec(p as usize, &centroids[c as usize], c_sq[c as usize]);
                assert_eq!(blocked[at].to_bits(), s.to_bits(), "{label} centers row {p}");
                at += 1;
            }
        }
        assert_eq!(space.dist_count(), blocked_count, "{label} dists_to_centers count");

        // Row-to-row shape (all-pairs leaf-leaf blocks).
        let a: Vec<u32> = (0..40).collect();
        let b: Vec<u32> = (50..90).collect();
        space.reset_count();
        block::dists_rows(&space, &a, &b, &mut blocked);
        let blocked_count = space.dist_count();
        space.reset_count();
        let mut at = 0usize;
        for &p in &a {
            for &qq in &b {
                let s = space.dist(p as usize, qq as usize);
                assert_eq!(blocked[at].to_bits(), s.to_bits(), "{label} rows ({p},{qq})");
                at += 1;
            }
        }
        assert_eq!(space.dist_count(), blocked_count, "{label} dists_rows count");
    }
}

/// The partitioned agglomeration only engages on wide frontiers
/// (√R ≥ 64 subtree roots, i.e. R ≥ ~4100 points at the top level);
/// build big enough to cross that threshold and assert the tree is
/// still byte-identical — including exact build distance counts — at
/// every thread count, with the persistent pool active.
#[test]
fn partitioned_agglomeration_identical_across_thread_counts() {
    let space = Space::euclidean(Data::Dense(gaussian_mixture(9000, 8, 12, 18.0, 13)));
    let build = |threads: usize| {
        middle_out::build(
            &space,
            &MiddleOutConfig {
                rmin: 30,
                seed: 21,
                parallelism: Parallelism::Fixed(threads),
                ..Default::default()
            },
        )
    };
    let reference = build(1);
    reference.validate(&space).unwrap();
    for &threads in &THREAD_COUNTS[1..] {
        let tree = build(threads);
        assert_trees_identical(
            &reference,
            &tree,
            &format!("partitioned agglomeration, {threads} threads"),
        );
    }
}
