//! Deterministic fault-injection drills over the serving stack.
//!
//! For each fault class, under (workers, shards) combinations spanning
//! {1, 8} × {1, 4}, these tests pin the robustness contract:
//!
//! * **accounting holds** — `completed + failed == submitted`, injected
//!   submit rejections are counted under `rejected`, and no job is lost
//!   or duplicated;
//! * **non-faulted jobs are unaffected** — their results equal a clean
//!   run's results, bit for bit;
//! * **drills replay** — the same [`FaultPlan`] against the same
//!   submission stream reproduces the same faults, fault for fault;
//! * **no residue** — a faults-off run on a fresh coordinator after a
//!   drill is bit-identical (results *and* per-job distance counts) to
//!   a never-faulted run.
//!
//! Fault plans are process-global, so every test here serializes on the
//! `ScopedFaults` lock — including clean baselines (via
//! [`ScopedFaults::none`]), which must not overlap another test's
//! drill. Switching plans *inside* one scope uses the raw
//! [`faults::install`] while the scope holds the lock.

use std::sync::Arc;
use std::time::Duration;

use anchors_hierarchy::coordinator::server::{Client, Server};
use anchors_hierarchy::coordinator::{
    CoordinatorConfig, FailureKind, JobSpec, JobState, ShardedCoordinator, SubmitError,
};
use anchors_hierarchy::data::Data;
use anchors_hierarchy::dataset::{gaussian_mixture, DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    AllPairsQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query, QueryResult,
};
use anchors_hierarchy::faults::{self, FaultPlan, ScopedFaults};
use anchors_hierarchy::json::Value;
use anchors_hierarchy::metrics::Space;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::serialize;

/// The full robustness matrix from the issue: worker counts {1, 8}
/// crossed with shard counts {1, 4}.
const MATRIX: [(usize, usize); 4] = [(1, 1), (8, 1), (1, 4), (8, 4)];

/// A small deterministic submission stream: three datasets, four query
/// families each, all tree-backed so the per-dataset build is cached.
fn stream() -> Vec<JobSpec> {
    let kinds = [DatasetKind::Squiggles, DatasetKind::Voronoi, DatasetKind::Cell];
    let mut jobs = Vec::new();
    for kind in kinds {
        let dataset = DatasetSpec::scaled(kind, 0.004);
        let queries = [
            Query::Kmeans(KmeansQuery { k: 3, iters: 2, use_tree: true, ..Default::default() }),
            Query::Knn(KnnQuery { target: KnnTarget::Point(3), k: 4, use_tree: true }),
            Query::Mst(MstQuery { use_tree: true }),
            Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
        ];
        for query in queries {
            jobs.push(JobSpec { dataset: dataset.clone(), query, rmin: 16, deadline_ms: None });
        }
    }
    jobs
}

/// Per-job terminal outcome, comparable across runs. `Err` carries the
/// failure's error string.
type Outcome = Result<(QueryResult, u64), String>;

fn run_stream(coord: &ShardedCoordinator, specs: &[JobSpec]) -> Vec<Outcome> {
    let ids: Vec<_> = specs
        .iter()
        .map(|s| coord.submit(s.clone()).expect("submit below capacity"))
        .collect();
    ids.iter()
        .map(|&id| match coord.wait(id) {
            JobState::Done(r) => Ok((r.output, r.dists)),
            JobState::Failed(f) => Err(f.error),
            other => panic!("wait returned non-terminal {other:?}"),
        })
        .collect()
}

/// Clean reference run for `specs` at this matrix point. Caller must
/// already hold the scope lock; faults are switched off for the run.
fn clean_baseline(workers: usize, shards: usize, specs: &[JobSpec]) -> Vec<Outcome> {
    faults::install(None);
    let coord = ShardedCoordinator::new(shards, workers, 64);
    let out = run_stream(&coord, specs);
    assert!(out.iter().all(Result::is_ok), "clean run must not fail");
    coord.shutdown();
    out
}

#[test]
fn panic_drill_accounts_every_job_and_spares_the_rest() {
    let _scope = ScopedFaults::none();
    let specs = stream();
    let plan = FaultPlan { seed: 7, panic_ppm: 350_000, ..Default::default() };
    let mut total_failed = 0u64;
    for (workers, shards) in MATRIX {
        let baseline = clean_baseline(workers, shards, &specs);
        let drill = || -> (Vec<Outcome>, u64) {
            faults::install(Some(plan.clone()));
            // Breaker off: the failure set must be exactly the decided
            // one, not shortened by a quarantine.
            let coord = ShardedCoordinator::with_config(
                shards,
                workers,
                64,
                None,
                CoordinatorConfig { breaker_k: 0, ..Default::default() },
            );
            let ids: Vec<_> =
                specs.iter().map(|s| coord.submit(s.clone()).expect("submit")).collect();
            let outcomes = ids
                .iter()
                .map(|&id| match coord.wait(id) {
                    JobState::Done(r) => Ok((r.output, r.dists)),
                    JobState::Failed(f) => {
                        assert_eq!(f.kind, FailureKind::Panic, "{}", f.error);
                        assert!(f.error.contains("injected fault"), "{}", f.error);
                        Err(f.error)
                    }
                    other => panic!("non-terminal {other:?}"),
                })
                .collect::<Vec<_>>();
            let m = coord.shutdown();
            assert_eq!(m.submitted, specs.len() as u64);
            assert_eq!(m.completed + m.failed, m.submitted, "job lost or duplicated");
            (outcomes, m.failed)
        };
        let (first, failed) = drill();
        total_failed += failed;
        // Non-faulted jobs produce exactly the clean results. (Distance
        // counts are excluded: a panicked first job shifts the one-time
        // tree-build attribution onto its successor by design.)
        for (i, (got, want)) in first.iter().zip(&baseline).enumerate() {
            if let Ok((out, _)) = got {
                let Ok((want_out, _)) = want else { unreachable!() };
                assert!(out == want_out, "job {i}: drilled result diverged from clean run");
            }
        }
        // Same plan, same stream → the same drill, fault for fault.
        let (second, _) = drill();
        for (i, (a, b)) in first.iter().zip(&second).enumerate() {
            match (a, b) {
                (Ok(x), Ok(y)) => assert!(x == y, "job {i}: replay diverged"),
                (Err(x), Err(y)) => assert_eq!(x, y, "job {i}: replay error diverged"),
                _ => panic!("job {i}: replay changed the failure set"),
            }
        }
    }
    assert!(total_failed > 0, "drill never injected a panic across the whole matrix");
}

#[test]
fn queue_full_drill_counts_rejections_and_replays() {
    let _scope = ScopedFaults::none();
    let specs = stream();
    let plan = FaultPlan { seed: 11, queue_full_ppm: 300_000, ..Default::default() };
    let mut total_rejected = 0u64;
    for (workers, shards) in MATRIX {
        let baseline = clean_baseline(workers, shards, &specs);
        // Capacity far above the stream length: every rejection below
        // is injected, none is a real queue-full.
        let mut drill = || -> Vec<bool> {
            faults::install(Some(plan.clone()));
            let coord = ShardedCoordinator::new(shards, workers, 64);
            let mut accepted = Vec::new();
            let mut pattern = Vec::new();
            for spec in &specs {
                match coord.submit(spec.clone()) {
                    Ok(id) => {
                        pattern.push(true);
                        accepted.push(Some(id));
                    }
                    Err(SubmitError::QueueFull) => {
                        pattern.push(false);
                        accepted.push(None);
                    }
                    Err(e) => panic!("unexpected submit error {e:?}"),
                }
            }
            for (i, id) in accepted.iter().enumerate() {
                let Some(id) = id else { continue };
                match coord.wait(*id) {
                    JobState::Done(r) => {
                        let Ok((want_out, _)) = &baseline[i] else { unreachable!() };
                        assert!(
                            &r.output == want_out,
                            "job {i}: accepted job diverged from clean run"
                        );
                    }
                    other => panic!("job {i}: accepted job ended {other:?}"),
                }
            }
            let n_ok = pattern.iter().filter(|&&b| b).count() as u64;
            let m = coord.shutdown();
            assert_eq!(m.submitted, n_ok);
            assert_eq!(m.rejected, specs.len() as u64 - n_ok);
            assert_eq!(m.completed, n_ok, "an accepted job was lost");
            assert_eq!(m.failed, 0);
            total_rejected += m.rejected;
            pattern
        };
        let first = drill();
        // install() resets the submit-attempt sequence, so the same
        // plan replays the same accept/reject pattern.
        let second = drill();
        assert_eq!(first, second, "rejection pattern did not replay");
    }
    assert!(total_rejected > 0, "drill never rejected a submit across the whole matrix");
}

#[test]
fn snapshot_truncation_fails_reads_loudly_then_recovers() {
    let _scope = ScopedFaults::none();
    let space = Space::euclidean(Data::Dense(gaussian_mixture(400, 6, 4, 12.0, 7)));
    let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, seed: 9, ..Default::default() });
    let mut buf = Vec::new();
    serialize::write_tree(&tree, &mut buf).unwrap();
    // The injected cut lands in the first 516 bytes; the snapshot must
    // extend past it for the truncation to be a real mid-file EOF.
    assert!(buf.len() > 600, "snapshot too small to truncate ({} bytes)", buf.len());

    faults::install(Some(FaultPlan { seed: 5, snap_trunc_ppm: 1_000_000, ..Default::default() }));
    for attempt in 0..3 {
        let err = serialize::read_tree(&mut buf.as_slice());
        assert!(err.is_err(), "attempt {attempt}: truncated read did not error");
    }

    // Clearing the plan restores clean reads of the very same bytes.
    faults::install(None);
    let mut back = serialize::read_tree(&mut buf.as_slice()).expect("clean read");
    back.attach_arena(&space);
    back.validate(&space).expect("round-tripped tree validates");
}

#[test]
fn socket_drop_drill_is_survived_by_client_retry() {
    let _scope = ScopedFaults::install(FaultPlan {
        seed: 3,
        sock_drop_ppm: 400_000,
        ..Default::default()
    });
    let coord = Arc::new(ShardedCoordinator::new(1, 2, 16));
    let server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let ping = Client::request(vec![("cmd", Value::Str("ping".into()))]);
    // The drill drops ~40% of accepted connections before any byte is
    // served; bounded retry with reconnect must ride through every one.
    let mut client = Client::connect(server.addr()).unwrap();
    for i in 0..5 {
        let resp = client.call_retry(&ping, 12).unwrap_or_else(|e| panic!("ping {i}: {e}"));
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "ping {i}");
    }
    // Faults off: a plain, no-retry call works first time.
    faults::install(None);
    let mut clean = Client::connect(server.addr()).unwrap();
    assert_eq!(clean.call(&ping).unwrap().get("pong"), Some(&Value::Bool(true)));
}

#[test]
fn post_drill_clean_run_is_bit_identical_to_never_faulted() {
    let _scope = ScopedFaults::none();
    let specs = stream();
    // Never-faulted reference, including exact per-job distance counts.
    let baseline = clean_baseline(2, 2, &specs);

    // A rough combined drill on a disposable coordinator: panics plus
    // injected queue-fulls. Only accounting is asserted here; the point
    // is what comes after.
    faults::install(Some(FaultPlan {
        seed: 13,
        panic_ppm: 300_000,
        queue_full_ppm: 200_000,
        ..Default::default()
    }));
    let coord = ShardedCoordinator::new(2, 2, 64);
    let ids: Vec<_> = specs.iter().filter_map(|s| coord.submit(s.clone()).ok()).collect();
    for id in &ids {
        assert!(coord.wait(*id).is_terminal());
    }
    let m = coord.shutdown();
    assert_eq!(m.completed + m.failed, m.submitted);

    // Faults off, fresh coordinator: results AND distance counts must
    // match the never-faulted run exactly — a drill leaves no residue.
    let after = clean_baseline(2, 2, &specs);
    for (i, (a, b)) in baseline.iter().zip(&after).enumerate() {
        let (Ok((out_a, dists_a)), Ok((out_b, dists_b))) = (a, b) else {
            panic!("job {i}: clean run failed");
        };
        assert!(out_a == out_b, "job {i}: post-drill result diverged");
        assert_eq!(dists_a, dists_b, "job {i}: post-drill distance count diverged");
    }
}

#[test]
fn wedged_job_is_reported_as_straggler_then_cancel_recovers_the_drain() {
    // Slow every traversal checkpoint: the MST below runs for far
    // longer than the first drain bound, wedging its shard on purpose.
    let _scope = ScopedFaults::install(FaultPlan {
        seed: 1,
        slow_leaf: Some(Duration::from_millis(5)),
        ..Default::default()
    });
    let coord = ShardedCoordinator::new(1, 1, 8);
    let id = coord
        .submit(JobSpec {
            dataset: DatasetSpec::scaled(DatasetKind::Cell, 0.004),
            query: Query::Mst(MstQuery { use_tree: true }),
            rmin: 16,
            deadline_ms: None,
        })
        .unwrap();
    // Wait until the job is actually on a worker.
    loop {
        match coord.state(id) {
            Some(JobState::Running) => break,
            Some(s) if s.is_terminal() => panic!("wedge job finished early: {s:?}"),
            _ => std::thread::sleep(Duration::from_millis(1)),
        }
    }
    let report = coord.drain(Duration::from_millis(100));
    assert!(!report.drained, "a wedged shard must not report a clean drain");
    assert_eq!(report.stragglers, vec![0], "the wedged shard is named");

    // Cancelling the wedged job unblocks the shard; a second drain
    // completes and the job lands in Failed("cancelled").
    assert!(coord.cancel(id), "running job must be cancellable");
    let report = coord.drain(Duration::from_secs(60));
    assert!(report.drained, "cancel did not unwedge the drain");
    let JobState::Failed(f) = coord.wait(id) else { panic!("cancelled job not failed") };
    assert_eq!(f.kind, FailureKind::Cancelled);
    assert_eq!(report.metrics.cancelled_running + report.metrics.cancelled, 1);
    // Intake stays closed after a drain.
    assert!(matches!(
        coord.submit(JobSpec {
            dataset: DatasetSpec::scaled(DatasetKind::Cell, 0.004),
            query: Query::Mst(MstQuery { use_tree: true }),
            rmin: 16,
            deadline_ms: None,
        }),
        Err(SubmitError::ShuttingDown)
    ));
}
