//! The parallel execution layer: a std-only scoped-thread executor with
//! **deterministic decomposition**.
//!
//! The paper makes individual queries cheap via the triangle inequality;
//! this module makes the *system* fast via threads — tree builds fan out
//! over anchor subtrees, assignment passes fan out over point chunks, and
//! [`crate::engine::Index::run_batch`] fans out over queries. Pestov's
//! lower bounds (PAPERS.md) say per-query pruning gains shrink as
//! dimension grows, which makes throughput parallelism the remaining
//! lever in high dimensions.
//!
//! ## The determinism contract
//!
//! Every consumer in this crate follows two rules that make results
//! **bit-reproducible under any thread count** (enforced by
//! `tests/parallel_equivalence.rs`):
//!
//! 1. **Fixed decomposition.** Work is split by *data* (fixed chunk
//!    sizes, anchor boundaries, a fixed tree frontier), never by thread
//!    count. The same work items exist whether 1 or 64 threads run them.
//! 2. **Ordered reduction.** Partial results (per-chunk sufficient
//!    statistics, per-subtree arenas, per-task accumulators) are merged
//!    in work-item order, so floating-point association is identical on
//!    every schedule.
//!
//! Under those rules the executor is free to schedule work items onto
//! threads in any order — scheduling affects wall-clock only, never
//! values. Distance *counts* stay exact as well: the sharded
//! [`crate::metrics::DistCounter`] is additive, and the decomposition
//! rules guarantee the same multiset of distance evaluations.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How much of the machine a build or query may use. The knob threads
/// through [`crate::engine::IndexBuilder`], [`crate::tree::middle_out::MiddleOutConfig`]
/// and [`crate::algorithms::kmeans::KmeansOpts`]; results are identical
/// for every setting (see the module docs), so it is purely a
/// wall-clock/resource control.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Single-threaded: all work runs on the calling thread.
    Serial,
    /// Exactly this many worker threads (clamped to at least 1).
    Fixed(usize),
    /// One worker per available hardware thread.
    Auto,
}

impl Parallelism {
    /// Worker-thread budget this setting resolves to.
    pub fn threads(self) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Fixed(n) => n.max(1),
            Parallelism::Auto => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }

    /// The `PALLAS_THREADS` environment override, if set to a valid
    /// thread count (`1` selects the serial path).
    pub fn from_env() -> Option<Parallelism> {
        let raw = std::env::var("PALLAS_THREADS").ok()?;
        match raw.trim().parse::<usize>() {
            Ok(0) => Some(Parallelism::Auto),
            Ok(1) => Some(Parallelism::Serial),
            Ok(n) => Some(Parallelism::Fixed(n)),
            Err(_) => None,
        }
    }

    /// Parse a CLI-style spec: `"serial"`, `"auto"`, or a thread count.
    pub fn parse(name: &str) -> Option<Parallelism> {
        match name {
            "serial" => Some(Parallelism::Serial),
            "auto" => Some(Parallelism::Auto),
            _ => match name.parse::<usize>() {
                Ok(0) => Some(Parallelism::Auto),
                Ok(1) => Some(Parallelism::Serial),
                Ok(n) => Some(Parallelism::Fixed(n)),
                Err(_) => None,
            },
        }
    }
}

impl Default for Parallelism {
    /// `PALLAS_THREADS` when set, otherwise [`Parallelism::Auto`].
    fn default() -> Self {
        Parallelism::from_env().unwrap_or(Parallelism::Auto)
    }
}

/// A scoped-thread work-chunk executor. Cheap to construct (it holds only
/// the resolved thread budget); threads are spawned per call via
/// [`std::thread::scope`], so borrowed data flows into tasks without
/// `Arc` plumbing.
#[derive(Clone, Copy, Debug)]
pub struct Executor {
    threads: usize,
}

impl Executor {
    pub fn new(parallelism: Parallelism) -> Executor {
        Executor { threads: parallelism.threads() }
    }

    /// An executor that runs everything on the calling thread.
    pub fn serial() -> Executor {
        Executor { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run tasks `0..n`, returning results **in task order**. Tasks are
    /// claimed from a shared atomic cursor, so long tasks don't stall
    /// short ones. The calling thread works alongside `threads - 1`
    /// spawned workers (keeping spawn overhead off the hot path for
    /// small fan-outs and the caller busy for large ones); a panicking
    /// task is propagated to the caller after all workers have stopped.
    pub fn map_tasks<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let drain = |out: &mut Vec<(usize, T)>| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            out.push((i, f(i)));
        };
        let buckets: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (1..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut out = Vec::new();
                        drain(&mut out);
                        out
                    })
                })
                .collect();
            let mut own = Vec::new();
            drain(&mut own);
            let mut all = vec![own];
            for h in handles {
                all.push(
                    h.join()
                        .unwrap_or_else(|panic| std::panic::resume_unwind(panic)),
                );
            }
            all
        });
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for bucket in buckets {
            for (i, v) in bucket {
                slots[i] = Some(v);
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("every task index produces exactly one result"))
            .collect()
    }

    /// Split `0..n` into fixed `chunk`-sized ranges and map each,
    /// returning results in chunk order. The chunk boundaries depend only
    /// on `n` and `chunk` — never on the thread count — which is rule 1
    /// of the determinism contract.
    pub fn map_chunks<T, F>(&self, n: usize, chunk: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        assert!(chunk > 0, "map_chunks with zero chunk size");
        let n_chunks = (n + chunk - 1) / chunk;
        self.map_tasks(n_chunks, |c| f(c * chunk..((c + 1) * chunk).min(n)))
    }
}

/// Run two closures, the second on a spawned thread when `threads > 1`
/// (rayon-`join` style, used by the top-down tree builder's two-way
/// recursion). Panics from either side propagate to the caller.
pub fn join<A, B, FA, FB>(threads: usize, fa: FA, fb: FB) -> (A, B)
where
    A: Send,
    B: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B + Send,
{
    if threads <= 1 {
        let a = fa();
        let b = fb();
        (a, b)
    } else {
        std::thread::scope(|s| {
            let hb = s.spawn(fb);
            let a = fa();
            let b = hb
                .join()
                .unwrap_or_else(|panic| std::panic::resume_unwind(panic));
            (a, b)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn threads_resolution() {
        assert_eq!(Parallelism::Serial.threads(), 1);
        assert_eq!(Parallelism::Fixed(4).threads(), 4);
        assert_eq!(Parallelism::Fixed(0).threads(), 1);
        assert!(Parallelism::Auto.threads() >= 1);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(Parallelism::parse("serial"), Some(Parallelism::Serial));
        assert_eq!(Parallelism::parse("auto"), Some(Parallelism::Auto));
        assert_eq!(Parallelism::parse("1"), Some(Parallelism::Serial));
        assert_eq!(Parallelism::parse("6"), Some(Parallelism::Fixed(6)));
        assert_eq!(Parallelism::parse("0"), Some(Parallelism::Auto));
        assert_eq!(Parallelism::parse("banana"), None);
    }

    #[test]
    fn map_tasks_preserves_order() {
        for threads in [1usize, 2, 8] {
            let exec = Executor::new(Parallelism::Fixed(threads));
            let out = exec.map_tasks(100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_tasks_empty_and_single() {
        let exec = Executor::new(Parallelism::Fixed(4));
        assert_eq!(exec.map_tasks(0, |i| i), Vec::<usize>::new());
        assert_eq!(exec.map_tasks(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn map_tasks_runs_each_exactly_once() {
        let exec = Executor::new(Parallelism::Fixed(8));
        let hits: Vec<AtomicU64> = (0..50).map(|_| AtomicU64::new(0)).collect();
        exec.map_tasks(50, |i| hits[i].fetch_add(1, Ordering::Relaxed));
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} run count");
        }
    }

    #[test]
    fn map_chunks_covers_range_exactly() {
        let exec = Executor::new(Parallelism::Fixed(3));
        for (n, chunk) in [(10usize, 3usize), (9, 3), (1, 5), (0, 4), (1000, 7)] {
            let ranges = exec.map_chunks(n, chunk, |r| r);
            let mut expect = 0;
            for r in &ranges {
                assert_eq!(r.start, expect, "n={n} chunk={chunk}");
                assert!(r.end - r.start <= chunk);
                expect = r.end;
            }
            assert_eq!(expect, n, "n={n} chunk={chunk}");
        }
    }

    #[test]
    fn chunk_boundaries_ignore_thread_count() {
        let a = Executor::new(Parallelism::Serial).map_chunks(103, 10, |r| r);
        let b = Executor::new(Parallelism::Fixed(8)).map_chunks(103, 10, |r| r);
        assert_eq!(a, b);
    }

    #[test]
    fn join_returns_both() {
        for threads in [1usize, 4] {
            let (a, b) = join(threads, || 2 + 2, || "ok");
            assert_eq!(a, 4);
            assert_eq!(b, "ok");
        }
    }

    #[test]
    fn map_tasks_propagates_panics() {
        let exec = Executor::new(Parallelism::Fixed(4));
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.map_tasks(16, |i| {
                if i == 9 {
                    panic!("boom from task 9");
                }
                i
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
            .unwrap_or("");
        assert!(msg.contains("boom"), "payload lost: {msg:?}");
    }
}
