//! The parallel execution layer: a std-only executor with **deterministic
//! decomposition** and a **persistent worker pool**.
//!
//! The paper makes individual queries cheap via the triangle inequality;
//! this module makes the *system* fast via threads — tree builds fan out
//! over anchor subtrees, assignment passes fan out over point chunks, and
//! [`crate::engine::Index::run_batch`] fans out over queries. Pestov's
//! lower bounds (PAPERS.md) say per-query pruning gains shrink as
//! dimension grows, which makes throughput parallelism the remaining
//! lever in high dimensions.
//!
//! ## The determinism contract
//!
//! Every consumer in this crate follows two rules that make results
//! **bit-reproducible under any thread count** (enforced by
//! `tests/parallel_equivalence.rs`):
//!
//! 1. **Fixed decomposition.** Work is split by *data* (fixed chunk
//!    sizes, anchor boundaries, a fixed tree frontier), never by thread
//!    count. The same work items exist whether 1 or 64 threads run them.
//! 2. **Ordered reduction.** Partial results (per-chunk sufficient
//!    statistics, per-subtree arenas, per-task accumulators) are merged
//!    in work-item order, so floating-point association is identical on
//!    every schedule.
//!
//! Under those rules the executor is free to schedule work items onto
//! threads in any order — scheduling affects wall-clock only, never
//! values. Distance *counts* stay exact as well: the sharded
//! [`crate::metrics::DistCounter`] is additive, and the decomposition
//! rules guarantee the same multiset of distance evaluations.
//!
//! ## The persistent pool
//!
//! An [`Executor`] with `threads > 1` owns a long-lived worker pool:
//! `threads - 1` parked OS threads plus the calling thread, woken per
//! call through a broadcast work channel (one epoch per `map_tasks` /
//! `map_chunks` / `join`). Hot loops that issue many small fan-outs —
//! per-anchor steal scans, per-iteration k-means frontiers, batch query
//! dispatch — therefore pay a condvar wake instead of a thread
//! spawn/join per pass (docs/EXPERIMENTS.md §Pool). The pool is created
//! lazily on the first parallel call, shared by `clone`d executors, and
//! torn down when the last clone drops. Tasks that are themselves
//! running *on* the pool fall back to scoped spawning for their own
//! nested fan-outs, so reentrancy can never deadlock the work channel.
//!
//! Executors deliberately stay *per consumer*: every coordinator worker
//! owns one, on every shard of a
//! [`crate::coordinator::ShardedCoordinator`] — a process-global pool
//! would re-introduce exactly the cross-job serialization point (one
//! broadcast channel) that the sharded coordinator exists to remove.

use std::any::Any;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// How much of the machine a build or query may use. The knob threads
/// through [`crate::engine::IndexBuilder`], [`crate::tree::middle_out::MiddleOutConfig`]
/// and [`crate::algorithms::kmeans::KmeansOpts`]; results are identical
/// for every setting (see the module docs), so it is purely a
/// wall-clock/resource control.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Single-threaded: all work runs on the calling thread.
    Serial,
    /// Exactly this many worker threads (clamped to at least 1).
    Fixed(usize),
    /// One worker per available hardware thread.
    Auto,
}

impl Parallelism {
    /// Worker-thread budget this setting resolves to.
    pub fn threads(self) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Fixed(n) => n.max(1),
            Parallelism::Auto => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }

    /// The `PALLAS_THREADS` environment override, if set to a valid
    /// spec — same grammar as [`Parallelism::parse`] (`serial`, `auto`,
    /// or a thread count; `1` selects the serial path).
    pub fn from_env() -> Option<Parallelism> {
        let raw = std::env::var("PALLAS_THREADS").ok()?;
        Parallelism::parse(raw.trim())
    }

    /// Parse a CLI-style spec: `"serial"`, `"auto"`, or a thread count.
    pub fn parse(name: &str) -> Option<Parallelism> {
        match name {
            "serial" => Some(Parallelism::Serial),
            "auto" => Some(Parallelism::Auto),
            _ => match name.parse::<usize>() {
                Ok(0) => Some(Parallelism::Auto),
                Ok(1) => Some(Parallelism::Serial),
                Ok(n) => Some(Parallelism::Fixed(n)),
                Err(_) => None,
            },
        }
    }
}

impl Default for Parallelism {
    /// `PALLAS_THREADS` when set, otherwise [`Parallelism::Auto`].
    fn default() -> Self {
        Parallelism::from_env().unwrap_or(Parallelism::Auto)
    }
}

thread_local! {
    /// Set while this thread is executing a pool job (worker threads and
    /// the broadcasting caller alike). Nested fan-outs issued from inside
    /// a pool task must not broadcast on a pool again — the channel is
    /// one-job-at-a-time — so they take the scoped-spawn path instead.
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Whether the current thread is executing inside a pool epoch. Used by
/// consumers to assert lock-ordering invariants — e.g. the engine's
/// lazy tree build must not be reached from inside an epoch, because a
/// task blocking on a long-held external lock keeps its epoch (and the
/// pool's broadcast channel) open, and the lock holder may need that
/// channel to make progress.
pub(crate) fn in_pool_task() -> bool {
    IN_POOL_TASK.with(|c| c.get())
}

/// RAII flag for [`IN_POOL_TASK`], exception-safe under unwinding.
struct PoolTaskGuard {
    prev: bool,
}

impl PoolTaskGuard {
    fn enter() -> PoolTaskGuard {
        let prev = IN_POOL_TASK.with(|c| c.replace(true));
        PoolTaskGuard { prev }
    }
}

impl Drop for PoolTaskGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL_TASK.with(|c| c.set(prev));
    }
}

/// A broadcast job: a type-erased pointer to the caller's drain closure.
/// The pointee lives on the broadcasting caller's stack; validity is
/// guaranteed because [`WorkerPool::run`] does not return until every
/// worker has finished the epoch (and the job slot is cleared before the
/// next epoch can start).
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn() + Sync),
}

// SAFETY: the pointer is only dereferenced by pool workers between job
// publication and epoch completion, a window during which the caller is
// blocked inside `WorkerPool::run` keeping the pointee alive. The
// pointee is `Sync`, so shared access from many threads is sound.
#[allow(unsafe_code)] // crate-wide deny; this is a sanctioned unsafe site
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped once per broadcast; workers join each epoch at most once.
    epoch: u64,
    job: Option<Job>,
    /// Pool workers this epoch wants (small fan-outs need few); workers
    /// beyond this skip the epoch without touching the job.
    expected: usize,
    /// Workers that have registered for the current epoch.
    joined: usize,
    /// Workers that have finished the current epoch.
    finished: usize,
    /// First panic payload observed this epoch (re-thrown by the caller).
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

/// Poison-tolerant state lock: the pool's state mutex protects plain
/// bookkeeping (no invariants that a panic could half-apply), so a
/// poisoned lock is recovered rather than cascading the panic into a
/// hung broadcast.
fn lock_state(m: &Mutex<PoolState>) -> std::sync::MutexGuard<'_, PoolState> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here waiting for the next epoch.
    work_cv: Condvar,
    /// The broadcasting caller parks here waiting for `finished == workers`.
    done_cv: Condvar,
    workers: usize,
}

/// The persistent pool: `workers` parked threads plus whichever thread is
/// currently broadcasting. One job runs at a time; concurrent broadcasts
/// from different threads serialize on `broadcast_lock`.
struct WorkerPool {
    shared: Arc<PoolShared>,
    broadcast_lock: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                expected: 0,
                joined: 0,
                finished: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            workers,
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("pallas-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, broadcast_lock: Mutex::new(()), handles }
    }

    /// Broadcast `job` to up to `wanted` parked workers, run `on_caller`
    /// on the calling thread, and block until every registered worker
    /// has finished the epoch. Small fan-outs wake only the workers they
    /// can feed instead of the whole pool (every worker that *checks*
    /// the epoch self-registers while slots remain, so lost
    /// `notify_one`s cannot strand the epoch — non-waiting workers
    /// always re-check before parking). Panics from any participant
    /// propagate to the caller after the epoch completes (so borrowed
    /// data stays alive throughout).
    #[allow(unsafe_code)] // crate-wide deny; lifetime-erasure site documented on `Job`
    fn run(&self, wanted: usize, on_caller: impl FnOnce(), job: &(dyn Fn() + Sync)) {
        let _serialize = self
            .broadcast_lock
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        // SAFETY: erase the borrow's lifetime; see `Job` for why the
        // pointee outlives every dereference.
        let job = Job { f: unsafe { std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(job) } };
        let expected = wanted.clamp(1, self.shared.workers);
        {
            let mut st = lock_state(&self.shared.state);
            debug_assert!(st.job.is_none(), "overlapping pool epochs");
            st.job = Some(job);
            st.epoch += 1;
            st.expected = expected;
            st.joined = 0;
            st.finished = 0;
            if expected == self.shared.workers {
                self.shared.work_cv.notify_all();
            } else {
                for _ in 0..expected {
                    self.shared.work_cv.notify_one();
                }
            }
        }
        let caller_panic = {
            let _guard = PoolTaskGuard::enter();
            catch_unwind(AssertUnwindSafe(on_caller)).err()
        };
        let mut st = lock_state(&self.shared.state);
        while st.finished < st.expected {
            st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        let worker_panic = st.panic.take();
        drop(st);
        if let Some(payload) = caller_panic.or(worker_panic) {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_state(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[allow(unsafe_code)] // crate-wide deny; job-pointer dereference documented on `Job`
fn worker_loop(shared: &PoolShared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_state(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    // Register only while the epoch has open slots and a
                    // live job; a full (or already-completed) epoch is
                    // skipped without touching the job pointer.
                    if st.joined < st.expected && st.job.is_some() {
                        st.joined += 1;
                        break st.job.expect("registered for a jobless epoch");
                    }
                    continue; // re-check: epoch == seen now, so we park
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let result = {
            let _guard = PoolTaskGuard::enter();
            // SAFETY: the broadcasting caller blocks until this worker
            // reports `finished`, keeping the pointee alive.
            catch_unwind(AssertUnwindSafe(|| unsafe { (*job.f)() }))
        };
        let mut st = lock_state(&shared.state);
        if let Err(payload) = result {
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.finished += 1;
        shared.done_cv.notify_all();
    }
}

struct ExecInner {
    threads: usize,
    /// Created on the first parallel call, then reused by every
    /// subsequent `map_tasks`/`map_chunks`/`join` on this executor (and
    /// its clones) until the last clone drops.
    pool: OnceLock<WorkerPool>,
}

/// A deterministic work-chunk executor backed by a persistent worker
/// pool. Cheap to construct (the pool is lazy) and cheap to `clone`
/// (clones share the pool); borrowed data flows into tasks without
/// `Arc` plumbing because the broadcasting caller blocks until the
/// epoch completes.
#[derive(Clone)]
pub struct Executor {
    inner: Arc<ExecInner>,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("threads", &self.inner.threads)
            .field("pool_started", &self.inner.pool.get().is_some())
            .finish()
    }
}

impl Executor {
    pub fn new(parallelism: Parallelism) -> Executor {
        Executor {
            inner: Arc::new(ExecInner {
                threads: parallelism.threads(),
                pool: OnceLock::new(),
            }),
        }
    }

    /// An executor that runs everything on the calling thread.
    pub fn serial() -> Executor {
        Executor::new(Parallelism::Serial)
    }

    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Whether the persistent pool has been spun up yet (it starts on
    /// the first parallel call).
    pub fn pool_started(&self) -> bool {
        self.inner.pool.get().is_some()
    }

    /// The pool, if this executor may use one *right now*: parallel
    /// budget, and not already inside a pool task (nested fan-outs take
    /// the scoped path — the work channel is one job at a time).
    fn usable_pool(&self) -> Option<&WorkerPool> {
        if self.inner.threads <= 1 || IN_POOL_TASK.with(|c| c.get()) {
            return None;
        }
        Some(
            self.inner
                .pool
                .get_or_init(|| WorkerPool::new(self.inner.threads - 1)),
        )
    }

    /// Run tasks `0..n`, returning results **in task order**. Tasks are
    /// claimed from a shared atomic cursor, so long tasks don't stall
    /// short ones. The calling thread works alongside the pool's
    /// `threads - 1` persistent workers — repeated calls reuse the same
    /// parked threads instead of spawning — and a panicking task is
    /// propagated to the caller after all workers have stopped.
    pub fn map_tasks<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let workers = self.inner.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let buckets: Mutex<Vec<Vec<(usize, T)>>> = Mutex::new(Vec::new());
        let drain = || {
            let mut out: Vec<(usize, T)> = Vec::new();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                out.push((i, f(i)));
            }
            if !out.is_empty() {
                buckets.lock().unwrap_or_else(|e| e.into_inner()).push(out);
            }
        };
        match self.usable_pool() {
            // The caller drains too, so `workers - 1` pool threads cover
            // the fan-out; waking more would find an empty cursor.
            Some(pool) => pool.run(workers - 1, &drain, &drain),
            None => scoped_fanout(workers, &drain),
        }
        let buckets = buckets.into_inner().unwrap_or_else(|e| e.into_inner());
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for bucket in buckets {
            for (i, v) in bucket {
                slots[i] = Some(v);
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("every task index produces exactly one result"))
            .collect()
    }

    /// Split `0..n` into fixed `chunk`-sized ranges and map each,
    /// returning results in chunk order. The chunk boundaries depend only
    /// on `n` and `chunk` — never on the thread count — which is rule 1
    /// of the determinism contract.
    pub fn map_chunks<T, F>(&self, n: usize, chunk: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        assert!(chunk > 0, "map_chunks with zero chunk size");
        let n_chunks = n.div_ceil(chunk);
        self.map_tasks(n_chunks, |c| f(c * chunk..((c + 1) * chunk).min(n)))
    }

    /// Run two closures, the second on a pool worker when one is
    /// available (rayon-`join` style, used by the top-down tree
    /// builder's two-way recursion). Nested joins — issued from inside a
    /// pool task — fall back to a scoped spawn. Panics from either side
    /// propagate to the caller.
    pub fn join<A, B, FA, FB>(&self, fa: FA, fb: FB) -> (A, B)
    where
        A: Send,
        B: Send,
        FA: FnOnce() -> A + Send,
        FB: FnOnce() -> B + Send,
    {
        match self.usable_pool() {
            None => join(self.inner.threads, fa, fb),
            Some(pool) => {
                let fb_slot: Mutex<Option<FB>> = Mutex::new(Some(fb));
                let b_out: Mutex<Option<B>> = Mutex::new(None);
                let mut a_out: Option<A> = None;
                pool.run(
                    1, // one side runs on one worker; fa stays on the caller
                    || a_out = Some(fa()),
                    &|| {
                        let fb = fb_slot.lock().unwrap_or_else(|e| e.into_inner()).take();
                        if let Some(fb) = fb {
                            let b = fb();
                            *b_out.lock().unwrap_or_else(|e| e.into_inner()) = Some(b);
                        }
                    },
                );
                (
                    a_out.expect("join caller side ran"),
                    b_out
                        .into_inner()
                        .unwrap_or_else(|e| e.into_inner())
                        .expect("join pool side ran"),
                )
            }
        }
    }
}

/// Scoped-thread fan-out: every participant runs the same drain closure.
/// Used when no pool is available (serial executors never get here) or
/// when the caller is itself a pool task (nested fan-out). Spawned
/// threads inherit the caller's pool-task flag: a nested fan-out's
/// helper threads are still "inside" the enclosing pool epoch, and
/// letting them broadcast on the pool would deadlock against the
/// epoch's own broadcast lock.
fn scoped_fanout(workers: usize, drain: &(dyn Fn() + Sync)) {
    let inherit = IN_POOL_TASK.with(|c| c.get());
    std::thread::scope(|s| {
        let handles: Vec<_> = (1..workers)
            .map(|_| {
                s.spawn(move || {
                    IN_POOL_TASK.with(|c| c.set(inherit));
                    drain();
                })
            })
            .collect();
        let own = catch_unwind(AssertUnwindSafe(drain)).err();
        let mut first_panic = own;
        for h in handles {
            if let Err(payload) = h.join() {
                first_panic.get_or_insert(payload);
            }
        }
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
    });
}

/// Run two closures, the second on a spawned thread when `threads > 1`.
/// The scoped-spawn primitive behind [`Executor::join`]'s nested-context
/// fallback (the top-down builder's recursion lands here below the top
/// split). Panics from either side propagate to the caller.
pub fn join<A, B, FA, FB>(threads: usize, fa: FA, fb: FB) -> (A, B)
where
    A: Send,
    B: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B + Send,
{
    if threads <= 1 {
        let a = fa();
        let b = fb();
        (a, b)
    } else {
        // The spawned side inherits the caller's pool-task flag so that
        // recursion below a pool epoch (e.g. the top-down builder's
        // nested joins) never broadcasts on a pool mid-epoch.
        let inherit = IN_POOL_TASK.with(|c| c.get());
        std::thread::scope(|s| {
            let hb = s.spawn(move || {
                IN_POOL_TASK.with(|c| c.set(inherit));
                fb()
            });
            let a = fa();
            let b = hb
                .join()
                .unwrap_or_else(|panic| std::panic::resume_unwind(panic));
            (a, b)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn threads_resolution() {
        assert_eq!(Parallelism::Serial.threads(), 1);
        assert_eq!(Parallelism::Fixed(4).threads(), 4);
        assert_eq!(Parallelism::Fixed(0).threads(), 1);
        assert!(Parallelism::Auto.threads() >= 1);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(Parallelism::parse("serial"), Some(Parallelism::Serial));
        assert_eq!(Parallelism::parse("auto"), Some(Parallelism::Auto));
        assert_eq!(Parallelism::parse("1"), Some(Parallelism::Serial));
        assert_eq!(Parallelism::parse("6"), Some(Parallelism::Fixed(6)));
        assert_eq!(Parallelism::parse("0"), Some(Parallelism::Auto));
        assert_eq!(Parallelism::parse("banana"), None);
    }

    #[test]
    fn map_tasks_preserves_order() {
        for threads in [1usize, 2, 8] {
            let exec = Executor::new(Parallelism::Fixed(threads));
            let out = exec.map_tasks(100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_tasks_empty_and_single() {
        let exec = Executor::new(Parallelism::Fixed(4));
        assert_eq!(exec.map_tasks(0, |i| i), Vec::<usize>::new());
        assert_eq!(exec.map_tasks(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn map_tasks_runs_each_exactly_once() {
        let exec = Executor::new(Parallelism::Fixed(8));
        let hits: Vec<AtomicU64> = (0..50).map(|_| AtomicU64::new(0)).collect();
        exec.map_tasks(50, |i| hits[i].fetch_add(1, Ordering::Relaxed));
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} run count");
        }
    }

    #[test]
    fn map_chunks_covers_range_exactly() {
        let exec = Executor::new(Parallelism::Fixed(3));
        for (n, chunk) in [(10usize, 3usize), (9, 3), (1, 5), (0, 4), (1000, 7)] {
            let ranges = exec.map_chunks(n, chunk, |r| r);
            let mut expect = 0;
            for r in &ranges {
                assert_eq!(r.start, expect, "n={n} chunk={chunk}");
                assert!(r.end - r.start <= chunk);
                expect = r.end;
            }
            assert_eq!(expect, n, "n={n} chunk={chunk}");
        }
    }

    #[test]
    fn chunk_boundaries_ignore_thread_count() {
        let a = Executor::new(Parallelism::Serial).map_chunks(103, 10, |r| r);
        let b = Executor::new(Parallelism::Fixed(8)).map_chunks(103, 10, |r| r);
        assert_eq!(a, b);
    }

    #[test]
    fn join_returns_both() {
        for threads in [1usize, 4] {
            let exec = Executor::new(Parallelism::Fixed(threads));
            let (a, b) = exec.join(|| 2 + 2, || "ok");
            assert_eq!(a, 4);
            assert_eq!(b, "ok");
            // The free-function form still works for nested callers.
            let (a, b) = join(threads, || 1, || 2);
            assert_eq!((a, b), (1, 2));
        }
    }

    #[test]
    fn map_tasks_propagates_panics() {
        let exec = Executor::new(Parallelism::Fixed(4));
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.map_tasks(16, |i| {
                if i == 9 {
                    panic!("boom from task 9");
                }
                i
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
            .unwrap_or("");
        assert!(msg.contains("boom"), "payload lost: {msg:?}");
        // The pool survives a panicked epoch and keeps serving.
        let out = exec.map_tasks(8, |i| i * 3);
        assert_eq!(out, (0..8).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_lazy_and_reused_across_calls() {
        let exec = Executor::new(Parallelism::Fixed(4));
        assert!(!exec.pool_started(), "pool must start lazily");
        let _ = exec.map_tasks(16, |i| i);
        assert!(exec.pool_started());
        // Clones share the same pool instance.
        let clone = exec.clone();
        assert!(clone.pool_started());
        for round in 0..20 {
            let out = clone.map_tasks(10, |i| i + round);
            assert_eq!(out[9], 9 + round);
        }
    }

    #[test]
    fn serial_executor_never_starts_a_pool() {
        let exec = Executor::serial();
        let _ = exec.map_tasks(32, |i| i);
        assert!(!exec.pool_started());
    }

    #[test]
    fn nested_map_tasks_does_not_deadlock() {
        // A task running on the pool fans out again on the same executor:
        // the inner call must take the scoped path, not the work channel.
        let exec = Executor::new(Parallelism::Fixed(4));
        let exec2 = exec.clone();
        let out = exec.map_tasks(6, |i| {
            let inner = exec2.map_tasks(4, |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 40 + 6);
        }
    }

    #[test]
    fn nested_join_does_not_deadlock() {
        let exec = Executor::new(Parallelism::Fixed(4));
        let exec2 = exec.clone();
        let (a, b) = exec.join(
            || exec2.join(|| 1, || 2),
            || exec2.join(|| 3, || 4),
        );
        assert_eq!((a, b), ((1, 2), (3, 4)));
    }

    #[test]
    fn deeply_nested_joins_do_not_deadlock() {
        // Regression: a thread spawned by a *nested* (scoped) join must
        // inherit the pool-task flag, or the next nesting level would
        // broadcast on the pool mid-epoch and deadlock — the shape of
        // the top-down builder's recursion at 8 threads.
        fn nest(exec: &Executor, depth: usize) -> usize {
            if depth == 0 {
                return 1;
            }
            let (a, b) = exec.join(|| nest(exec, depth - 1), || nest(exec, depth - 1));
            a + b
        }
        let exec = Executor::new(Parallelism::Fixed(8));
        assert_eq!(nest(&exec, 4), 16);
    }

    #[test]
    fn doubly_nested_map_tasks_does_not_deadlock() {
        // Same regression for map_tasks: scoped-fan-out helper threads
        // inherit the flag, so a third nesting level stays scoped.
        let exec = Executor::new(Parallelism::Fixed(3));
        let e2 = exec.clone();
        let out = exec.map_tasks(4, |i| {
            e2.map_tasks(3, |j| e2.map_tasks(2, |k| i + j + k).iter().sum::<usize>())
                .iter()
                .sum::<usize>()
        });
        let serial = Executor::serial();
        let expect = serial.map_tasks(4, |i| {
            serial
                .map_tasks(3, |j| serial.map_tasks(2, |k| i + j + k).iter().sum::<usize>())
                .iter()
                .sum::<usize>()
        });
        assert_eq!(out, expect);
    }

    #[test]
    fn concurrent_broadcasts_from_two_threads_serialize() {
        let exec = Executor::new(Parallelism::Fixed(3));
        std::thread::scope(|s| {
            let e1 = exec.clone();
            let e2 = exec.clone();
            let h1 = s.spawn(move || e1.map_tasks(200, |i| i as u64).iter().sum::<u64>());
            let h2 = s.spawn(move || e2.map_tasks(200, |i| (i * 2) as u64).iter().sum::<u64>());
            assert_eq!(h1.join().unwrap(), 199 * 200 / 2);
            assert_eq!(h2.join().unwrap(), 199 * 200);
        });
    }
}
