//! JSON wire format for [`Query`] / [`QueryResult`] over the crate's
//! own [`crate::json`] module (offline environment — no serde).
//!
//! Queries serialize flat — `{"op": "kmeans", "k": 10, ...}` — so a
//! server request embeds one directly next to its transport fields
//! (`cmd`, `dataset`, ...). Missing fields take the same defaults as
//! the option structs' [`Default`] impls, and `"tree"` defaults to
//! `true` unless explicitly `false`, preserving the historical server
//! protocol. Results serialize as `{"kind": ..., ...}` with derived
//! convenience counts (`n_anomalies`, `n_pairs`, `n_edges`) written but
//! ignored on read, so `parse(write(x)) == x` for every variant.

use super::{
    AllPairsQuery, AnomalyQuery, BallQuery, BallStatsQuery, GaussianEmQuery, InitKind, KdeQuery,
    KernelRegressionQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query, QueryResult,
    XmeansQuery,
};
use crate::algorithms::kde::Kernel;
use crate::algorithms::knn::Neighbor;
use crate::algorithms::mst::Edge;
use crate::ids;
use crate::json::Value;
use std::collections::BTreeMap;

fn obj(fields: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

fn num(x: f64) -> Value {
    Value::Num(x)
}

fn f32_row(row: &[f32]) -> Value {
    Value::Arr(row.iter().map(|&v| num(f64::from(v))).collect())
}

fn f32_rows(rows: &[Vec<f32>]) -> Value {
    Value::Arr(rows.iter().map(|r| f32_row(r)).collect())
}

fn f64_row(row: &[f64]) -> Value {
    Value::Arr(row.iter().map(|&v| num(v)).collect())
}

fn get_f64(v: &Value, key: &str) -> Option<f64> {
    v.get(key).and_then(Value::as_f64)
}

fn get_or(v: &Value, key: &str, default: f64) -> f64 {
    get_f64(v, key).unwrap_or(default)
}

/// Optional count field: absent takes the default, present must be a
/// whole non-negative in-range number (garbage like `-1.5` or `1e300`
/// is an error, not a silent truncation).
fn get_usize(v: &Value, key: &str, default: usize) -> Result<usize, String> {
    match get_f64(v, key) {
        Some(raw) => ids::wire_usize(raw, key),
        None => Ok(default),
    }
}

fn get_u64(v: &Value, key: &str, default: u64) -> Result<u64, String> {
    match get_f64(v, key) {
        Some(raw) => ids::wire_u64(raw, key),
        None => Ok(default),
    }
}

/// Required count field, checked the same way.
fn req_usize(v: &Value, key: &str) -> Result<usize, String> {
    let raw = get_f64(v, key).ok_or_else(|| format!("missing \"{key}\""))?;
    ids::wire_usize(raw, key)
}

fn req_u64(v: &Value, key: &str) -> Result<u64, String> {
    let raw = get_f64(v, key).ok_or_else(|| format!("missing \"{key}\""))?;
    ids::wire_u64(raw, key)
}

/// `"tree"` defaults to true unless explicitly false (historical server
/// behavior: `"tree": 0`-style junk also reads as true).
fn tree_flag(v: &Value) -> bool {
    !matches!(v.get(key_tree()), Some(Value::Bool(false)))
}

fn key_tree() -> &'static str {
    "tree"
}

fn parse_f32_row(v: &Value, what: &str) -> Result<Vec<f32>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: expected array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| format!("{what}: expected number"))
        })
        .collect()
}

fn parse_f32_rows(v: &Value, what: &str) -> Result<Vec<Vec<f32>>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: expected array of arrays"))?
        .iter()
        .map(|row| parse_f32_row(row, what))
        .collect()
}

fn parse_f64_row(v: &Value, what: &str) -> Result<Vec<f64>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: expected array"))?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| format!("{what}: expected number")))
        .collect()
}

fn field<'a>(v: &'a Value, key: &str) -> Result<&'a Value, String> {
    v.get(key).ok_or_else(|| format!("missing \"{key}\""))
}

fn init_kind(v: &Value) -> Result<InitKind, String> {
    match v.get("init") {
        None => Ok(InitKind::Random),
        Some(Value::Str(s)) => {
            InitKind::parse(s).ok_or_else(|| format!("unknown init {s:?}"))
        }
        Some(other) => Err(format!("bad init field {other:?}")),
    }
}

/// `"kernel"` defaults to Gaussian; unknown names are an error, not a
/// silent fallback.
fn kernel_field(v: &Value) -> Result<Kernel, String> {
    match v.get("kernel") {
        None => Ok(Kernel::Gaussian),
        Some(Value::Str(s)) => {
            Kernel::parse(s).ok_or_else(|| format!("unknown kernel {s:?}"))
        }
        Some(other) => Err(format!("bad kernel field {other:?}")),
    }
}

// ---------------------------------------------------------------------
// Queries
// ---------------------------------------------------------------------

/// Serialize a query as a flat `{"op": ..., ...}` object.
pub fn query_to_json(q: &Query) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![("op", Value::Str(q.kind().into()))];
    match q {
        Query::Kmeans(q) => {
            fields.push(("k", num(ids::wire_from_usize(q.k))));
            fields.push(("iters", num(ids::wire_from_usize(q.iters))));
            fields.push(("init", Value::Str(q.init.name().into())));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Xmeans(q) => {
            fields.push(("k_min", num(ids::wire_from_usize(q.k_min))));
            fields.push(("k_max", num(ids::wire_from_usize(q.k_max))));
        }
        Query::Anomaly(q) => {
            fields.push(("threshold", num(ids::wire_from_u64(q.threshold))));
            if let Some(r) = q.radius {
                fields.push(("radius", num(r)));
            }
            fields.push(("frac", num(q.target_frac)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::AllPairs(q) => {
            fields.push(("tau", num(q.tau)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Ball(q) => {
            fields.push(("center", f32_row(&q.center)));
            fields.push(("radius", num(q.radius)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::BallStats(q) => {
            fields.push(("center", f32_row(&q.center)));
            fields.push(("radius", num(q.radius)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Kde(q) => {
            fields.push(("center", f32_row(&q.center)));
            fields.push(("kernel", Value::Str(q.kernel.name().into())));
            fields.push(("bandwidth", num(q.bandwidth)));
            fields.push(("eps_abs", num(q.eps_abs)));
            fields.push(("eps_rel", num(q.eps_rel)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::KernelRegression(q) => {
            fields.push(("center", f32_row(&q.center)));
            fields.push(("target", num(ids::wire_from_usize(q.target_dim))));
            fields.push(("kernel", Value::Str(q.kernel.name().into())));
            fields.push(("bandwidth", num(q.bandwidth)));
            fields.push(("eps_abs", num(q.eps_abs)));
            fields.push(("eps_rel", num(q.eps_rel)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::GaussianEm(q) => {
            fields.push(("k", num(ids::wire_from_usize(q.k))));
            fields.push(("steps", num(ids::wire_from_usize(q.steps))));
            fields.push(("tau", num(q.tau)));
            fields.push(("init", Value::Str(q.init.name().into())));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Knn(q) => {
            match &q.target {
                KnnTarget::Point(id) => fields.push(("point", num(ids::wire_from_u32(*id)))),
                KnnTarget::Vector(v) => fields.push(("vector", f32_row(v))),
            }
            fields.push(("k", num(ids::wire_from_usize(q.k))));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Mst(q) => {
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
    }
    obj(fields)
}

/// Parse a query from a flat object carrying an `"op"` field (extra
/// fields — `cmd`, `dataset`, ... — are ignored, so a whole server
/// request parses directly).
pub fn query_from_json(v: &Value) -> Result<Query, String> {
    let op = v
        .get("op")
        .and_then(Value::as_str)
        .ok_or("missing \"op\"")?;
    let use_tree = tree_flag(v);
    match op {
        "kmeans" => {
            let d = KmeansQuery::default();
            Ok(Query::Kmeans(KmeansQuery {
                k: get_usize(v, "k", d.k)?,
                iters: get_usize(v, "iters", d.iters)?,
                init: init_kind(v)?,
                use_tree,
            }))
        }
        "xmeans" => {
            let d = XmeansQuery::default();
            Ok(Query::Xmeans(XmeansQuery {
                k_min: get_usize(v, "k_min", d.k_min)?,
                k_max: get_usize(v, "k_max", d.k_max)?,
            }))
        }
        "anomaly" => {
            let d = AnomalyQuery::default();
            Ok(Query::Anomaly(AnomalyQuery {
                threshold: get_u64(v, "threshold", d.threshold)?,
                radius: get_f64(v, "radius"),
                target_frac: get_or(v, "frac", d.target_frac),
                use_tree,
            }))
        }
        "allpairs" => {
            let d = AllPairsQuery::default();
            Ok(Query::AllPairs(AllPairsQuery { tau: get_or(v, "tau", d.tau), use_tree }))
        }
        "ball" => {
            let center = parse_f32_row(field(v, "center")?, "center")?;
            let d = BallQuery::default();
            Ok(Query::Ball(BallQuery {
                center,
                radius: get_or(v, "radius", d.radius),
                use_tree,
            }))
        }
        "ballstats" => {
            let center = parse_f32_row(field(v, "center")?, "center")?;
            let d = BallStatsQuery::default();
            Ok(Query::BallStats(BallStatsQuery {
                center,
                radius: get_or(v, "radius", d.radius),
                use_tree,
            }))
        }
        "kde" => {
            let center = parse_f32_row(field(v, "center")?, "center")?;
            let d = KdeQuery::default();
            Ok(Query::Kde(KdeQuery {
                center,
                kernel: kernel_field(v)?,
                bandwidth: get_or(v, "bandwidth", d.bandwidth),
                eps_abs: get_or(v, "eps_abs", d.eps_abs),
                eps_rel: get_or(v, "eps_rel", d.eps_rel),
                use_tree,
            }))
        }
        "kreg" => {
            let center = parse_f32_row(field(v, "center")?, "center")?;
            let d = KernelRegressionQuery::default();
            Ok(Query::KernelRegression(KernelRegressionQuery {
                center,
                target_dim: get_usize(v, "target", d.target_dim)?,
                kernel: kernel_field(v)?,
                bandwidth: get_or(v, "bandwidth", d.bandwidth),
                eps_abs: get_or(v, "eps_abs", d.eps_abs),
                eps_rel: get_or(v, "eps_rel", d.eps_rel),
                use_tree,
            }))
        }
        "em" => {
            let d = GaussianEmQuery::default();
            Ok(Query::GaussianEm(GaussianEmQuery {
                k: get_usize(v, "k", d.k)?,
                steps: get_usize(v, "steps", d.steps)?,
                tau: get_or(v, "tau", d.tau),
                init: init_kind(v)?,
                use_tree,
            }))
        }
        "knn" => {
            let target = match (v.get("point"), v.get("vector")) {
                (Some(p), None) => {
                    let raw = p.as_f64().ok_or("bad \"point\"")?;
                    KnnTarget::Point(ids::wire_u32(raw, "point")?)
                }
                (None, Some(vec)) => KnnTarget::Vector(parse_f32_row(vec, "vector")?),
                (None, None) => return Err("knn needs \"point\" or \"vector\"".into()),
                (Some(_), Some(_)) => {
                    return Err("knn takes \"point\" or \"vector\", not both".into())
                }
            };
            let d = KnnQuery::default();
            Ok(Query::Knn(KnnQuery { target, k: get_usize(v, "k", d.k)?, use_tree }))
        }
        "mst" => Ok(Query::Mst(MstQuery { use_tree })),
        other => Err(format!("unknown op {other:?}")),
    }
}

// ---------------------------------------------------------------------
// Query stats (observability counters)
// ---------------------------------------------------------------------

/// Serialize a [`QueryStats`](crate::obs::QueryStats) block. The prune
/// counters go out as a named object (one key per
/// [`crate::obs::PruneRule`]) and the fan-out as the full
/// [`crate::obs::LEVEL_SLOTS`]-length array, so the round trip is exact
/// rather than lossy-trimmed.
pub fn stats_to_json(s: &crate::obs::QueryStats) -> Value {
    let mut pruned: Vec<(&str, Value)> = Vec::with_capacity(crate::obs::PruneRule::ALL.len());
    for rule in crate::obs::PruneRule::ALL {
        pruned.push((rule.name(), num(ids::wire_from_u64(s.pruned_by(rule)))));
    }
    obj(vec![
        ("nodes_visited", num(ids::wire_from_u64(s.nodes_visited))),
        ("pruned", obj(pruned)),
        ("leaf_rows", num(ids::wire_from_u64(s.leaf_rows))),
        ("frontier_peak", num(ids::wire_from_u64(s.frontier_peak))),
        (
            "level_fanout",
            Value::Arr(s.level_fanout.iter().map(|&c| num(ids::wire_from_u64(c))).collect()),
        ),
    ])
}

/// Parse a [`QueryStats`](crate::obs::QueryStats) block written by
/// [`stats_to_json`]. Missing prune keys and missing trailing fan-out
/// slots read as zero (forward compatibility for new rules/levels);
/// malformed numbers are an error.
pub fn stats_from_json(v: &Value) -> Result<crate::obs::QueryStats, String> {
    let mut s = crate::obs::QueryStats {
        nodes_visited: req_u64(v, "nodes_visited")?,
        leaf_rows: req_u64(v, "leaf_rows")?,
        frontier_peak: req_u64(v, "frontier_peak")?,
        ..Default::default()
    };
    let pruned = field(v, "pruned")?;
    for (slot, rule) in s.pruned.iter_mut().zip(crate::obs::PruneRule::ALL) {
        *slot = get_u64(pruned, rule.name(), 0)?;
    }
    let fanout = field(v, "level_fanout")?
        .as_arr()
        .ok_or("bad \"level_fanout\"")?;
    if fanout.len() > s.level_fanout.len() {
        return Err(format!(
            "level_fanout has {} slots but the build supports {}",
            fanout.len(),
            s.level_fanout.len()
        ));
    }
    for (slot, raw) in s.level_fanout.iter_mut().zip(fanout) {
        let f = raw.as_f64().ok_or("bad \"level_fanout\" entry")?;
        *slot = ids::wire_u64(f, "level_fanout entry")?;
    }
    Ok(s)
}

// ---------------------------------------------------------------------
// Results
// ---------------------------------------------------------------------

/// Serialize a result as `{"kind": ..., ...}`.
pub fn result_to_json(r: &QueryResult) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![("kind", Value::Str(r.kind().into()))];
    match r {
        QueryResult::Kmeans { centroids, distortion, iterations } => {
            fields.push(("distortion", num(*distortion)));
            fields.push(("iterations", num(ids::wire_from_usize(*iterations))));
            fields.push(("centroids", f32_rows(centroids)));
        }
        QueryResult::Xmeans { centroids, k, distortion, bic } => {
            fields.push(("k", num(ids::wire_from_usize(*k))));
            fields.push(("distortion", num(*distortion)));
            fields.push(("bic", num(*bic)));
            fields.push(("centroids", f32_rows(centroids)));
        }
        QueryResult::Anomaly { radius, anomalies } => {
            fields.push(("radius", num(*radius)));
            fields.push(("n_anomalies", num(ids::wire_from_usize(anomalies.len()))));
            fields.push((
                "anomalies",
                Value::Arr(anomalies.iter().map(|&i| num(ids::wire_from_u32(i))).collect()),
            ));
        }
        QueryResult::AllPairs { pairs } => {
            fields.push(("n_pairs", num(ids::wire_from_usize(pairs.len()))));
            fields.push((
                "pairs",
                Value::Arr(
                    pairs
                        .iter()
                        .map(|&(i, j)| {
                            Value::Arr(vec![
                                num(ids::wire_from_u32(i)),
                                num(ids::wire_from_u32(j)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        QueryResult::Ball { count, mean, total_variance } => {
            fields.push(("count", num(ids::wire_from_u64(*count))));
            fields.push(("total_variance", num(*total_variance)));
            fields.push(("mean", f32_row(mean)));
        }
        QueryResult::BallStats { count, mean, variance, total_variance } => {
            fields.push(("count", num(ids::wire_from_u64(*count))));
            fields.push(("total_variance", num(*total_variance)));
            fields.push(("mean", f32_row(mean)));
            fields.push(("variance", f64_row(variance)));
        }
        QueryResult::Kde { sum, density, error_bound } => {
            fields.push(("sum", num(*sum)));
            fields.push(("density", num(*density)));
            fields.push(("error_bound", num(*error_bound)));
        }
        QueryResult::KernelRegression {
            prediction,
            weight_sum,
            weighted_sum,
            weight_error_bound,
            value_error_bound,
        } => {
            fields.push(("prediction", num(*prediction)));
            fields.push(("weight_sum", num(*weight_sum)));
            fields.push(("weighted_sum", num(*weighted_sum)));
            fields.push(("weight_error_bound", num(*weight_error_bound)));
            fields.push(("value_error_bound", num(*value_error_bound)));
        }
        QueryResult::GaussianEm { weights, means, variances, loglik, steps } => {
            fields.push(("loglik", num(*loglik)));
            fields.push(("steps", num(ids::wire_from_usize(*steps))));
            fields.push(("weights", f64_row(weights)));
            fields.push(("variances", f64_row(variances)));
            fields.push(("means", f32_rows(means)));
        }
        QueryResult::Knn { neighbors } => {
            fields.push((
                "neighbors",
                Value::Arr(
                    neighbors
                        .iter()
                        .map(|n| Value::Arr(vec![num(ids::wire_from_u32(n.id)), num(n.dist)]))
                        .collect(),
                ),
            ));
        }
        QueryResult::Mst { edges, total_weight } => {
            fields.push(("n_edges", num(ids::wire_from_usize(edges.len()))));
            fields.push(("total_weight", num(*total_weight)));
            fields.push((
                "edges",
                Value::Arr(
                    edges
                        .iter()
                        .map(|e| {
                            Value::Arr(vec![
                                num(ids::wire_from_u32(e.a)),
                                num(ids::wire_from_u32(e.b)),
                                num(e.dist),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
    }
    obj(fields)
}

/// Parse a result from its `{"kind": ..., ...}` form.
pub fn result_from_json(v: &Value) -> Result<QueryResult, String> {
    let kind = v
        .get("kind")
        .and_then(Value::as_str)
        .ok_or("missing \"kind\"")?;
    match kind {
        "kmeans" => Ok(QueryResult::Kmeans {
            centroids: parse_f32_rows(field(v, "centroids")?, "centroids")?,
            distortion: get_f64(v, "distortion").ok_or("missing \"distortion\"")?,
            iterations: req_usize(v, "iterations")?,
        }),
        "xmeans" => Ok(QueryResult::Xmeans {
            centroids: parse_f32_rows(field(v, "centroids")?, "centroids")?,
            k: req_usize(v, "k")?,
            distortion: get_f64(v, "distortion").ok_or("missing \"distortion\"")?,
            bic: get_f64(v, "bic").ok_or("missing \"bic\"")?,
        }),
        "anomaly" => {
            let anomalies = field(v, "anomalies")?
                .as_arr()
                .ok_or("bad \"anomalies\"")?
                .iter()
                .map(|x| {
                    let raw = x.as_f64().ok_or_else(|| "bad anomaly id".to_string())?;
                    ids::wire_u32(raw, "anomaly id")
                })
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::Anomaly {
                radius: get_f64(v, "radius").ok_or("missing \"radius\"")?,
                anomalies,
            })
        }
        "allpairs" => {
            let pairs = field(v, "pairs")?
                .as_arr()
                .ok_or("bad \"pairs\"")?
                .iter()
                .map(|p| {
                    let (i, j) = match p.as_arr() {
                        Some([i, j]) => (i, j),
                        _ => return Err("bad pair".to_string()),
                    };
                    let i = ids::wire_u32(i.as_f64().ok_or("bad pair")?, "pair id")?;
                    let j = ids::wire_u32(j.as_f64().ok_or("bad pair")?, "pair id")?;
                    Ok((i, j))
                })
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::AllPairs { pairs })
        }
        "ball" => Ok(QueryResult::Ball {
            count: req_u64(v, "count")?,
            mean: parse_f32_row(field(v, "mean")?, "mean")?,
            total_variance: get_f64(v, "total_variance").ok_or("missing \"total_variance\"")?,
        }),
        "ballstats" => Ok(QueryResult::BallStats {
            count: req_u64(v, "count")?,
            mean: parse_f32_row(field(v, "mean")?, "mean")?,
            variance: parse_f64_row(field(v, "variance")?, "variance")?,
            total_variance: get_f64(v, "total_variance").ok_or("missing \"total_variance\"")?,
        }),
        "kde" => Ok(QueryResult::Kde {
            sum: get_f64(v, "sum").ok_or("missing \"sum\"")?,
            density: get_f64(v, "density").ok_or("missing \"density\"")?,
            error_bound: get_f64(v, "error_bound").ok_or("missing \"error_bound\"")?,
        }),
        "kreg" => Ok(QueryResult::KernelRegression {
            prediction: get_f64(v, "prediction").ok_or("missing \"prediction\"")?,
            weight_sum: get_f64(v, "weight_sum").ok_or("missing \"weight_sum\"")?,
            weighted_sum: get_f64(v, "weighted_sum").ok_or("missing \"weighted_sum\"")?,
            weight_error_bound: get_f64(v, "weight_error_bound")
                .ok_or("missing \"weight_error_bound\"")?,
            value_error_bound: get_f64(v, "value_error_bound")
                .ok_or("missing \"value_error_bound\"")?,
        }),
        "em" => Ok(QueryResult::GaussianEm {
            weights: parse_f64_row(field(v, "weights")?, "weights")?,
            means: parse_f32_rows(field(v, "means")?, "means")?,
            variances: parse_f64_row(field(v, "variances")?, "variances")?,
            loglik: get_f64(v, "loglik").ok_or("missing \"loglik\"")?,
            steps: req_usize(v, "steps")?,
        }),
        "knn" => {
            let neighbors = field(v, "neighbors")?
                .as_arr()
                .ok_or("bad \"neighbors\"")?
                .iter()
                .map(|p| {
                    let (id, dist) = match p.as_arr() {
                        Some([id, dist]) => (id, dist),
                        _ => return Err("bad neighbor".to_string()),
                    };
                    let id = ids::wire_u32(id.as_f64().ok_or("bad neighbor")?, "neighbor id")?;
                    let dist = dist.as_f64().ok_or("bad neighbor")?;
                    Ok(Neighbor { id, dist })
                })
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::Knn { neighbors })
        }
        "mst" => {
            let edges = field(v, "edges")?
                .as_arr()
                .ok_or("bad \"edges\"")?
                .iter()
                .map(|e| {
                    let (a, b, dist) = match e.as_arr() {
                        Some([a, b, dist]) => (a, b, dist),
                        _ => return Err("bad edge".to_string()),
                    };
                    let a = ids::wire_u32(a.as_f64().ok_or("bad edge")?, "edge endpoint")?;
                    let b = ids::wire_u32(b.as_f64().ok_or("bad edge")?, "edge endpoint")?;
                    let dist = dist.as_f64().ok_or("bad edge")?;
                    Ok(Edge { a, b, dist })
                })
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::Mst {
                edges,
                total_weight: get_f64(v, "total_weight").ok_or("missing \"total_weight\"")?,
            })
        }
        other => Err(format!("unknown result kind {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn roundtrip_query(q: Query) {
        let text = json::write(&query_to_json(&q));
        let back = query_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(q, back, "wire-mangled query: {text}");
    }

    #[test]
    fn every_query_variant_roundtrips() {
        roundtrip_query(Query::Kmeans(KmeansQuery {
            k: 7,
            iters: 3,
            init: InitKind::Anchors,
            use_tree: false,
        }));
        roundtrip_query(Query::Xmeans(XmeansQuery { k_min: 2, k_max: 9 }));
        roundtrip_query(Query::Anomaly(AnomalyQuery {
            threshold: 12,
            radius: Some(0.75),
            target_frac: 0.2,
            use_tree: true,
        }));
        roundtrip_query(Query::Anomaly(AnomalyQuery { radius: None, ..Default::default() }));
        roundtrip_query(Query::AllPairs(AllPairsQuery { tau: 1.25, use_tree: false }));
        roundtrip_query(Query::Ball(BallQuery {
            center: vec![0.5, -1.5, 3.0],
            radius: 2.0,
            use_tree: true,
        }));
        roundtrip_query(Query::BallStats(BallStatsQuery {
            center: vec![1.25, 0.0],
            radius: 4.5,
            use_tree: false,
        }));
        roundtrip_query(Query::Kde(KdeQuery {
            center: vec![0.5, 2.5],
            kernel: Kernel::Epanechnikov,
            bandwidth: 3.5,
            eps_abs: 0.25,
            eps_rel: 0.0,
            use_tree: true,
        }));
        roundtrip_query(Query::KernelRegression(KernelRegressionQuery {
            center: vec![-1.0, 0.0, 2.0],
            target_dim: 2,
            kernel: Kernel::Gaussian,
            bandwidth: 0.5,
            eps_abs: 0.0,
            eps_rel: 0.05,
            use_tree: false,
        }));
        roundtrip_query(Query::GaussianEm(GaussianEmQuery {
            k: 4,
            steps: 6,
            tau: 0.01,
            init: InitKind::Random,
            use_tree: true,
        }));
        roundtrip_query(Query::Knn(KnnQuery {
            target: KnnTarget::Point(17),
            k: 3,
            use_tree: true,
        }));
        roundtrip_query(Query::Knn(KnnQuery {
            target: KnnTarget::Vector(vec![1.0, 2.0]),
            k: 8,
            use_tree: false,
        }));
        roundtrip_query(Query::Mst(MstQuery { use_tree: false }));
    }

    #[test]
    fn query_defaults_fill_in() {
        let v = json::parse(r#"{"op":"kmeans"}"#).unwrap();
        assert_eq!(query_from_json(&v).unwrap(), Query::Kmeans(KmeansQuery::default()));
        let v = json::parse(r#"{"op":"mst"}"#).unwrap();
        assert_eq!(query_from_json(&v).unwrap(), Query::Mst(MstQuery { use_tree: true }));
    }

    #[test]
    fn unknown_op_rejected() {
        let v = json::parse(r#"{"op":"nope"}"#).unwrap();
        assert!(query_from_json(&v).is_err());
    }

    #[test]
    fn kernel_defaults_fill_in_and_unknown_kernel_rejected() {
        let v = json::parse(r#"{"op":"kde","center":[0.0,1.0]}"#).unwrap();
        assert_eq!(
            query_from_json(&v).unwrap(),
            Query::Kde(KdeQuery { center: vec![0.0, 1.0], ..Default::default() })
        );
        let v = json::parse(r#"{"op":"kreg","center":[1.0],"kernel":"box"}"#).unwrap();
        assert!(query_from_json(&v).is_err());
        let v = json::parse(r#"{"op":"ballstats"}"#).unwrap();
        assert!(query_from_json(&v).is_err(), "ballstats requires a center");
    }

    fn roundtrip_result(r: QueryResult) {
        let text = json::write(&result_to_json(&r));
        let back = result_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(r, back, "wire-mangled result: {text}");
    }

    #[test]
    fn every_result_variant_roundtrips() {
        roundtrip_result(QueryResult::Kmeans {
            centroids: vec![vec![1.5, -2.25], vec![0.0, 3.125]],
            distortion: 123.456,
            iterations: 4,
        });
        roundtrip_result(QueryResult::Xmeans {
            centroids: vec![vec![0.5]],
            k: 1,
            distortion: 9.0,
            bic: -12.5,
        });
        roundtrip_result(QueryResult::Anomaly { radius: 0.5, anomalies: vec![3, 9, 41] });
        roundtrip_result(QueryResult::AllPairs { pairs: vec![(0, 4), (2, 7)] });
        roundtrip_result(QueryResult::Ball {
            count: 42,
            mean: vec![1.0, 2.0],
            total_variance: 0.25,
        });
        roundtrip_result(QueryResult::BallStats {
            count: 17,
            mean: vec![0.5, -3.0],
            variance: vec![0.125, 2.5],
            total_variance: 2.625,
        });
        roundtrip_result(QueryResult::Kde {
            sum: 12.5,
            density: 0.125,
            error_bound: 0.0625,
        });
        roundtrip_result(QueryResult::KernelRegression {
            prediction: 3.75,
            weight_sum: 8.5,
            weighted_sum: 31.875,
            weight_error_bound: 0.25,
            value_error_bound: 0.5,
        });
        roundtrip_result(QueryResult::GaussianEm {
            weights: vec![0.5, 0.5],
            means: vec![vec![0.0], vec![1.0]],
            variances: vec![1.0, 2.0],
            loglik: -321.75,
            steps: 5,
        });
        roundtrip_result(QueryResult::Knn {
            neighbors: vec![Neighbor { id: 3, dist: 0.5 }, Neighbor { id: 8, dist: 1.25 }],
        });
        roundtrip_result(QueryResult::Mst {
            edges: vec![Edge { a: 0, b: 1, dist: 0.5 }],
            total_weight: 0.5,
        });
    }

    #[test]
    fn query_stats_roundtrip_is_exact() {
        let s = crate::obs::QueryStats {
            nodes_visited: 123,
            leaf_rows: 4567,
            frontier_peak: 89,
            pruned: std::array::from_fn(|i| (i as u64 + 1) * 7),
            level_fanout: std::array::from_fn(|i| i as u64 * 3),
        };
        let text = json::write(&stats_to_json(&s));
        let back = stats_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(s, back, "wire-mangled stats: {text}");
    }

    #[test]
    fn query_stats_missing_prune_keys_read_as_zero() {
        let v = json::parse(
            r#"{"nodes_visited":5,"pruned":{"triangle":2},"leaf_rows":9,
                "frontier_peak":1,"level_fanout":[5]}"#,
        )
        .unwrap();
        let s = stats_from_json(&v).unwrap();
        assert_eq!(s.nodes_visited, 5);
        assert_eq!(s.pruned_by(crate::obs::PruneRule::Triangle), 2);
        assert_eq!(s.pruned_by(crate::obs::PruneRule::Budget), 0);
        assert_eq!(s.level_fanout[0], 5);
        assert_eq!(s.level_fanout[1], 0);
    }
}
