//! JSON wire format for [`Query`] / [`QueryResult`] over the crate's
//! own [`crate::json`] module (offline environment — no serde).
//!
//! Queries serialize flat — `{"op": "kmeans", "k": 10, ...}` — so a
//! server request embeds one directly next to its transport fields
//! (`cmd`, `dataset`, ...). Missing fields take the same defaults as
//! the option structs' [`Default`] impls, and `"tree"` defaults to
//! `true` unless explicitly `false`, preserving the historical server
//! protocol. Results serialize as `{"kind": ..., ...}` with derived
//! convenience counts (`n_anomalies`, `n_pairs`, `n_edges`) written but
//! ignored on read, so `parse(write(x)) == x` for every variant.

use super::{
    AllPairsQuery, AnomalyQuery, BallQuery, GaussianEmQuery, InitKind, KmeansQuery, KnnQuery,
    KnnTarget, MstQuery, Query, QueryResult, XmeansQuery,
};
use crate::algorithms::knn::Neighbor;
use crate::algorithms::mst::Edge;
use crate::json::Value;
use std::collections::BTreeMap;

fn obj(fields: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

fn num(x: f64) -> Value {
    Value::Num(x)
}

fn f32_row(row: &[f32]) -> Value {
    Value::Arr(row.iter().map(|&v| num(v as f64)).collect())
}

fn f32_rows(rows: &[Vec<f32>]) -> Value {
    Value::Arr(rows.iter().map(|r| f32_row(r)).collect())
}

fn f64_row(row: &[f64]) -> Value {
    Value::Arr(row.iter().map(|&v| num(v)).collect())
}

fn get_f64(v: &Value, key: &str) -> Option<f64> {
    v.get(key).and_then(Value::as_f64)
}

fn get_or(v: &Value, key: &str, default: f64) -> f64 {
    get_f64(v, key).unwrap_or(default)
}

/// `"tree"` defaults to true unless explicitly false (historical server
/// behavior: `"tree": 0`-style junk also reads as true).
fn tree_flag(v: &Value) -> bool {
    !matches!(v.get(key_tree()), Some(Value::Bool(false)))
}

fn key_tree() -> &'static str {
    "tree"
}

fn parse_f32_row(v: &Value, what: &str) -> Result<Vec<f32>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: expected array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| format!("{what}: expected number"))
        })
        .collect()
}

fn parse_f32_rows(v: &Value, what: &str) -> Result<Vec<Vec<f32>>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: expected array of arrays"))?
        .iter()
        .map(|row| parse_f32_row(row, what))
        .collect()
}

fn parse_f64_row(v: &Value, what: &str) -> Result<Vec<f64>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: expected array"))?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| format!("{what}: expected number")))
        .collect()
}

fn field<'a>(v: &'a Value, key: &str) -> Result<&'a Value, String> {
    v.get(key).ok_or_else(|| format!("missing \"{key}\""))
}

fn init_kind(v: &Value) -> Result<InitKind, String> {
    match v.get("init") {
        None => Ok(InitKind::Random),
        Some(Value::Str(s)) => {
            InitKind::parse(s).ok_or_else(|| format!("unknown init {s:?}"))
        }
        Some(other) => Err(format!("bad init field {other:?}")),
    }
}

// ---------------------------------------------------------------------
// Queries
// ---------------------------------------------------------------------

/// Serialize a query as a flat `{"op": ..., ...}` object.
pub fn query_to_json(q: &Query) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![("op", Value::Str(q.kind().into()))];
    match q {
        Query::Kmeans(q) => {
            fields.push(("k", num(q.k as f64)));
            fields.push(("iters", num(q.iters as f64)));
            fields.push(("init", Value::Str(q.init.name().into())));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Xmeans(q) => {
            fields.push(("k_min", num(q.k_min as f64)));
            fields.push(("k_max", num(q.k_max as f64)));
        }
        Query::Anomaly(q) => {
            fields.push(("threshold", num(q.threshold as f64)));
            if let Some(r) = q.radius {
                fields.push(("radius", num(r)));
            }
            fields.push(("frac", num(q.target_frac)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::AllPairs(q) => {
            fields.push(("tau", num(q.tau)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Ball(q) => {
            fields.push(("center", f32_row(&q.center)));
            fields.push(("radius", num(q.radius)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::GaussianEm(q) => {
            fields.push(("k", num(q.k as f64)));
            fields.push(("steps", num(q.steps as f64)));
            fields.push(("tau", num(q.tau)));
            fields.push(("init", Value::Str(q.init.name().into())));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Knn(q) => {
            match &q.target {
                KnnTarget::Point(id) => fields.push(("point", num(*id as f64))),
                KnnTarget::Vector(v) => fields.push(("vector", f32_row(v))),
            }
            fields.push(("k", num(q.k as f64)));
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
        Query::Mst(q) => {
            fields.push((key_tree(), Value::Bool(q.use_tree)));
        }
    }
    obj(fields)
}

/// Parse a query from a flat object carrying an `"op"` field (extra
/// fields — `cmd`, `dataset`, ... — are ignored, so a whole server
/// request parses directly).
pub fn query_from_json(v: &Value) -> Result<Query, String> {
    let op = v
        .get("op")
        .and_then(Value::as_str)
        .ok_or("missing \"op\"")?;
    let use_tree = tree_flag(v);
    match op {
        "kmeans" => {
            let d = KmeansQuery::default();
            Ok(Query::Kmeans(KmeansQuery {
                k: get_or(v, "k", d.k as f64) as usize,
                iters: get_or(v, "iters", d.iters as f64) as usize,
                init: init_kind(v)?,
                use_tree,
            }))
        }
        "xmeans" => {
            let d = XmeansQuery::default();
            Ok(Query::Xmeans(XmeansQuery {
                k_min: get_or(v, "k_min", d.k_min as f64) as usize,
                k_max: get_or(v, "k_max", d.k_max as f64) as usize,
            }))
        }
        "anomaly" => {
            let d = AnomalyQuery::default();
            Ok(Query::Anomaly(AnomalyQuery {
                threshold: get_or(v, "threshold", d.threshold as f64) as u64,
                radius: get_f64(v, "radius"),
                target_frac: get_or(v, "frac", d.target_frac),
                use_tree,
            }))
        }
        "allpairs" => {
            let d = AllPairsQuery::default();
            Ok(Query::AllPairs(AllPairsQuery { tau: get_or(v, "tau", d.tau), use_tree }))
        }
        "ball" => {
            let center = parse_f32_row(field(v, "center")?, "center")?;
            let d = BallQuery::default();
            Ok(Query::Ball(BallQuery {
                center,
                radius: get_or(v, "radius", d.radius),
                use_tree,
            }))
        }
        "em" => {
            let d = GaussianEmQuery::default();
            Ok(Query::GaussianEm(GaussianEmQuery {
                k: get_or(v, "k", d.k as f64) as usize,
                steps: get_or(v, "steps", d.steps as f64) as usize,
                tau: get_or(v, "tau", d.tau),
                init: init_kind(v)?,
                use_tree,
            }))
        }
        "knn" => {
            let target = match (v.get("point"), v.get("vector")) {
                (Some(p), None) => KnnTarget::Point(
                    p.as_f64().ok_or("bad \"point\"")? as u32,
                ),
                (None, Some(vec)) => KnnTarget::Vector(parse_f32_row(vec, "vector")?),
                (None, None) => return Err("knn needs \"point\" or \"vector\"".into()),
                (Some(_), Some(_)) => {
                    return Err("knn takes \"point\" or \"vector\", not both".into())
                }
            };
            let d = KnnQuery::default();
            Ok(Query::Knn(KnnQuery { target, k: get_or(v, "k", d.k as f64) as usize, use_tree }))
        }
        "mst" => Ok(Query::Mst(MstQuery { use_tree })),
        other => Err(format!("unknown op {other:?}")),
    }
}

// ---------------------------------------------------------------------
// Results
// ---------------------------------------------------------------------

/// Serialize a result as `{"kind": ..., ...}`.
pub fn result_to_json(r: &QueryResult) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![("kind", Value::Str(r.kind().into()))];
    match r {
        QueryResult::Kmeans { centroids, distortion, iterations } => {
            fields.push(("distortion", num(*distortion)));
            fields.push(("iterations", num(*iterations as f64)));
            fields.push(("centroids", f32_rows(centroids)));
        }
        QueryResult::Xmeans { centroids, k, distortion, bic } => {
            fields.push(("k", num(*k as f64)));
            fields.push(("distortion", num(*distortion)));
            fields.push(("bic", num(*bic)));
            fields.push(("centroids", f32_rows(centroids)));
        }
        QueryResult::Anomaly { radius, anomalies } => {
            fields.push(("radius", num(*radius)));
            fields.push(("n_anomalies", num(anomalies.len() as f64)));
            fields.push((
                "anomalies",
                Value::Arr(anomalies.iter().map(|&i| num(i as f64)).collect()),
            ));
        }
        QueryResult::AllPairs { pairs } => {
            fields.push(("n_pairs", num(pairs.len() as f64)));
            fields.push((
                "pairs",
                Value::Arr(
                    pairs
                        .iter()
                        .map(|&(i, j)| Value::Arr(vec![num(i as f64), num(j as f64)]))
                        .collect(),
                ),
            ));
        }
        QueryResult::Ball { count, mean, total_variance } => {
            fields.push(("count", num(*count as f64)));
            fields.push(("total_variance", num(*total_variance)));
            fields.push(("mean", f32_row(mean)));
        }
        QueryResult::GaussianEm { weights, means, variances, loglik, steps } => {
            fields.push(("loglik", num(*loglik)));
            fields.push(("steps", num(*steps as f64)));
            fields.push(("weights", f64_row(weights)));
            fields.push(("variances", f64_row(variances)));
            fields.push(("means", f32_rows(means)));
        }
        QueryResult::Knn { neighbors } => {
            fields.push((
                "neighbors",
                Value::Arr(
                    neighbors
                        .iter()
                        .map(|n| Value::Arr(vec![num(n.id as f64), num(n.dist)]))
                        .collect(),
                ),
            ));
        }
        QueryResult::Mst { edges, total_weight } => {
            fields.push(("n_edges", num(edges.len() as f64)));
            fields.push(("total_weight", num(*total_weight)));
            fields.push((
                "edges",
                Value::Arr(
                    edges
                        .iter()
                        .map(|e| Value::Arr(vec![num(e.a as f64), num(e.b as f64), num(e.dist)]))
                        .collect(),
                ),
            ));
        }
    }
    obj(fields)
}

/// Parse a result from its `{"kind": ..., ...}` form.
pub fn result_from_json(v: &Value) -> Result<QueryResult, String> {
    let kind = v
        .get("kind")
        .and_then(Value::as_str)
        .ok_or("missing \"kind\"")?;
    match kind {
        "kmeans" => Ok(QueryResult::Kmeans {
            centroids: parse_f32_rows(field(v, "centroids")?, "centroids")?,
            distortion: get_f64(v, "distortion").ok_or("missing \"distortion\"")?,
            iterations: get_f64(v, "iterations").ok_or("missing \"iterations\"")? as usize,
        }),
        "xmeans" => Ok(QueryResult::Xmeans {
            centroids: parse_f32_rows(field(v, "centroids")?, "centroids")?,
            k: get_f64(v, "k").ok_or("missing \"k\"")? as usize,
            distortion: get_f64(v, "distortion").ok_or("missing \"distortion\"")?,
            bic: get_f64(v, "bic").ok_or("missing \"bic\"")?,
        }),
        "anomaly" => {
            let anomalies = field(v, "anomalies")?
                .as_arr()
                .ok_or("bad \"anomalies\"")?
                .iter()
                .map(|x| x.as_f64().map(|f| f as u32).ok_or("bad anomaly id"))
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::Anomaly {
                radius: get_f64(v, "radius").ok_or("missing \"radius\"")?,
                anomalies,
            })
        }
        "allpairs" => {
            let pairs = field(v, "pairs")?
                .as_arr()
                .ok_or("bad \"pairs\"")?
                .iter()
                .map(|p| {
                    let p = p.as_arr().filter(|p| p.len() == 2).ok_or("bad pair")?;
                    let i = p[0].as_f64().ok_or("bad pair")? as u32;
                    let j = p[1].as_f64().ok_or("bad pair")? as u32;
                    Ok::<(u32, u32), &str>((i, j))
                })
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::AllPairs { pairs })
        }
        "ball" => Ok(QueryResult::Ball {
            count: get_f64(v, "count").ok_or("missing \"count\"")? as u64,
            mean: parse_f32_row(field(v, "mean")?, "mean")?,
            total_variance: get_f64(v, "total_variance").ok_or("missing \"total_variance\"")?,
        }),
        "em" => Ok(QueryResult::GaussianEm {
            weights: parse_f64_row(field(v, "weights")?, "weights")?,
            means: parse_f32_rows(field(v, "means")?, "means")?,
            variances: parse_f64_row(field(v, "variances")?, "variances")?,
            loglik: get_f64(v, "loglik").ok_or("missing \"loglik\"")?,
            steps: get_f64(v, "steps").ok_or("missing \"steps\"")? as usize,
        }),
        "knn" => {
            let neighbors = field(v, "neighbors")?
                .as_arr()
                .ok_or("bad \"neighbors\"")?
                .iter()
                .map(|p| {
                    let p = p.as_arr().filter(|p| p.len() == 2).ok_or("bad neighbor")?;
                    let id = p[0].as_f64().ok_or("bad neighbor")? as u32;
                    let dist = p[1].as_f64().ok_or("bad neighbor")?;
                    Ok::<Neighbor, &str>(Neighbor { id, dist })
                })
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::Knn { neighbors })
        }
        "mst" => {
            let edges = field(v, "edges")?
                .as_arr()
                .ok_or("bad \"edges\"")?
                .iter()
                .map(|e| {
                    let e = e.as_arr().filter(|e| e.len() == 3).ok_or("bad edge")?;
                    let a = e[0].as_f64().ok_or("bad edge")? as u32;
                    let b = e[1].as_f64().ok_or("bad edge")? as u32;
                    let dist = e[2].as_f64().ok_or("bad edge")?;
                    Ok::<Edge, &str>(Edge { a, b, dist })
                })
                .collect::<Result<_, _>>()?;
            Ok(QueryResult::Mst {
                edges,
                total_weight: get_f64(v, "total_weight").ok_or("missing \"total_weight\"")?,
            })
        }
        other => Err(format!("unknown result kind {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn roundtrip_query(q: Query) {
        let text = json::write(&query_to_json(&q));
        let back = query_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(q, back, "wire-mangled query: {text}");
    }

    #[test]
    fn every_query_variant_roundtrips() {
        roundtrip_query(Query::Kmeans(KmeansQuery {
            k: 7,
            iters: 3,
            init: InitKind::Anchors,
            use_tree: false,
        }));
        roundtrip_query(Query::Xmeans(XmeansQuery { k_min: 2, k_max: 9 }));
        roundtrip_query(Query::Anomaly(AnomalyQuery {
            threshold: 12,
            radius: Some(0.75),
            target_frac: 0.2,
            use_tree: true,
        }));
        roundtrip_query(Query::Anomaly(AnomalyQuery { radius: None, ..Default::default() }));
        roundtrip_query(Query::AllPairs(AllPairsQuery { tau: 1.25, use_tree: false }));
        roundtrip_query(Query::Ball(BallQuery {
            center: vec![0.5, -1.5, 3.0],
            radius: 2.0,
            use_tree: true,
        }));
        roundtrip_query(Query::GaussianEm(GaussianEmQuery {
            k: 4,
            steps: 6,
            tau: 0.01,
            init: InitKind::Random,
            use_tree: true,
        }));
        roundtrip_query(Query::Knn(KnnQuery {
            target: KnnTarget::Point(17),
            k: 3,
            use_tree: true,
        }));
        roundtrip_query(Query::Knn(KnnQuery {
            target: KnnTarget::Vector(vec![1.0, 2.0]),
            k: 8,
            use_tree: false,
        }));
        roundtrip_query(Query::Mst(MstQuery { use_tree: false }));
    }

    #[test]
    fn query_defaults_fill_in() {
        let v = json::parse(r#"{"op":"kmeans"}"#).unwrap();
        assert_eq!(query_from_json(&v).unwrap(), Query::Kmeans(KmeansQuery::default()));
        let v = json::parse(r#"{"op":"mst"}"#).unwrap();
        assert_eq!(query_from_json(&v).unwrap(), Query::Mst(MstQuery { use_tree: true }));
    }

    #[test]
    fn unknown_op_rejected() {
        let v = json::parse(r#"{"op":"nope"}"#).unwrap();
        assert!(query_from_json(&v).is_err());
    }

    fn roundtrip_result(r: QueryResult) {
        let text = json::write(&result_to_json(&r));
        let back = result_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(r, back, "wire-mangled result: {text}");
    }

    #[test]
    fn every_result_variant_roundtrips() {
        roundtrip_result(QueryResult::Kmeans {
            centroids: vec![vec![1.5, -2.25], vec![0.0, 3.125]],
            distortion: 123.456,
            iterations: 4,
        });
        roundtrip_result(QueryResult::Xmeans {
            centroids: vec![vec![0.5]],
            k: 1,
            distortion: 9.0,
            bic: -12.5,
        });
        roundtrip_result(QueryResult::Anomaly { radius: 0.5, anomalies: vec![3, 9, 41] });
        roundtrip_result(QueryResult::AllPairs { pairs: vec![(0, 4), (2, 7)] });
        roundtrip_result(QueryResult::Ball {
            count: 42,
            mean: vec![1.0, 2.0],
            total_variance: 0.25,
        });
        roundtrip_result(QueryResult::GaussianEm {
            weights: vec![0.5, 0.5],
            means: vec![vec![0.0], vec![1.0]],
            variances: vec![1.0, 2.0],
            loglik: -321.75,
            steps: 5,
        });
        roundtrip_result(QueryResult::Knn {
            neighbors: vec![Neighbor { id: 3, dist: 0.5 }, Neighbor { id: 8, dist: 1.25 }],
        });
        roundtrip_result(QueryResult::Mst {
            edges: vec![Edge { a: 0, b: 1, dist: 0.5 }],
            total_weight: 0.5,
        });
    }
}
