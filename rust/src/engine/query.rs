//! The typed query surface of the engine: one [`Query`] variant per
//! algorithm family the paper's cached-statistics metric tree serves,
//! each with its own options struct (sensible [`Default`]s throughout),
//! and the matching [`QueryResult`] payloads.
//!
//! Every query carries a `use_tree` switch selecting the
//! tree-accelerated implementation (default) or the naive baseline the
//! paper compares against — except X-means, which is defined in terms of
//! the tree and always uses it.

use crate::algorithms::kde::Kernel;
use crate::algorithms::knn::Neighbor;
use crate::algorithms::mst::Edge;

/// Centroid / mixture-mean initialization strategy (wire-safe subset of
/// [`crate::algorithms::kmeans::Init`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    /// k distinct datapoints chosen uniformly at random.
    Random,
    /// Centroids of the k anchors of the anchors hierarchy (the paper's
    /// "Anchors Start", Table 4).
    Anchors,
}

impl InitKind {
    pub fn name(&self) -> &'static str {
        match self {
            InitKind::Random => "random",
            InitKind::Anchors => "anchors",
        }
    }

    pub fn parse(name: &str) -> Option<InitKind> {
        match name {
            "random" => Some(InitKind::Random),
            "anchors" => Some(InitKind::Anchors),
            _ => None,
        }
    }
}

/// Exact K-means (paper §4.1).
#[derive(Clone, Debug, PartialEq)]
pub struct KmeansQuery {
    pub k: usize,
    /// Maximum Lloyd iterations (at least one pass always runs).
    pub iters: usize,
    pub init: InitKind,
    pub use_tree: bool,
}

impl Default for KmeansQuery {
    fn default() -> Self {
        KmeansQuery { k: 10, iters: 5, init: InitKind::Random, use_tree: true }
    }
}

/// X-means: K-means with BIC-driven estimation of k (Pelleg & Moore).
/// Tree-only: the algorithm is defined in terms of the shared index.
#[derive(Clone, Debug, PartialEq)]
pub struct XmeansQuery {
    pub k_min: usize,
    pub k_max: usize,
}

impl Default for XmeansQuery {
    fn default() -> Self {
        XmeansQuery { k_min: 1, k_max: 16 }
    }
}

/// Non-parametric anomaly detection sweep (paper §4.2).
#[derive(Clone, Debug, PartialEq)]
pub struct AnomalyQuery {
    /// A point is anomalous when fewer than `threshold` points lie
    /// within the radius.
    pub threshold: u64,
    /// Explicit neighborhood radius; `None` auto-calibrates so roughly
    /// `target_frac` of the points are anomalous (the paper's §5 setup).
    pub radius: Option<f64>,
    pub target_frac: f64,
    pub use_tree: bool,
}

impl Default for AnomalyQuery {
    fn default() -> Self {
        AnomalyQuery { threshold: 10, radius: None, target_frac: 0.1, use_tree: true }
    }
}

/// All close pairs `D(x, y) ≤ tau` (paper §4.3, attribute grouping).
#[derive(Clone, Debug, PartialEq)]
pub struct AllPairsQuery {
    pub tau: f64,
    pub use_tree: bool,
}

impl Default for AllPairsQuery {
    fn default() -> Self {
        AllPairsQuery { tau: 1.0, use_tree: true }
    }
}

/// Exact count / mean / total-variance of the points inside a ball
/// (the paper's §1 cached-sufficient-statistics motivation).
#[derive(Clone, Debug, PartialEq)]
pub struct BallQuery {
    pub center: Vec<f32>,
    pub radius: f64,
    pub use_tree: bool,
}

impl Default for BallQuery {
    fn default() -> Self {
        BallQuery { center: Vec::new(), radius: 1.0, use_tree: true }
    }
}

/// Kernel density estimate at a query point, tree-pruned under a
/// user-supplied absolute/relative error budget: the result's kernel sum
/// is within `eps_abs + eps_rel·S` of the exact sum `S`
/// ([`crate::algorithms::kde`]).
#[derive(Clone, Debug, PartialEq)]
pub struct KdeQuery {
    pub center: Vec<f32>,
    pub kernel: Kernel,
    pub bandwidth: f64,
    pub eps_abs: f64,
    pub eps_rel: f64,
    pub use_tree: bool,
}

impl Default for KdeQuery {
    fn default() -> Self {
        KdeQuery {
            center: Vec::new(),
            kernel: Kernel::Gaussian,
            bandwidth: 1.0,
            eps_abs: 0.0,
            eps_rel: 0.01,
            use_tree: true,
        }
    }
}

/// Nadaraya-Watson kernel regression at a query point: the response is
/// dataset coordinate `target_dim`, the smoothing weights use the full
/// metric, and the same budget-split traversal as [`KdeQuery`] bounds
/// both the weight sum and (via the per-dimension second moments) the
/// weighted response sum.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelRegressionQuery {
    pub center: Vec<f32>,
    /// Which dataset coordinate is the regression response.
    pub target_dim: usize,
    pub kernel: Kernel,
    pub bandwidth: f64,
    pub eps_abs: f64,
    pub eps_rel: f64,
    pub use_tree: bool,
}

impl Default for KernelRegressionQuery {
    fn default() -> Self {
        KernelRegressionQuery {
            center: Vec::new(),
            target_dim: 0,
            kernel: Kernel::Gaussian,
            bandwidth: 1.0,
            eps_abs: 0.0,
            eps_rel: 0.01,
            use_tree: true,
        }
    }
}

/// Exact count / mean / **per-dimension variance** of the points inside
/// a ball — [`BallQuery`] extended with the full variance diagonal from
/// the per-dimension second moments cached on every node.
#[derive(Clone, Debug, PartialEq)]
pub struct BallStatsQuery {
    pub center: Vec<f32>,
    pub radius: f64,
    pub use_tree: bool,
}

impl Default for BallStatsQuery {
    fn default() -> Self {
        BallStatsQuery { center: Vec::new(), radius: 1.0, use_tree: true }
    }
}

/// Spherical-Gaussian mixture EM (paper §6).
#[derive(Clone, Debug, PartialEq)]
pub struct GaussianEmQuery {
    pub k: usize,
    /// EM steps to run (at least one always runs).
    pub steps: usize,
    /// Responsibility-bracket width below which whole nodes are awarded
    /// in bulk; `0.0` is exact (bit-comparable to naive EM).
    pub tau: f64,
    pub init: InitKind,
    pub use_tree: bool,
}

impl Default for GaussianEmQuery {
    fn default() -> Self {
        GaussianEmQuery { k: 5, steps: 5, tau: 0.0, init: InitKind::Random, use_tree: true }
    }
}

/// What a k-NN query searches around.
#[derive(Clone, Debug, PartialEq)]
pub enum KnnTarget {
    /// A dataset row (excluded from its own neighbor list).
    Point(u32),
    /// An arbitrary query vector of the space's dimension.
    Vector(Vec<f32>),
}

/// k-nearest-neighbor search (paper §2.1).
#[derive(Clone, Debug, PartialEq)]
pub struct KnnQuery {
    pub target: KnnTarget,
    pub k: usize,
    pub use_tree: bool,
}

impl Default for KnnQuery {
    fn default() -> Self {
        KnnQuery { target: KnnTarget::Point(0), k: 5, use_tree: true }
    }
}

/// Euclidean minimum spanning tree / dependency tree (paper §6).
#[derive(Clone, Debug, PartialEq)]
pub struct MstQuery {
    pub use_tree: bool,
}

impl Default for MstQuery {
    fn default() -> Self {
        MstQuery { use_tree: true }
    }
}

/// One request against an [`crate::engine::Index`] — the union of every
/// algorithm family the shared metric tree accelerates.
#[derive(Clone, Debug, PartialEq)]
pub enum Query {
    Kmeans(KmeansQuery),
    Xmeans(XmeansQuery),
    Anomaly(AnomalyQuery),
    AllPairs(AllPairsQuery),
    Ball(BallQuery),
    BallStats(BallStatsQuery),
    Kde(KdeQuery),
    KernelRegression(KernelRegressionQuery),
    GaussianEm(GaussianEmQuery),
    Knn(KnnQuery),
    Mst(MstQuery),
}

impl Query {
    /// Stable wire/display name of the algorithm family.
    pub fn kind(&self) -> &'static str {
        match self {
            Query::Kmeans(_) => "kmeans",
            Query::Xmeans(_) => "xmeans",
            Query::Anomaly(_) => "anomaly",
            Query::AllPairs(_) => "allpairs",
            Query::Ball(_) => "ball",
            Query::BallStats(_) => "ballstats",
            Query::Kde(_) => "kde",
            Query::KernelRegression(_) => "kreg",
            Query::GaussianEm(_) => "em",
            Query::Knn(_) => "knn",
            Query::Mst(_) => "mst",
        }
    }

    /// Whether executing this query touches the metric tree (an
    /// [`crate::engine::Index`] builds its tree lazily on first need, so
    /// all-naive workloads never pay for one).
    pub fn needs_tree(&self) -> bool {
        match self {
            Query::Kmeans(q) => q.use_tree,
            Query::Xmeans(_) => true,
            Query::Anomaly(q) => q.use_tree,
            Query::AllPairs(q) => q.use_tree,
            Query::Ball(q) => q.use_tree,
            Query::BallStats(q) => q.use_tree,
            Query::Kde(q) => q.use_tree,
            Query::KernelRegression(q) => q.use_tree,
            Query::GaussianEm(q) => q.use_tree,
            Query::Knn(q) => q.use_tree,
            Query::Mst(q) => q.use_tree,
        }
    }
}

/// The algorithm-specific answer to a [`Query`]; variants correspond
/// one-to-one (verified by the dispatch round-trip test).
#[derive(Clone, Debug, PartialEq)]
pub enum QueryResult {
    Kmeans {
        centroids: Vec<Vec<f32>>,
        distortion: f64,
        iterations: usize,
    },
    Xmeans {
        centroids: Vec<Vec<f32>>,
        k: usize,
        distortion: f64,
        bic: f64,
    },
    Anomaly {
        /// The radius actually used (calibrated when the query left it
        /// unset).
        radius: f64,
        /// Ids of the anomalous points, ascending.
        anomalies: Vec<u32>,
    },
    AllPairs {
        /// (i, j) with i < j and D(i, j) ≤ tau, ascending.
        pairs: Vec<(u32, u32)>,
    },
    Ball {
        count: u64,
        mean: Vec<f32>,
        total_variance: f64,
    },
    BallStats {
        count: u64,
        mean: Vec<f32>,
        /// Per-dimension (biased) variance of the in-ball points.
        variance: Vec<f64>,
        total_variance: f64,
    },
    Kde {
        /// Estimated kernel sum Σ K(‖q − xᵢ‖).
        sum: f64,
        /// `sum / n` — density up to the kernel's normalizing constant.
        density: f64,
        /// Worst-case |sum − exact|; finite, 0 for naive evaluation.
        error_bound: f64,
    },
    KernelRegression {
        /// Nadaraya-Watson estimate ŷ(q) (0 when no weight).
        prediction: f64,
        weight_sum: f64,
        weighted_sum: f64,
        /// Worst-case |weight_sum − exact|; finite.
        weight_error_bound: f64,
        /// Worst-case |prediction − exact|; finite (saturated, never
        /// NaN/∞ — the wire layer requires representable numbers).
        value_error_bound: f64,
    },
    GaussianEm {
        weights: Vec<f64>,
        means: Vec<Vec<f32>>,
        variances: Vec<f64>,
        /// Log-likelihood after the final step.
        loglik: f64,
        steps: usize,
    },
    Knn {
        /// Ascending by distance.
        neighbors: Vec<Neighbor>,
    },
    Mst {
        edges: Vec<Edge>,
        total_weight: f64,
    },
}

impl QueryResult {
    /// Stable wire/display name; matches [`Query::kind`] of the query
    /// that produced it.
    pub fn kind(&self) -> &'static str {
        match self {
            QueryResult::Kmeans { .. } => "kmeans",
            QueryResult::Xmeans { .. } => "xmeans",
            QueryResult::Anomaly { .. } => "anomaly",
            QueryResult::AllPairs { .. } => "allpairs",
            QueryResult::Ball { .. } => "ball",
            QueryResult::BallStats { .. } => "ballstats",
            QueryResult::Kde { .. } => "kde",
            QueryResult::KernelRegression { .. } => "kreg",
            QueryResult::GaussianEm { .. } => "em",
            QueryResult::Knn { .. } => "knn",
            QueryResult::Mst { .. } => "mst",
        }
    }

    /// One-line human summary (CLI and server logs).
    pub fn summary(&self) -> String {
        match self {
            QueryResult::Kmeans { distortion, iterations, centroids } => format!(
                "kmeans: k={} distortion {distortion:.6e} after {iterations} iterations",
                centroids.len()
            ),
            QueryResult::Xmeans { k, distortion, bic, .. } => {
                format!("xmeans: chose k={k} distortion {distortion:.6e} bic {bic:.4e}")
            }
            QueryResult::Anomaly { radius, anomalies } => {
                format!("anomaly: {} anomalies at radius {radius:.4}", anomalies.len())
            }
            QueryResult::AllPairs { pairs } => format!("allpairs: {} close pairs", pairs.len()),
            QueryResult::Ball { count, total_variance, .. } => {
                format!("ball: {count} points, total variance {total_variance:.4}")
            }
            QueryResult::BallStats { count, variance, total_variance, .. } => format!(
                "ballstats: {count} points, total variance {total_variance:.4} over {} dims",
                variance.len()
            ),
            QueryResult::Kde { sum, density, error_bound } => {
                format!("kde: kernel sum {sum:.6e} (density {density:.6e} ± {error_bound:.2e})")
            }
            QueryResult::KernelRegression { prediction, weight_sum, value_error_bound, .. } => {
                format!(
                    "kreg: prediction {prediction:.6} (weight {weight_sum:.4}, ± {value_error_bound:.2e})"
                )
            }
            QueryResult::GaussianEm { loglik, steps, weights, .. } => format!(
                "em: k={} loglik {loglik:.6e} after {steps} steps",
                weights.len()
            ),
            QueryResult::Knn { neighbors } => format!("knn: {} neighbors", neighbors.len()),
            QueryResult::Mst { edges, total_weight } => {
                format!("mst: {} edges, total weight {total_weight:.4}", edges.len())
            }
        }
    }
}
