//! [`Index::run`] — the single dispatcher mapping every [`Query`]
//! variant onto the naive/tree implementation pair in
//! [`crate::algorithms`]. This is the only place in the crate that calls
//! the algorithm layer on behalf of a consumer; the CLI, coordinator and
//! server all route through here.

use super::{
    AllPairsQuery, AnomalyQuery, BallQuery, BallStatsQuery, GaussianEmQuery, Index, InitKind,
    KdeQuery, KernelRegressionQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query,
    QueryResult, XmeansQuery,
};
use crate::algorithms::{allpairs, anomaly, ballquery, gaussian, kde, kmeans, knn, mst, xmeans};
use crate::metrics::dense_dot;
use crate::parallel::{Executor, Parallelism};

impl Index {
    /// Execute one query against the shared index. Invalid inputs
    /// (dimension mismatches, out-of-range point ids) panic with a
    /// descriptive message; the coordinator turns panics into
    /// `JobState::Failed`.
    pub fn run(&self, query: &Query) -> QueryResult {
        self.run_with(query, self.executor())
    }

    /// [`Index::run`] plus the per-query [`QueryStats`] delta: the
    /// observability counters accumulated by exactly this query. The
    /// sink is shared across the index (like the distance counter), so
    /// the delta is taken by snapshotting before and after; concurrent
    /// queries on the *same* index would bleed into each other's deltas
    /// — the coordinator runs one job at a time per shard, which is the
    /// serving path this feeds. Counters are deterministic: the same
    /// query on the same index yields a bit-identical [`QueryStats`] at
    /// every thread count (see `tests/obs_equivalence.rs`).
    ///
    /// `frontier_peak` is a high-water mark, not a sum, so it is reset
    /// before the run rather than differenced.
    pub fn run_traced(&self, query: &Query) -> (QueryResult, crate::obs::QueryStats) {
        let obs = self.space().obs();
        let before = obs.snapshot();
        obs.reset_frontier_peak();
        let result = self.run(query);
        let stats = obs.snapshot().delta_from(&before);
        (result, stats)
    }

    /// [`Index::run`] with an explicit executor for the query's internal
    /// passes. Results are identical for every budget (the determinism
    /// contract of [`crate::parallel`]); `run_batch` uses this to keep
    /// per-query work serial when the batch itself already saturates the
    /// workers, while single queries reuse the index's persistent pool.
    fn run_with(&self, query: &Query, exec: &Executor) -> QueryResult {
        match query {
            Query::Kmeans(q) => self.run_kmeans(q, exec),
            Query::Xmeans(q) => self.run_xmeans(q, exec),
            Query::Anomaly(q) => self.run_anomaly(q),
            Query::AllPairs(q) => self.run_allpairs(q),
            Query::Ball(q) => self.run_ball(q),
            Query::BallStats(q) => self.run_ball_stats(q),
            Query::Kde(q) => self.run_kde(q),
            Query::KernelRegression(q) => self.run_kernel_regression(q),
            Query::GaussianEm(q) => self.run_em(q),
            Query::Knn(q) => self.run_knn(q),
            Query::Mst(q) => self.run_mst(q),
        }
    }

    /// Execute a workload of queries against the shared index,
    /// dispatching them across [`Index::parallelism`] workers. Results
    /// come back in submission order and are bitwise identical to
    /// sequential [`Index::run`] calls (each query is a deterministic
    /// function of the index — the round-trip test asserts this), so
    /// the fan-out buys throughput only. The tree is built once up
    /// front when any query needs it; the sharded distance counter
    /// keeps [`Index::dist_count`] exact under the concurrency.
    pub fn run_batch(&self, queries: &[Query]) -> Vec<QueryResult> {
        if queries.iter().any(|q| q.needs_tree()) {
            // Build once, before the fan-out. Load-bearing beyond
            // performance: tasks inside a pool epoch must never reach a
            // lazy tree *build* (see the invariant on `Index::tree`).
            self.tree();
        }
        // Divide the budget: one worker per query first, and any spare
        // threads go to each query's internal passes (a single-query
        // "batch" gets the whole budget inside the query). Results are
        // the budget-independent ones either way.
        if queries.len() == 1 {
            // A single-query "batch" gets the whole budget inside the
            // query, on the index's persistent pool.
            return vec![self.run(&queries[0])];
        }
        let budget = self.parallelism().threads();
        let workers = budget.min(queries.len()).max(1);
        let spare = budget / workers;
        // When the batch saturates the budget each query runs serial
        // inside; leftover budget goes to a scoped per-query executor.
        // Its fan-outs never broadcast (pool epochs don't nest — the
        // in-task guard is deliberately global rather than per-pool, so
        // cross-pool broadcast cycles can't deadlock), which makes one
        // shared instance safe; the cost is that this corner — batches
        // smaller than half the budget — still pays scoped spawns per
        // pass, exactly the pre-pool behavior.
        let per_query = if spare > 1 {
            Executor::new(Parallelism::Fixed(spare))
        } else {
            Executor::serial()
        };
        self.executor()
            .map_tasks(queries.len(), |i| self.run_with(&queries[i], &per_query))
    }

    fn kmeans_opts(&self) -> kmeans::KmeansOpts {
        kmeans::KmeansOpts {
            engine: self.batch_engine().cloned(),
            seed: self.seed(),
            // The *_ex entry points below take the executor explicitly
            // and never read this field; it only matters if these opts
            // are forwarded to a non-_ex entry point, where the index's
            // own budget is the right default.
            parallelism: self.parallelism(),
            ..Default::default()
        }
    }

    fn run_kmeans(&self, q: &KmeansQuery, exec: &Executor) -> QueryResult {
        let init = match q.init {
            InitKind::Random => kmeans::Init::Random,
            InitKind::Anchors => kmeans::Init::Anchors,
        };
        let (k, iters) = (q.k.max(1), q.iters.max(1));
        let opts = self.kmeans_opts();
        let r = if q.use_tree {
            kmeans::tree_lloyd_ex(self.space(), &self.tree(), init, k, iters, &opts, exec)
        } else {
            kmeans::naive_lloyd_ex(self.space(), init, k, iters, &opts, exec)
        };
        QueryResult::Kmeans {
            centroids: r.centroids,
            distortion: r.distortion,
            iterations: r.iterations,
        }
    }

    fn run_xmeans(&self, q: &XmeansQuery, exec: &Executor) -> QueryResult {
        let k_min = q.k_min.max(1);
        let k_max = q.k_max.max(k_min);
        let r = xmeans::xmeans_ex(
            self.space(),
            &self.tree(),
            k_min,
            k_max,
            &self.kmeans_opts(),
            exec,
        );
        QueryResult::Xmeans {
            centroids: r.centroids,
            k: r.k,
            distortion: r.distortion,
            bic: r.bic,
        }
    }

    fn run_anomaly(&self, q: &AnomalyQuery) -> QueryResult {
        let radius = q.radius.unwrap_or_else(|| {
            anomaly::calibrate_radius(self.space(), q.threshold, q.target_frac, 50, self.seed())
        });
        let params = anomaly::AnomalyParams { radius, threshold: q.threshold };
        let sweep = if q.use_tree {
            anomaly::tree_sweep(self.space(), &self.tree(), &params)
        } else {
            anomaly::naive_sweep(self.space(), &params)
        };
        let anomalies = sweep
            .flags
            .iter()
            .enumerate()
            .filter(|(_, &f)| f)
            .map(|(i, _)| i as u32)
            .collect();
        QueryResult::Anomaly { radius, anomalies }
    }

    fn run_allpairs(&self, q: &AllPairsQuery) -> QueryResult {
        let r = if q.use_tree {
            allpairs::tree_close_pairs(self.space(), &self.tree(), q.tau)
        } else {
            allpairs::naive_close_pairs(self.space(), q.tau)
        };
        QueryResult::AllPairs { pairs: r.pairs }
    }

    fn run_ball(&self, q: &BallQuery) -> QueryResult {
        assert_eq!(
            q.center.len(),
            self.space().dim(),
            "ball query center has dimension {} but the space has {}",
            q.center.len(),
            self.space().dim()
        );
        let stats = if q.use_tree {
            ballquery::tree_ball_stats(self.space(), &self.tree(), &q.center, q.radius)
        } else {
            ballquery::naive_ball_stats(self.space(), &q.center, q.radius)
        };
        QueryResult::Ball {
            count: stats.count,
            mean: stats.mean,
            total_variance: stats.total_variance,
        }
    }

    fn run_ball_stats(&self, q: &BallStatsQuery) -> QueryResult {
        assert_eq!(
            q.center.len(),
            self.space().dim(),
            "ballstats query center has dimension {} but the space has {}",
            q.center.len(),
            self.space().dim()
        );
        let m = if q.use_tree {
            ballquery::tree_ball_moments(self.space(), &self.tree(), &q.center, q.radius)
        } else {
            ballquery::naive_ball_moments(self.space(), &q.center, q.radius)
        };
        QueryResult::BallStats {
            count: m.count,
            mean: m.mean,
            variance: m.variance,
            total_variance: m.total_variance,
        }
    }

    /// Common validation for the kernel-family queries.
    fn check_kernel_query(&self, center: &[f32], bandwidth: f64, eps_abs: f64, eps_rel: f64) {
        assert_eq!(
            center.len(),
            self.space().dim(),
            "kernel query center has dimension {} but the space has {}",
            center.len(),
            self.space().dim()
        );
        assert!(
            bandwidth.is_finite() && bandwidth > 0.0,
            "kernel bandwidth must be a positive finite number, got {bandwidth}"
        );
        assert!(
            eps_abs.is_finite() && eps_abs >= 0.0 && eps_rel.is_finite() && eps_rel >= 0.0,
            "error budget must be non-negative and finite, got abs={eps_abs} rel={eps_rel}"
        );
    }

    fn run_kde(&self, q: &KdeQuery) -> QueryResult {
        self.check_kernel_query(&q.center, q.bandwidth, q.eps_abs, q.eps_rel);
        let budget = kde::ErrorBudget { eps_abs: q.eps_abs, eps_rel: q.eps_rel };
        let r = if q.use_tree {
            kde::tree_kde(self.space(), &self.tree(), &q.center, q.kernel, q.bandwidth, budget)
        } else {
            kde::naive_kde(self.space(), &q.center, q.kernel, q.bandwidth)
        };
        QueryResult::Kde { sum: r.sum, density: r.density, error_bound: r.error_bound }
    }

    fn run_kernel_regression(&self, q: &KernelRegressionQuery) -> QueryResult {
        self.check_kernel_query(&q.center, q.bandwidth, q.eps_abs, q.eps_rel);
        assert!(
            q.target_dim < self.space().dim(),
            "regression target dimension {} out of range (space has {} dims)",
            q.target_dim,
            self.space().dim()
        );
        let budget = kde::ErrorBudget { eps_abs: q.eps_abs, eps_rel: q.eps_rel };
        let r = if q.use_tree {
            kde::tree_kernel_regression(
                self.space(),
                &self.tree(),
                &q.center,
                q.target_dim,
                q.kernel,
                q.bandwidth,
                budget,
            )
        } else {
            kde::naive_kernel_regression(
                self.space(),
                &q.center,
                q.target_dim,
                q.kernel,
                q.bandwidth,
            )
        };
        QueryResult::KernelRegression {
            prediction: r.prediction,
            weight_sum: r.weight_sum,
            weighted_sum: r.weighted_sum,
            weight_error_bound: r.weight_error_bound,
            value_error_bound: r.value_error_bound,
        }
    }

    fn run_em(&self, q: &GaussianEmQuery) -> QueryResult {
        let k = q.k.max(1);
        let steps = q.steps.max(1);
        let seeds = match q.init {
            InitKind::Random => kmeans::random_init(self.space(), k, self.seed()),
            InitKind::Anchors => kmeans::anchors_init(self.space(), k, self.seed()),
        };
        let mut mix = gaussian::Mixture::from_seeds(seeds);
        let mut loglik = f64::NEG_INFINITY;
        if q.use_tree {
            let tree = self.tree();
            for _ in 0..steps {
                loglik = gaussian::tree_em_step(self.space(), &tree, &mut mix, q.tau);
            }
        } else {
            for _ in 0..steps {
                loglik = gaussian::naive_em_step(self.space(), &mut mix);
            }
        }
        QueryResult::GaussianEm {
            weights: mix.weights,
            means: mix.means,
            variances: mix.variances,
            loglik,
            steps,
        }
    }

    fn run_knn(&self, q: &KnnQuery) -> QueryResult {
        let space = self.space();
        let (qrow, q_sq, skip) = match &q.target {
            KnnTarget::Point(id) => {
                assert!(
                    (*id as usize) < space.n(),
                    "knn query point {id} out of range (n = {})",
                    space.n()
                );
                let mut row = vec![0f32; space.dim()];
                space.fill_row(*id as usize, &mut row);
                let sq = space.data.sqnorm(*id as usize);
                (row, sq, Some(*id))
            }
            KnnTarget::Vector(v) => {
                assert_eq!(
                    v.len(),
                    space.dim(),
                    "knn query vector has dimension {} but the space has {}",
                    v.len(),
                    space.dim()
                );
                // pallas-lint: allow(uncounted-dist, query norm staging; knn distances counted in the search)
                (v.clone(), dense_dot(v, v), None)
            }
        };
        let k = q.k.max(1);
        let neighbors = if q.use_tree {
            knn::tree_knn(space, &self.tree(), &qrow, q_sq, k, skip)
        } else {
            knn::naive_knn(space, &qrow, q_sq, k, skip)
        };
        QueryResult::Knn { neighbors }
    }

    fn run_mst(&self, q: &MstQuery) -> QueryResult {
        let edges = if q.use_tree {
            mst::tree_mst(self.space(), &self.tree())
        } else {
            mst::naive_mst(self.space())
        };
        let total_weight = mst::total_weight(&edges);
        QueryResult::Mst { edges, total_weight }
    }
}
