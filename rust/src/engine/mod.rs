//! The engine facade: **build one index, run many queries**.
//!
//! The paper's thesis is that a single metric tree decorated with cached
//! sufficient statistics accelerates a *wide variety* of statistical
//! algorithms. This module is that thesis as an API. An [`IndexBuilder`]
//! captures everything needed to stand an index up (dataset, tree
//! strategy, leaf threshold, seed, optional XLA batch engine); the
//! resulting [`Index`] owns the [`Space`] (with its distance counter)
//! and the [`MetricTree`], and answers every [`Query`] variant through
//! one dispatcher, [`Index::run`]:
//!
//! ```
//! use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
//! use anchors_hierarchy::engine::{IndexBuilder, KmeansQuery, Query, QueryResult};
//! use anchors_hierarchy::parallel::Parallelism;
//!
//! let index = IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.004))
//!     .rmin(16)
//!     .parallelism(Parallelism::Fixed(2)) // tree build + queries may use 2 workers
//!     .build();
//! let result = index.run(&Query::Kmeans(KmeansQuery { k: 5, iters: 3, ..Default::default() }));
//! let QueryResult::Kmeans { distortion, .. } = result else { panic!("wrong variant") };
//! assert!(distortion.is_finite());
//! assert!(index.dist_count() > 0);
//! ```
//!
//! Design points:
//!
//! * **Build once, query many.** The expensive parts — materializing the
//!   dataset and building the tree — happen once per index; every query
//!   family (k-means, x-means, anomaly, all-pairs, ball stats, Gaussian
//!   EM, k-NN, MST) then shares them. [`Index::run_batch`] amortizes a
//!   whole workload over one index.
//! * **Lazy tree.** The tree is built on first need, so a workload of
//!   naive-baseline queries (every options struct has a `use_tree`
//!   switch) never pays for a build.
//! * **Exact accounting.** The index owns the space's distance counter;
//!   [`Index::dist_count`] exposes it so callers (the coordinator, the
//!   bench harness) can attribute distance computations to queries. The
//!   counter is sharded per thread, so counts stay exact when builds and
//!   batches run on many workers.
//! * **Deterministic parallelism.** [`IndexBuilder::parallelism`] sets
//!   the worker budget for the tree build, the k-means/x-means passes,
//!   and [`Index::run_batch`]'s query fan-out. Every thread count yields
//!   bit-identical trees and results (see [`crate::parallel`]).
//! * **One implementation layer.** The dispatcher calls the same
//!   `naive_*` / `tree_*` free functions in [`crate::algorithms`] that
//!   the paper-table benches measure; the facade adds routing, not
//!   logic. The CLI, the batch [`crate::coordinator`], and the TCP
//!   server all construct work as [`Query`] values and execute them
//!   here, and the [`wire`] module gives every query and result a JSON
//!   form for the network boundary.

mod dispatch;
pub mod query;
pub mod wire;

pub use query::{
    AllPairsQuery, AnomalyQuery, BallQuery, BallStatsQuery, GaussianEmQuery, InitKind, KdeQuery,
    KernelRegressionQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query, QueryResult,
    XmeansQuery,
};

use crate::dataset::DatasetSpec;
use crate::metrics::Space;
use crate::parallel::{Executor, Parallelism};
use crate::runtime::BatchDistanceEngine;
use crate::tree::middle_out::{self, MiddleOutConfig};
use crate::tree::{top_down, MetricTree};
use std::sync::{Arc, Mutex};

/// How the index's metric tree is constructed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TreeStrategy {
    /// Middle-out via the anchors hierarchy (§3 of the paper; default).
    MiddleOut,
    /// Classic top-down splitting (§2.2 baseline).
    TopDown,
}

impl TreeStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            TreeStrategy::MiddleOut => "middle-out",
            TreeStrategy::TopDown => "top-down",
        }
    }

    pub fn parse(name: &str) -> Option<TreeStrategy> {
        match name {
            "middle-out" => Some(TreeStrategy::MiddleOut),
            "top-down" => Some(TreeStrategy::TopDown),
            _ => None,
        }
    }
}

/// Everything needed to stand up an [`Index`]. All knobs default
/// sensibly: middle-out tree, `rmin = 30` (the paper's Table-2 leaf
/// threshold), the dataset's own seed, no batch engine.
#[derive(Clone)]
pub struct IndexBuilder {
    dataset: DatasetSpec,
    strategy: TreeStrategy,
    rmin: usize,
    seed: Option<u64>,
    exact_radii: bool,
    batch_engine: Option<Arc<BatchDistanceEngine>>,
    parallelism: Parallelism,
    f32_tier: Option<bool>,
}

impl IndexBuilder {
    pub fn new(dataset: DatasetSpec) -> IndexBuilder {
        IndexBuilder {
            dataset,
            strategy: TreeStrategy::MiddleOut,
            rmin: 30,
            seed: None,
            exact_radii: false,
            batch_engine: None,
            parallelism: Parallelism::default(),
            f32_tier: None,
        }
    }

    /// Tree construction strategy (default middle-out).
    pub fn strategy(mut self, strategy: TreeStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Leaf threshold for the tree (default 30).
    pub fn rmin(mut self, rmin: usize) -> Self {
        self.rmin = rmin;
        self
    }

    /// Seed for tree construction and query-level randomness (centroid
    /// initialization). Defaults to the dataset's seed, so an index is a
    /// deterministic function of its builder.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Recompute exact node radii after the middle-out build.
    pub fn exact_radii(mut self, exact: bool) -> Self {
        self.exact_radii = exact;
        self
    }

    /// Optional XLA batch engine for dense leaf-level distance blocks.
    pub fn batch_engine(mut self, engine: Option<Arc<BatchDistanceEngine>>) -> Self {
        self.batch_engine = engine;
        self
    }

    /// Worker budget for the tree build, the parallel assignment passes
    /// and [`Index::run_batch`]. Defaults to `PALLAS_THREADS` when set,
    /// else one worker per hardware thread; results are bit-identical
    /// for every setting.
    pub fn parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// Explicitly enable/disable the f32 filter tier
    /// ([`Space::set_f32_tier`]) on the space this builder materializes,
    /// overriding the `PALLAS_F32_TIER` environment default applied by
    /// [`DatasetSpec::build`]. Results are bit-identical either way —
    /// the tier only changes how many evaluations run in f64 vs f32
    /// ([`Index::f32_dist_count`]).
    pub fn with_f32_tier(mut self, on: bool) -> Self {
        self.f32_tier = Some(on);
        self
    }

    /// Materialize the dataset and wrap it in an [`Index`]. The tree is
    /// built lazily, on the first query that needs it.
    pub fn build(self) -> Index {
        let mut space = self.dataset.build();
        if let Some(on) = self.f32_tier {
            space.set_f32_tier(on);
        }
        let space = Arc::new(space);
        self.build_on(space)
    }

    /// Wrap an already-materialized space (e.g. the coordinator's
    /// dataset cache) without rebuilding it. The space's existing
    /// f32-tier flag governs; a [`Self::with_f32_tier`] override is not
    /// applied here (the space may be shared with other indexes).
    pub fn build_on(self, space: Arc<Space>) -> Index {
        let seed = self.seed.unwrap_or(self.dataset.seed);
        Index {
            space,
            tree: Mutex::new(None),
            strategy: self.strategy,
            rmin: self.rmin,
            exact_radii: self.exact_radii,
            batch_engine: self.batch_engine,
            seed,
            executor: Executor::new(self.parallelism),
            parallelism: self.parallelism,
        }
    }
}

/// A built index: the space, its (lazily built) metric tree, and the
/// distance counter — the shared substrate every [`Query`] runs on.
pub struct Index {
    space: Arc<Space>,
    tree: Mutex<Option<Arc<MetricTree>>>,
    strategy: TreeStrategy,
    rmin: usize,
    exact_radii: bool,
    batch_engine: Option<Arc<BatchDistanceEngine>>,
    seed: u64,
    /// The index's persistent worker pool: tree builds, the parallel
    /// query passes and `run_batch` all fan out here, so repeated
    /// queries never re-pay thread spawn/join.
    executor: Executor,
    parallelism: Parallelism,
}

impl Index {
    /// Assemble an index from pre-built parts (used by the coordinator's
    /// dataset/tree caches — each shard of a
    /// [`crate::coordinator::ShardedCoordinator`] assembles its jobs'
    /// views this way over its own cache). The tree is considered
    /// already built; `rmin` must be the leaf threshold it was actually
    /// built with so [`Index::rmin`] reports the truth.
    pub fn from_parts(
        space: Arc<Space>,
        tree: Arc<MetricTree>,
        batch_engine: Option<Arc<BatchDistanceEngine>>,
        seed: u64,
        rmin: usize,
    ) -> Index {
        let parallelism = Parallelism::default();
        Index {
            space,
            tree: Mutex::new(Some(tree)),
            strategy: TreeStrategy::MiddleOut,
            rmin,
            exact_radii: false,
            batch_engine,
            seed,
            executor: Executor::new(parallelism),
            parallelism,
        }
    }

    /// Replace the worker budget (used by the coordinator, which keeps
    /// per-job work serial by default so its own worker pool provides
    /// the concurrency). This also replaces the executor — prefer
    /// [`Index::with_executor`] when a long-lived pool already exists.
    pub fn with_parallelism(mut self, parallelism: Parallelism) -> Index {
        self.parallelism = parallelism;
        self.executor = Executor::new(parallelism);
        self
    }

    /// Adopt an existing executor (and its persistent worker pool), so
    /// many indexes — e.g. every job the coordinator assembles over a
    /// cached dataset — share one set of parked worker threads.
    pub fn with_executor(mut self, executor: Executor) -> Index {
        self.parallelism = Parallelism::Fixed(executor.threads());
        self.executor = executor;
        self
    }

    /// The executor queries and builds fan out on.
    pub fn executor(&self) -> &Executor {
        &self.executor
    }

    /// The worker budget builds and batches run with.
    pub fn parallelism(&self) -> Parallelism {
        self.parallelism
    }

    pub fn space(&self) -> &Space {
        &self.space
    }

    /// Shared handle to the space (for callers that cache it).
    pub fn space_shared(&self) -> Arc<Space> {
        Arc::clone(&self.space)
    }

    /// The metric tree, building it on first use.
    ///
    /// The built tree carries the tree-order memory layout
    /// ([`crate::tree::Layout`]): a permuted copy of the dataset (the
    /// *arena*, sharing this index's distance counter) in which every
    /// leaf is one contiguous row range, so leaf scans stream
    /// sequential slabs instead of gathering scattered rows. All ids
    /// crossing the query boundary — results out, point targets in —
    /// remain dataset ids; translation happens inside the algorithms
    /// through zero-cost layout views, and results are bit-identical
    /// to the pre-layout gather path (`tests/layout_equivalence.rs`).
    /// The price is one extra resident copy of the dataset per built
    /// tree.
    ///
    /// Lock-ordering invariant: the build runs under the tree mutex and
    /// broadcasts on this index's worker pool, so it must never be
    /// *reached* from inside a pool epoch — a task blocking on this
    /// mutex would keep its epoch open while the builder waits for the
    /// broadcast channel. [`Index::run_batch`] upholds this by
    /// materializing the tree before fanning out (and
    /// [`crate::engine::Query::needs_tree`] covers every dispatch path
    /// that touches the tree); the debug assertion catches any future
    /// path that breaks the invariant.
    pub fn tree(&self) -> Arc<MetricTree> {
        let mut guard = self.tree.lock().unwrap();
        if let Some(tree) = guard.as_ref() {
            return Arc::clone(tree);
        }
        debug_assert!(
            !crate::parallel::in_pool_task(),
            "lazy tree build reached from inside a pool epoch — pre-build \
             the tree before fanning out (see Index::run_batch)"
        );
        let tree = Arc::new(match self.strategy {
            TreeStrategy::MiddleOut => middle_out::build_ex(
                &self.space,
                &MiddleOutConfig {
                    rmin: self.rmin,
                    seed: self.seed,
                    exact_radii: self.exact_radii,
                    parallelism: self.parallelism,
                },
                &self.executor,
            ),
            TreeStrategy::TopDown => {
                top_down::build_ex(&self.space, self.rmin, &self.executor)
            }
        });
        *guard = Some(Arc::clone(&tree));
        tree
    }

    /// Whether the tree has been built yet.
    pub fn tree_built(&self) -> bool {
        self.tree.lock().unwrap().is_some()
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn rmin(&self) -> usize {
        self.rmin
    }

    pub fn batch_engine(&self) -> Option<&Arc<BatchDistanceEngine>> {
        self.batch_engine.as_ref()
    }

    /// Total distance computations charged to this index's space
    /// (monotonic; includes the tree build once it happens).
    pub fn dist_count(&self) -> u64 {
        self.space.dist_count()
    }

    /// f32 filter-tier evaluations charged to this index's space —
    /// reported separately from [`Index::dist_count`] so the Table-2
    /// f64 budget stays comparable across tiers (0 when the tier is
    /// off).
    pub fn f32_dist_count(&self) -> u64 {
        self.space.f32_dist_count()
    }

    /// Whether the index's space has the f32 filter tier enabled.
    pub fn f32_tier(&self) -> bool {
        self.space.f32_tier()
    }

    /// Lifetime observability counters charged to this index's space
    /// (monotonic sums across every query run so far, like
    /// [`Index::dist_count`]). For per-query deltas use
    /// [`Index::run_traced`].
    pub fn obs_stats(&self) -> crate::obs::QueryStats {
        self.space.obs().snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;

    fn tiny_builder() -> IndexBuilder {
        IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.004))
    }

    #[test]
    fn tree_is_lazy_and_cached() {
        let index = tiny_builder().rmin(16).build();
        assert!(!index.tree_built(), "tree built eagerly");
        let before = index.dist_count();
        let t1 = index.tree();
        assert!(index.tree_built());
        assert!(index.dist_count() > before, "build did no counted work");
        let mid = index.dist_count();
        let t2 = index.tree();
        assert!(Arc::ptr_eq(&t1, &t2), "tree rebuilt on second access");
        assert_eq!(index.dist_count(), mid, "second access re-paid the build");
    }

    #[test]
    fn naive_query_never_builds_tree() {
        let index = tiny_builder().build();
        let q = Query::Kmeans(KmeansQuery { k: 3, iters: 2, use_tree: false, ..Default::default() });
        let _ = index.run(&q);
        assert!(!index.tree_built(), "naive query built the tree");
    }

    #[test]
    fn strategies_differ_but_both_serve_queries() {
        for strategy in [TreeStrategy::MiddleOut, TreeStrategy::TopDown] {
            let index = tiny_builder().strategy(strategy).rmin(16).build();
            let r = index.run(&Query::Kmeans(KmeansQuery { k: 4, iters: 3, ..Default::default() }));
            assert_eq!(r.kind(), "kmeans");
        }
    }

    #[test]
    fn f32_tier_knob_flows_to_the_space() {
        let index = tiny_builder().with_f32_tier(true).build();
        assert!(index.f32_tier());
        assert_eq!(index.f32_dist_count(), 0, "no f32 work before any query");
        // Explicit off must win even under a PALLAS_F32_TIER=1 env (the
        // CI tier pass runs this very test with the env set).
        let off = tiny_builder().with_f32_tier(false).build();
        assert!(!off.f32_tier(), "explicit off lost to the env default");
    }

    #[test]
    fn from_parts_reuses_the_given_tree() {
        let built = tiny_builder().rmin(16).build();
        let tree = built.tree();
        let index = Index::from_parts(built.space_shared(), Arc::clone(&tree), None, 7, 16);
        assert!(index.tree_built());
        assert!(Arc::ptr_eq(&index.tree(), &tree));
        assert_eq!(index.rmin(), 16);
    }
}
