//! A small property-based-testing driver (the environment is offline, so
//! the `proptest` crate is unavailable; this provides the same workflow:
//! many random cases per property, deterministic seeds, and failure
//! reports that include the reproducing seed).

use crate::rng::Rng;

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of property `f`. Each case gets a fresh
/// deterministic [`Rng`]; on failure the panic message carries the seed so
/// `check_with_seed` can replay it.
pub fn check(name: &str, cases: u64, f: impl Fn(&mut Rng) -> CaseResult) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (replay seed: {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay one case by explicit seed (for debugging failures).
pub fn check_with_seed(name: &str, seed: u64, f: impl Fn(&mut Rng) -> CaseResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property {name:?} failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper producing `CaseResult`s.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // Interior mutability via a cell since f is Fn.
        let counter = std::cell::Cell::new(0u64);
        check("always-true", 25, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always-false", 5, |_| Err("nope".into()));
    }

    #[test]
    fn cases_see_different_randomness() {
        let seen = std::cell::RefCell::new(std::collections::HashSet::new());
        check("distinct-streams", 20, |rng| {
            seen.borrow_mut().insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.borrow().len(), 20);
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = || {
            let v = std::cell::RefCell::new(Vec::new());
            check("det", 5, |rng| {
                v.borrow_mut().push(rng.next_u64());
                Ok(())
            });
            v.into_inner()
        };
        assert_eq!(collect(), collect());
    }

    #[test]
    fn prop_assert_macro() {
        check("macro", 3, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 10, "x was {x}");
            Ok(())
        });
    }
}
