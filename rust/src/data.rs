//! Point storage: dense row-major and sparse CSR matrices.
//!
//! Both carry cached per-row squared norms so Euclidean distances can use
//! the expansion `||x-y||² = ||x||² + ||y||² − 2x·y` — the same identity
//! the Pallas kernel (python/compile/kernels/pairwise.py) uses, which is
//! what makes the scalar path and the XLA path bit-compatible up to f32
//! rounding.

/// Largest |value| in a slice, for the f32 filter tier's error bound.
/// Any non-finite entry (±inf or NaN) maps to +inf, which makes
/// [`crate::metrics::block::F32Filter::new`] decline deterministically.
pub(crate) fn max_abs_of(values: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &v in values {
        let a = v.abs();
        if a > m {
            m = a;
        }
        if !a.is_finite() {
            m = f32::INFINITY;
        }
    }
    m
}

/// Dense row-major f32 matrix.
#[derive(Clone, Debug)]
pub struct DenseMatrix {
    pub n: usize,
    pub d: usize,
    pub values: Vec<f32>,
    /// Cached ||row_i||² in f64.
    sqnorms: Vec<f64>,
    /// Cached ||row_i||² rounded to f32 (`sqnorms[i] as f32`) — the
    /// sidecar the f32 filter tier reads. Derived, never recomputed, so
    /// it is a pure function of `sqnorms` and stays bit-consistent
    /// across `select_rows` copies.
    sqnorms32: Vec<f32>,
    /// Cached max|value| over the whole matrix (the `M` of the filter
    /// tier's ε bound). +inf if any entry is non-finite.
    max_abs: f32,
}

impl DenseMatrix {
    pub fn new(n: usize, d: usize, values: Vec<f32>) -> Self {
        assert_eq!(values.len(), n * d, "shape mismatch");
        let sqnorms: Vec<f64> = (0..n)
            .map(|i| {
                values[i * d..(i + 1) * d]
                    .iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum()
            })
            .collect();
        let sqnorms32 = sqnorms.iter().map(|&s| s as f32).collect();
        let max_abs = max_abs_of(&values);
        DenseMatrix { n, d, values, sqnorms, sqnorms32, max_abs }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let n = rows.len();
        let d = rows.first().map_or(0, |r| r.len());
        let mut values = Vec::with_capacity(n * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged rows");
            values.extend_from_slice(r);
        }
        DenseMatrix::new(n, d, values)
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.values[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn sqnorm(&self, i: usize) -> f64 {
        self.sqnorms[i]
    }

    /// The f32-rounded cached squared norm (filter-tier sidecar).
    #[inline]
    pub fn sqnorm32(&self, i: usize) -> f32 {
        self.sqnorms32[i]
    }

    /// Cached max|value| over the matrix (+inf if any non-finite entry).
    #[inline]
    pub fn max_abs(&self) -> f32 {
        self.max_abs
    }

    /// One contiguous slab of rows plus the matching cached squared
    /// norms — the zero-gather view the contiguous leaf-scan kernels
    /// read ([`crate::metrics::block`]). Values are `(hi−lo)·d` floats
    /// in storage order.
    #[inline]
    pub fn rows_slab(&self, rows: std::ops::Range<usize>) -> (&[f32], &[f64]) {
        (
            &self.values[rows.start * self.d..rows.end * self.d],
            &self.sqnorms[rows],
        )
    }

    /// [`Self::rows_slab`] with the f32 norm sidecar instead of the f64
    /// norms — what the f32 filter-tier kernel streams.
    #[inline]
    pub fn rows_slab_f32(&self, rows: std::ops::Range<usize>) -> (&[f32], &[f32]) {
        (
            &self.values[rows.start * self.d..rows.end * self.d],
            &self.sqnorms32[rows],
        )
    }

    /// Copy the listed rows (in order, repeats allowed) into a new
    /// matrix. Cached norms (f64 and f32 sidecar) are copied, not
    /// recomputed, so the selected rows are bit-identical to the
    /// originals in every cached quantity. `max_abs` is copied from the
    /// parent too: an upper bound over a row subset is still an upper
    /// bound, and copying keeps the arena's filter ε bit-equal to the
    /// original space's.
    pub fn select_rows(&self, ids: &[u32]) -> DenseMatrix {
        let mut values = Vec::with_capacity(ids.len() * self.d);
        let mut sqnorms = Vec::with_capacity(ids.len());
        let mut sqnorms32 = Vec::with_capacity(ids.len());
        for &i in ids {
            values.extend_from_slice(self.row(i as usize));
            sqnorms.push(self.sqnorms[i as usize]);
            sqnorms32.push(self.sqnorms32[i as usize]);
        }
        DenseMatrix {
            n: ids.len(),
            d: self.d,
            values,
            sqnorms,
            sqnorms32,
            max_abs: self.max_abs,
        }
    }

    /// L2-normalize every row in place (zero rows are left untouched).
    /// Turns Euclidean distance into the cosine-equivalent metric
    /// `sqrt(2 - 2 cos)` — used for bag-of-words data.
    pub fn normalize_rows(&mut self) {
        for i in 0..self.n {
            let norm = self.sqnorms[i].sqrt();
            if norm > 0.0 {
                for v in &mut self.values[i * self.d..(i + 1) * self.d] {
                    *v = (*v as f64 / norm) as f32;
                }
                self.sqnorms[i] = self.values[i * self.d..(i + 1) * self.d]
                    .iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum();
                self.sqnorms32[i] = self.sqnorms[i] as f32;
            }
        }
        self.max_abs = max_abs_of(&self.values);
    }

    /// Transpose (attributes become points — §4.3 of the paper).
    pub fn transpose(&self) -> DenseMatrix {
        let mut values = vec![0f32; self.n * self.d];
        for i in 0..self.n {
            for j in 0..self.d {
                values[j * self.n + i] = self.values[i * self.d + j];
            }
        }
        DenseMatrix::new(self.d, self.n, values)
    }

    /// Normalize each *column* to zero mean and unit L2 norm, so that
    /// for the transposed matrix `ρ(x,y) = 1 − D²(x*,y*)/2` (paper eq. 8).
    pub fn standardize_columns(&mut self) {
        for j in 0..self.d {
            let mut mean = 0.0f64;
            for i in 0..self.n {
                mean += self.values[i * self.d + j] as f64;
            }
            mean /= self.n as f64;
            let mut ss = 0.0f64;
            for i in 0..self.n {
                let v = self.values[i * self.d + j] as f64 - mean;
                ss += v * v;
            }
            let scale = if ss > 0.0 { 1.0 / ss.sqrt() } else { 0.0 };
            for i in 0..self.n {
                let v = self.values[i * self.d + j] as f64;
                self.values[i * self.d + j] = ((v - mean) * scale) as f32;
            }
        }
        // Re-derive row norms.
        *self = DenseMatrix::new(self.n, self.d, std::mem::take(&mut self.values));
    }
}

/// Sparse CSR f32 matrix (for bag-of-words / high-dimensional binary data).
#[derive(Clone, Debug)]
pub struct SparseMatrix {
    pub n: usize,
    pub d: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    sqnorms: Vec<f64>,
    /// f32-rounded cached norms — the filter-tier sidecar (see
    /// [`DenseMatrix::sqnorm32`]).
    sqnorms32: Vec<f32>,
    /// Cached max|stored value| (+inf if any non-finite entry). Absent
    /// entries are 0, so this bounds every coordinate.
    max_abs: f32,
}

impl SparseMatrix {
    /// Build from per-row (index, value) pair lists. Indices within a row
    /// must be strictly increasing.
    pub fn from_rows(d: usize, rows: &[Vec<(u32, f32)>]) -> Self {
        let n = rows.len();
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        let mut sqnorms = Vec::with_capacity(n);
        indptr.push(0);
        for row in rows {
            let mut prev: i64 = -1;
            let mut sq = 0.0f64;
            for &(idx, val) in row {
                assert!((idx as usize) < d, "column index out of range");
                assert!((idx as i64) > prev, "row indices must be increasing");
                prev = idx as i64;
                indices.push(idx);
                values.push(val);
                sq += (val as f64) * (val as f64);
            }
            indptr.push(indices.len());
            sqnorms.push(sq);
        }
        let sqnorms32 = sqnorms.iter().map(|&s| s as f32).collect();
        let max_abs = max_abs_of(&values);
        SparseMatrix { n, d, indptr, indices, values, sqnorms, sqnorms32, max_abs }
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    #[inline]
    pub fn sqnorm(&self, i: usize) -> f64 {
        self.sqnorms[i]
    }

    /// The f32-rounded cached squared norm (filter-tier sidecar).
    #[inline]
    pub fn sqnorm32(&self, i: usize) -> f32 {
        self.sqnorms32[i]
    }

    /// Cached max|stored value| (+inf if any non-finite entry).
    #[inline]
    pub fn max_abs(&self) -> f32 {
        self.max_abs
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Copy the listed rows (in order, repeats allowed) into a new CSR
    /// matrix. Per-row index/value segments and cached norms are copied
    /// verbatim, so the selected rows are bit-identical to the
    /// originals.
    pub fn select_rows(&self, ids: &[u32]) -> SparseMatrix {
        let nnz: usize = ids
            .iter()
            .map(|&i| self.indptr[i as usize + 1] - self.indptr[i as usize])
            .sum();
        let mut indptr = Vec::with_capacity(ids.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        let mut sqnorms = Vec::with_capacity(ids.len());
        let mut sqnorms32 = Vec::with_capacity(ids.len());
        indptr.push(0);
        for &i in ids {
            let (idx, val) = self.row(i as usize);
            indices.extend_from_slice(idx);
            values.extend_from_slice(val);
            indptr.push(indices.len());
            sqnorms.push(self.sqnorms[i as usize]);
            sqnorms32.push(self.sqnorms32[i as usize]);
        }
        SparseMatrix {
            n: ids.len(),
            d: self.d,
            indptr,
            indices,
            values,
            sqnorms,
            sqnorms32,
            max_abs: self.max_abs,
        }
    }

    /// Sparse·sparse dot product (merge join on sorted indices).
    pub fn dot_rows(&self, i: usize, j: usize) -> f64 {
        let (ia, va) = self.row(i);
        let (ib, vb) = self.row(j);
        let (mut p, mut q) = (0usize, 0usize);
        let mut acc = 0.0f64;
        while p < ia.len() && q < ib.len() {
            match ia[p].cmp(&ib[q]) {
                std::cmp::Ordering::Less => p += 1,
                std::cmp::Ordering::Greater => q += 1,
                std::cmp::Ordering::Equal => {
                    acc += va[p] as f64 * vb[q] as f64;
                    p += 1;
                    q += 1;
                }
            }
        }
        acc
    }

    /// Sparse·dense dot product against an arbitrary vector.
    #[inline]
    pub fn dot_vec(&self, i: usize, q: &[f32]) -> f64 {
        let (idx, val) = self.row(i);
        let mut acc = 0.0f64;
        for (&j, &v) in idx.iter().zip(val) {
            acc += v as f64 * q[j as usize] as f64;
        }
        acc
    }

    /// [`Self::dot_vec`] entirely in f32 — the filter-tier form. A
    /// single-accumulator chain of ≤ nnz(i) ≤ d adds, which the filter's
    /// error bound ([`crate::metrics::block::f32_eps`]) covers with the
    /// same `N = d + 16` term it uses for the 8-lane dense kernel.
    #[inline]
    pub fn dot_vec_f32(&self, i: usize, q: &[f32]) -> f32 {
        let (idx, val) = self.row(i);
        let mut acc = 0.0f32;
        for (&j, &v) in idx.iter().zip(val) {
            acc += v * q[j as usize];
        }
        acc
    }

    /// Densify one row into `out` (zero-filled first). `out.len()` may
    /// exceed `d` (feature-hashed padding is the caller's business).
    pub fn fill_row(&self, i: usize, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = 0.0;
        }
        let (idx, val) = self.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            out[j as usize] = v;
        }
    }

    /// Feature-hash to a dense matrix of width `w` (signed hashing to keep
    /// inner products approximately preserved). Used to feed the fixed-D
    /// XLA variants with reuters-sized data.
    pub fn hash_to_dense(&self, w: usize) -> DenseMatrix {
        let mut values = vec![0f32; self.n * w];
        for i in 0..self.n {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                // splitmix-style mix of the column id.
                let mut h = (j as u64).wrapping_add(0x9E3779B97F4A7C15);
                h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
                h ^= h >> 31;
                let bucket = (h % w as u64) as usize;
                let sign = if (h >> 63) == 0 { 1.0f32 } else { -1.0f32 };
                values[i * w + bucket] += sign * v;
            }
        }
        DenseMatrix::new(self.n, w, values)
    }
}

/// The dataset payload handed to [`crate::metrics::Space`].
#[derive(Clone, Debug)]
pub enum Data {
    Dense(DenseMatrix),
    Sparse(SparseMatrix),
}

impl Data {
    pub fn n(&self) -> usize {
        match self {
            Data::Dense(m) => m.n,
            Data::Sparse(m) => m.n,
        }
    }
    pub fn dim(&self) -> usize {
        match self {
            Data::Dense(m) => m.d,
            Data::Sparse(m) => m.d,
        }
    }
    pub fn sqnorm(&self, i: usize) -> f64 {
        match self {
            Data::Dense(m) => m.sqnorm(i),
            Data::Sparse(m) => m.sqnorm(i),
        }
    }
    /// f32-rounded cached squared norm (filter-tier sidecar).
    pub fn sqnorm32(&self, i: usize) -> f32 {
        match self {
            Data::Dense(m) => m.sqnorm32(i),
            Data::Sparse(m) => m.sqnorm32(i),
        }
    }
    /// Cached max|value| (+inf if any entry is non-finite).
    pub fn max_abs(&self) -> f32 {
        match self {
            Data::Dense(m) => m.max_abs(),
            Data::Sparse(m) => m.max_abs(),
        }
    }
    pub fn is_sparse(&self) -> bool {
        matches!(self, Data::Sparse(_))
    }

    /// Copy the listed rows (in order) into a new payload of the same
    /// kind — the permutation primitive behind the tree-order arena
    /// ([`crate::tree::Layout`]).
    pub fn select_rows(&self, ids: &[u32]) -> Data {
        match self {
            Data::Dense(m) => Data::Dense(m.select_rows(ids)),
            Data::Sparse(m) => Data::Sparse(m.select_rows(ids)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_rows_and_norms() {
        let m = DenseMatrix::new(2, 3, vec![1.0, 2.0, 2.0, 0.0, 3.0, 4.0]);
        assert_eq!(m.row(0), &[1.0, 2.0, 2.0]);
        assert_eq!(m.sqnorm(0), 9.0);
        assert_eq!(m.sqnorm(1), 25.0);
        assert_eq!(m.sqnorm32(0), 9.0f32);
        assert_eq!(m.max_abs(), 4.0);
        let (slab, norms32) = m.rows_slab_f32(0..2);
        assert_eq!(slab.len(), 6);
        assert_eq!(norms32, &[9.0f32, 25.0]);
    }

    #[test]
    fn max_abs_flags_non_finite() {
        assert_eq!(max_abs_of(&[1.0, -3.5, 2.0]), 3.5);
        assert_eq!(max_abs_of(&[]), 0.0);
        assert_eq!(max_abs_of(&[1.0, f32::NAN, 99.0]), f32::INFINITY);
        assert_eq!(max_abs_of(&[f32::NEG_INFINITY, 1.0]), f32::INFINITY);
    }

    #[test]
    fn f32_sidecars_survive_select_and_normalize() {
        let m = DenseMatrix::new(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.sqnorm32(0).to_bits(), m.sqnorm32(2).to_bits());
        assert_eq!(s.max_abs(), m.max_abs(), "subset copies the parent bound");
        let mut nm = m.clone();
        nm.normalize_rows();
        assert_eq!(nm.sqnorm32(1), nm.sqnorm(1) as f32);
        assert!(nm.max_abs() <= 1.0 + f32::EPSILON);
    }

    #[test]
    fn sparse_dot_vec_f32_matches_f64() {
        let rows = vec![vec![(0u32, 1.5f32), (2, -2.0)], vec![(1u32, 3.0f32)]];
        let m = SparseMatrix::from_rows(4, &rows);
        let q = [2.0f32, -1.0, 0.5, 9.0];
        assert_eq!(m.dot_vec_f32(0, &q) as f64, m.dot_vec(0, &q));
        assert_eq!(m.dot_vec_f32(1, &q) as f64, m.dot_vec(1, &q));
        assert_eq!(m.sqnorm32(0), m.sqnorm(0) as f32);
        assert_eq!(m.max_abs(), 3.0);
        let s = m.select_rows(&[1]);
        assert_eq!(s.max_abs(), 3.0);
        assert_eq!(s.sqnorm32(0).to_bits(), m.sqnorm32(1).to_bits());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = DenseMatrix::new(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!((t.n, t.d), (3, 2));
        assert_eq!(t.row(0), &[1., 4.]);
        let tt = t.transpose();
        assert_eq!(tt.values, m.values);
    }

    #[test]
    fn standardize_columns_gives_unit_norm_zero_mean() {
        let mut m = DenseMatrix::new(4, 2, vec![1., 10., 2., 20., 3., 30., 4., 45.]);
        m.standardize_columns();
        for j in 0..2 {
            let mean: f64 = (0..4).map(|i| m.values[i * 2 + j] as f64).sum::<f64>() / 4.0;
            let ss: f64 = (0..4).map(|i| (m.values[i * 2 + j] as f64).powi(2)).sum();
            assert!(mean.abs() < 1e-6, "mean {mean}");
            assert!((ss - 1.0).abs() < 1e-5, "ss {ss}");
        }
    }

    #[test]
    fn correlation_distance_identity() {
        // paper eq. (8): rho = 1 - D^2/2 after standardization.
        let mut m = DenseMatrix::new(
            5,
            2,
            vec![1., 2., 2., 4.2, 3., 5.8, 4., 8.1, 5., 9.9],
        );
        // plain correlation first
        let xs: Vec<f64> = (0..5).map(|i| m.values[i * 2] as f64).collect();
        let ys: Vec<f64> = (0..5).map(|i| m.values[i * 2 + 1] as f64).collect();
        let mx = xs.iter().sum::<f64>() / 5.0;
        let my = ys.iter().sum::<f64>() / 5.0;
        let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let sx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum::<f64>().sqrt();
        let sy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum::<f64>().sqrt();
        let rho = cov / (sx * sy);

        m.standardize_columns();
        let t = m.transpose();
        let d2: f64 = (0..5)
            .map(|i| (t.row(0)[i] as f64 - t.row(1)[i] as f64).powi(2))
            .sum();
        assert!((rho - (1.0 - d2 / 2.0)).abs() < 1e-5, "rho {rho} vs {}", 1.0 - d2 / 2.0);
    }

    #[test]
    fn sparse_dot_and_norms() {
        let rows = vec![
            vec![(0u32, 1.0f32), (3, 2.0)],
            vec![(1u32, 3.0f32), (3, 4.0)],
            vec![],
        ];
        let m = SparseMatrix::from_rows(5, &rows);
        assert_eq!(m.n, 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.sqnorm(0), 5.0);
        assert_eq!(m.dot_rows(0, 1), 8.0);
        assert_eq!(m.dot_rows(0, 2), 0.0);
        assert_eq!(m.dot_vec(1, &[1., 1., 1., 1., 1.]), 7.0);
    }

    #[test]
    fn sparse_fill_row() {
        let m = SparseMatrix::from_rows(4, &[vec![(1, 2.0), (3, -1.0)]]);
        let mut out = vec![9.0f32; 6];
        m.fill_row(0, &mut out);
        assert_eq!(out, vec![0.0, 2.0, 0.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn hash_to_dense_preserves_norms_approximately() {
        // Signed feature hashing preserves E[||x||^2]; with few collisions
        // (nnz << width) norms match almost exactly.
        let rows = vec![
            vec![(0u32, 1.0f32), (100, 2.0), (4000, 3.0)],
            vec![(7u32, 1.5f32), (2000, 2.5)],
        ];
        let m = SparseMatrix::from_rows(4732, &rows);
        let dm = m.hash_to_dense(1024);
        assert!((dm.sqnorm(0) - 14.0).abs() < 1e-6);
        assert!((dm.sqnorm(1) - 8.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "increasing")]
    fn sparse_rejects_unsorted() {
        SparseMatrix::from_rows(4, &[vec![(2, 1.0), (1, 1.0)]]);
    }

    #[test]
    fn dense_select_rows_is_bit_exact() {
        let m = DenseMatrix::new(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = m.select_rows(&[2, 0, 2]);
        assert_eq!((s.n, s.d), (3, 2));
        assert_eq!(s.row(0), m.row(2));
        assert_eq!(s.row(1), m.row(0));
        assert_eq!(s.sqnorm(0).to_bits(), m.sqnorm(2).to_bits());
        assert_eq!(s.sqnorm(2).to_bits(), m.sqnorm(2).to_bits());
        let (slab, norms) = s.rows_slab(1..3);
        assert_eq!(slab, &[1., 2., 5., 6.]);
        assert_eq!(norms.len(), 2);
    }

    #[test]
    fn sparse_select_rows_is_bit_exact() {
        let rows = vec![
            vec![(0u32, 1.0f32), (3, 2.0)],
            vec![(1u32, 3.0f32)],
            vec![],
        ];
        let m = SparseMatrix::from_rows(5, &rows);
        let s = m.select_rows(&[1, 2, 0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.row(0), m.row(1));
        assert_eq!(s.row(1), m.row(2));
        assert_eq!(s.row(2), m.row(0));
        assert_eq!(s.sqnorm(2).to_bits(), m.sqnorm(0).to_bits());
        assert_eq!(s.nnz(), 3);
    }
}
