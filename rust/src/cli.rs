//! Hand-rolled CLI argument parsing (offline environment — no clap).
//!
//! Grammar: `anchors-hierarchy <command> [--flag value]...`. Flags are
//! typed at the call site; unknown flags are an error listing the valid
//! set.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    used: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut it = args.into_iter();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(format!("expected --flag, found {arg:?}"));
            };
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else {
                let value = it
                    .next()
                    .ok_or_else(|| format!("flag --{name} expects a value"))?;
                flags.insert(name.to_string(), value);
            }
        }
        Ok(Args {
            command,
            flags,
            used: std::cell::RefCell::new(std::collections::BTreeSet::new()),
        })
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    fn raw(&self, name: &str) -> Option<&str> {
        self.used.borrow_mut().insert(name.to_string());
        self.flags.get(name).map(String::as_str)
    }

    /// String flag with default.
    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.raw(name).unwrap_or(default).to_string()
    }

    /// Optional string flag.
    pub fn opt_str(&self, name: &str) -> Option<String> {
        self.raw(name).map(str::to_string)
    }

    /// Typed flag with default.
    pub fn flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.raw(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name}: cannot parse {v:?}: {e}")),
        }
    }

    /// Boolean flag (`--x true|false|1|0`).
    pub fn bool_flag(&self, name: &str, default: bool) -> Result<bool, String> {
        match self.raw(name) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format!("--{name}: expected bool, found {v:?}")),
        }
    }

    /// Call after reading all flags: errors on unknown flags (typo guard).
    pub fn finish(&self) -> Result<(), String> {
        let used = self.used.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !used.contains(*k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown flag(s) {:?}; valid flags for this command: {:?}",
                unknown,
                used.iter().collect::<Vec<_>>()
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("table2 --scale 0.1 --rmin 30");
        assert_eq!(a.command, "table2");
        assert_eq!(a.flag("scale", 1.0f64).unwrap(), 0.1);
        assert_eq!(a.flag("rmin", 5usize).unwrap(), 30);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form() {
        let a = parse("kmeans --k=7");
        assert_eq!(a.flag("k", 0usize).unwrap(), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("kmeans");
        assert_eq!(a.flag("k", 3usize).unwrap(), 3);
        assert_eq!(a.str_flag("dataset", "cell"), "cell");
        assert!(a.bool_flag("tree", true).unwrap());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(vec!["x".into(), "--k".into()]).is_err());
    }

    #[test]
    fn non_flag_errors() {
        assert!(Args::parse(vec!["x".into(), "k".into()]).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("kmeans --k 3 --typo 1");
        let _ = a.flag("k", 0usize);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_parse_reports_flag() {
        let a = parse("kmeans --k abc");
        let err = a.flag("k", 0usize).unwrap_err();
        assert!(err.contains("--k"), "{err}");
    }

    #[test]
    fn bool_parsing() {
        let a = parse("x --t true --f 0");
        assert!(a.bool_flag("t", false).unwrap());
        assert!(!a.bool_flag("f", true).unwrap());
        let a = parse("x --b maybe");
        assert!(a.bool_flag("b", false).is_err());
    }
}
