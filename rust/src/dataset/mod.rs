//! The Table-1 dataset suite.
//!
//! Real `cell` / `covtype` / `reuters` files are not available in this
//! environment, so each is replaced by a synthetic surrogate that
//! preserves the property the paper's evaluation leans on (see DESIGN.md
//! §Substitutions): cluster structure for cell/covtype, *absence* of
//! structure for reuters (that is what produces the paper's anti-speedup),
//! sparse mixtures for genM-ki, and 2-d manifold/filament structure for
//! squiggles/voronoi.
//!
//! Every generator is deterministic in its seed; `DatasetSpec::scale`
//! shrinks row counts uniformly so the full Table-2 sweep stays tractable
//! on one machine while preserving each dataset's structure.

pub mod io;
mod sparse_gen;
mod synthetic;

pub use sparse_gen::{gen_mixture, reuters_surrogate};
pub use synthetic::{
    cell_surrogate, covtype_surrogate, figure1, gaussian_mixture, squiggles, voronoi,
};

use crate::data::Data;
use crate::metrics::Space;

/// Identifies one dataset of Table 1.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetKind {
    Squiggles,
    Voronoi,
    Cell,
    Covtype,
    /// reuters100 (full surrogate) — `half: true` gives reuters50.
    Reuters { half: bool },
    /// genM-ki: `dims` ∈ {100, 1000, 10000}, `components` = i.
    Gen { dims: usize, components: usize },
    /// The Figure-1 two-class spreadsheet.
    Figure1,
}

impl DatasetKind {
    pub fn parse(name: &str) -> Option<DatasetKind> {
        match name {
            "squiggles" => Some(DatasetKind::Squiggles),
            "voronoi" => Some(DatasetKind::Voronoi),
            "cell" => Some(DatasetKind::Cell),
            "covtype" => Some(DatasetKind::Covtype),
            "reuters100" => Some(DatasetKind::Reuters { half: false }),
            "reuters50" => Some(DatasetKind::Reuters { half: true }),
            "figure1" => Some(DatasetKind::Figure1),
            _ => {
                // genM-ki, e.g. gen100-k3
                let rest = name.strip_prefix("gen")?;
                let (dims, k) = rest.split_once("-k")?;
                Some(DatasetKind::Gen {
                    dims: dims.parse().ok()?,
                    components: k.parse().ok()?,
                })
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            DatasetKind::Squiggles => "squiggles".into(),
            DatasetKind::Voronoi => "voronoi".into(),
            DatasetKind::Cell => "cell".into(),
            DatasetKind::Covtype => "covtype".into(),
            DatasetKind::Reuters { half: false } => "reuters100".into(),
            DatasetKind::Reuters { half: true } => "reuters50".into(),
            DatasetKind::Gen { dims, components } => format!("gen{dims}-k{components}"),
            DatasetKind::Figure1 => "figure1".into(),
        }
    }

    /// Paper row count (Table 1).
    pub fn paper_rows(&self) -> usize {
        match self {
            DatasetKind::Squiggles | DatasetKind::Voronoi => 80_000,
            DatasetKind::Cell => 39_972,
            DatasetKind::Covtype => 150_000,
            DatasetKind::Reuters { half: false } => 10_077,
            DatasetKind::Reuters { half: true } => 5_038,
            DatasetKind::Gen { .. } => 100_000,
            DatasetKind::Figure1 => 100_000,
        }
    }

    pub fn dims(&self) -> usize {
        match self {
            DatasetKind::Squiggles | DatasetKind::Voronoi => 2,
            DatasetKind::Cell => 38,
            DatasetKind::Covtype => 54,
            DatasetKind::Reuters { .. } => 4_732,
            DatasetKind::Gen { dims, .. } => *dims,
            DatasetKind::Figure1 => 1_000,
        }
    }
}

/// A fully-specified dataset build request.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub kind: DatasetKind,
    /// Row-count multiplier in (0, 1]; 1.0 = the paper's size.
    pub scale: f64,
    pub seed: u64,
}

impl DatasetSpec {
    pub fn new(kind: DatasetKind) -> Self {
        DatasetSpec { kind, scale: 1.0, seed: 20130 }
    }

    pub fn scaled(kind: DatasetKind, scale: f64) -> Self {
        DatasetSpec { kind, scale, seed: 20130 }
    }

    pub fn rows(&self) -> usize {
        ((self.kind.paper_rows() as f64 * self.scale).round() as usize).max(16)
    }

    /// Generate the dataset as a [`Space`] (Euclidean). The
    /// `PALLAS_F32_TIER` environment default is applied here — the one
    /// chokepoint every materialization path (CLI, coordinator, server,
    /// [`crate::engine::IndexBuilder::build`]) flows through — so the
    /// CI `PALLAS_F32_TIER=1` pass drives the whole suite through the
    /// filter tier. An explicit
    /// [`crate::engine::IndexBuilder::with_f32_tier`] overrides it.
    pub fn build(&self) -> Space {
        let r = self.rows();
        let seed = self.seed;
        let data: Data = match &self.kind {
            DatasetKind::Squiggles => Data::Dense(squiggles(r, seed)),
            DatasetKind::Voronoi => Data::Dense(voronoi(r, seed)),
            DatasetKind::Cell => Data::Dense(cell_surrogate(r, seed)),
            DatasetKind::Covtype => Data::Dense(covtype_surrogate(r, seed)),
            DatasetKind::Reuters { .. } => {
                Data::Sparse(reuters_surrogate(r, self.kind.dims(), seed))
            }
            DatasetKind::Gen { dims, components } => {
                Data::Sparse(gen_mixture(r, *dims, *components, seed))
            }
            DatasetKind::Figure1 => Data::Dense(figure1(r, seed).0),
        };
        let mut space = Space::euclidean(data);
        space.set_f32_tier(default_f32_tier().unwrap_or_else(|e| panic!("{e}")));
        space
    }
}

/// `PALLAS_F32_TIER` environment default: unset ⇒ off; `1`/`true` ⇒ on;
/// `0`/`false` ⇒ off. A variable that is *set but unrecognized* is a
/// loud `Err`, never a silent fallback — the CI `PALLAS_F32_TIER=1`
/// pass exists to exercise the filter tier, and quietly degrading to
/// off would turn that coverage green while testing nothing (same
/// contract as [`crate::coordinator::shard::default_shards`]).
pub fn default_f32_tier() -> Result<bool, String> {
    parse_f32_tier(std::env::var("PALLAS_F32_TIER").ok().as_deref())
}

fn parse_f32_tier(raw: Option<&str>) -> Result<bool, String> {
    match raw {
        None => Ok(false),
        Some(raw) => match raw.trim() {
            "1" | "true" => Ok(true),
            "0" | "false" => Ok(false),
            other => Err(format!(
                "$PALLAS_F32_TIER: expected 1/0/true/false, got {other:?}"
            )),
        },
    }
}

/// All Table-2 datasets, in paper order (figure1 excluded — it has its own
/// experiment).
pub fn table2_datasets() -> Vec<DatasetKind> {
    vec![
        DatasetKind::Squiggles,
        DatasetKind::Voronoi,
        DatasetKind::Cell,
        DatasetKind::Covtype,
        DatasetKind::Reuters { half: true },
        DatasetKind::Reuters { half: false },
        DatasetKind::Gen { dims: 100, components: 3 },
        DatasetKind::Gen { dims: 100, components: 20 },
        DatasetKind::Gen { dims: 100, components: 100 },
        DatasetKind::Gen { dims: 1000, components: 3 },
        DatasetKind::Gen { dims: 1000, components: 20 },
        DatasetKind::Gen { dims: 1000, components: 100 },
        DatasetKind::Gen { dims: 10000, components: 3 },
        DatasetKind::Gen { dims: 10000, components: 20 },
        DatasetKind::Gen { dims: 10000, components: 100 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for kind in table2_datasets() {
            let name = kind.name();
            assert_eq!(DatasetKind::parse(&name), Some(kind.clone()), "{name}");
        }
        assert_eq!(DatasetKind::parse("figure1"), Some(DatasetKind::Figure1));
        assert_eq!(DatasetKind::parse("nope"), None);
        assert_eq!(DatasetKind::parse("genx-ky"), None);
    }

    #[test]
    fn scaled_rows() {
        let spec = DatasetSpec::scaled(DatasetKind::Squiggles, 0.01);
        assert_eq!(spec.rows(), 800);
        let spec = DatasetSpec::scaled(DatasetKind::Cell, 1.0);
        assert_eq!(spec.rows(), 39_972);
    }

    #[test]
    fn builds_have_declared_shapes() {
        for kind in [
            DatasetKind::Squiggles,
            DatasetKind::Voronoi,
            DatasetKind::Cell,
            DatasetKind::Covtype,
            DatasetKind::Reuters { half: false },
            DatasetKind::Gen { dims: 100, components: 3 },
        ] {
            let spec = DatasetSpec::scaled(kind.clone(), 0.005);
            let space = spec.build();
            assert_eq!(space.n(), spec.rows(), "{}", kind.name());
            assert_eq!(space.dim(), kind.dims(), "{}", kind.name());
        }
    }

    #[test]
    fn f32_tier_env_values_parse_loudly() {
        // Pure-parse test: mutating the real env would race with the
        // parallel test harness.
        assert_eq!(parse_f32_tier(None), Ok(false));
        assert_eq!(parse_f32_tier(Some("1")), Ok(true));
        assert_eq!(parse_f32_tier(Some(" true ")), Ok(true));
        assert_eq!(parse_f32_tier(Some("0")), Ok(false));
        assert_eq!(parse_f32_tier(Some("false")), Ok(false));
        assert!(parse_f32_tier(Some("yes")).is_err());
        assert!(parse_f32_tier(Some("")).is_err());
    }

    #[test]
    fn deterministic_by_seed() {
        let a = DatasetSpec::scaled(DatasetKind::Cell, 0.005).build();
        let b = DatasetSpec::scaled(DatasetKind::Cell, 0.005).build();
        assert_eq!(a.n(), b.n());
        for i in 0..a.n().min(20) {
            assert_eq!(a.dist_uncounted(0, i), b.dist_uncounted(0, i));
        }
    }
}
