//! Sparse synthetic generators: the Reuters bag-of-words surrogate and
//! the paper's genM-ki sparse mixtures.

use crate::data::SparseMatrix;
use crate::rng::{Rng, ZipfTable};

/// Reuters bag-of-words surrogate (Table 1: 10077 docs × 4732 terms).
///
/// The paper's finding for this dataset is an ANTI-speedup: bag-of-words
/// news text has too little metric structure for the tree to exploit at
/// 10k documents. We therefore deliberately generate documents with *no
/// topic structure*: every document draws its terms i.i.d. from one global
/// Zipf(1.1) vocabulary distribution, with log-scaled term frequencies and
/// L2 row normalization (the standard cosine-style preprocessing). What
/// remains is exactly the structureless high-dimensional cloud whose
/// behaviour the paper reports.
pub fn reuters_surrogate(rows: usize, vocab: usize, seed: u64) -> SparseMatrix {
    let mut rng = Rng::new(seed);
    let zipf = ZipfTable::new(vocab, 1.1);
    let mut doc_rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(rows);
    let mut counts: std::collections::BTreeMap<u32, u32> = std::collections::BTreeMap::new();
    for _ in 0..rows {
        counts.clear();
        // Document length: lognormal-ish, mean ≈ 90 tokens.
        let len = (30.0 + rng.normal().mul_add(30.0, 60.0).max(0.0)) as usize;
        for _ in 0..len {
            let term = zipf.sample(&mut rng) as u32;
            *counts.entry(term).or_insert(0) += 1;
        }
        // log(1 + tf) weights, then L2 normalize.
        let mut row: Vec<(u32, f32)> = counts
            .iter()
            .map(|(&t, &c)| (t, (1.0 + c as f32).ln()))
            .collect();
        let norm: f32 = row.iter().map(|&(_, v)| v * v).sum::<f32>().sqrt();
        if norm > 0.0 {
            for v in row.iter_mut() {
                v.1 /= norm;
            }
        }
        doc_rows.push(row);
    }
    SparseMatrix::from_rows(vocab, &doc_rows)
}

/// genM-ki (Table 1): "artificially generated sparse data in M dimensions,
/// generated from a mixture of i components".
///
/// Each component activates a random ~5% subset of the M dimensions; a
/// point from that component sets each active dimension to 1 w.p. 0.9 and
/// each inactive dimension to 1 w.p. 0.002 (background noise). The high
/// within-support probability makes the i modes strongly separated —
/// within-component distances are several times smaller than
/// cross-component ones, which is the regime in which the paper's gen
/// rows show their very large speedups. K-means runs use k = i (the
/// paper restricts gen experiments to the matching k).
pub fn gen_mixture(rows: usize, dims: usize, components: usize, seed: u64) -> SparseMatrix {
    let mut rng = Rng::new(seed);
    let active_frac = 0.05;
    let active_count = ((dims as f64 * active_frac) as usize).max(2);
    let noise_p = 0.002;
    let active_p = 0.9;
    // Component supports.
    let supports: Vec<Vec<usize>> = (0..components)
        .map(|_| {
            let mut s = rng.sample_indices(dims, active_count);
            s.sort_unstable();
            s
        })
        .collect();
    let mut out_rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(rows);
    let mut row_set: Vec<u32> = Vec::new();
    for _ in 0..rows {
        let c = rng.below(components);
        row_set.clear();
        // Active dims: dense Bernoulli over the small support.
        for &j in &supports[c] {
            if rng.bool(active_p) {
                row_set.push(j as u32);
            }
        }
        // Background noise: expected dims*noise_p extra ones, sampled via
        // a binomial-count + uniform-position scheme (O(nnz), not O(M)).
        let extra = binomial_sample(&mut rng, dims, noise_p);
        for _ in 0..extra {
            row_set.push(rng.below(dims) as u32);
        }
        row_set.sort_unstable();
        row_set.dedup();
        out_rows.push(row_set.iter().map(|&j| (j, 1.0f32)).collect());
    }
    SparseMatrix::from_rows(dims, &out_rows)
}

/// Sample Binomial(n, p) — normal approximation for large n·p, direct
/// Bernoulli summation for small (exact where it matters).
fn binomial_sample(rng: &mut Rng, n: usize, p: f64) -> usize {
    let mean = n as f64 * p;
    if mean < 30.0 {
        // Inverse-CDF via waiting times (geometric skips): O(np).
        let mut count = 0usize;
        let mut i = 0f64;
        let log_q = (1.0 - p).ln();
        loop {
            let skip = (rng.f64().ln() / log_q).floor();
            i += skip + 1.0;
            if i > n as f64 {
                return count;
            }
            count += 1;
        }
    } else {
        let sd = (mean * (1.0 - p)).sqrt();
        (rng.normal_ms(mean, sd).round().clamp(0.0, n as f64)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Data;
    use crate::metrics::Space;

    #[test]
    fn reuters_rows_normalized_and_sparse() {
        let m = reuters_surrogate(300, 4732, 1);
        assert_eq!((m.n, m.d), (300, 4732));
        for i in 0..m.n {
            let sq = m.sqnorm(i);
            assert!((sq - 1.0).abs() < 1e-4, "row {i} norm² = {sq}");
        }
        // Sparse: far fewer nonzeros than dense.
        assert!(m.nnz() < 300 * 200, "nnz {}", m.nnz());
    }

    #[test]
    fn reuters_lacks_cluster_structure() {
        // Pairwise distances should concentrate (ratio of 10th percentile
        // to 90th percentile close to 1) — the "no structure" regime.
        let m = reuters_surrogate(200, 2000, 2);
        let space = Space::euclidean(Data::Sparse(m));
        let mut ds: Vec<f64> = Vec::new();
        for i in 0..60 {
            for j in (i + 1)..60 {
                ds.push(space.dist_uncounted(i, j));
            }
        }
        ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p10 = ds[ds.len() / 10];
        let p90 = ds[ds.len() * 9 / 10];
        assert!(p90 / p10 < 1.35, "distances too spread: {p10} .. {p90}");
    }

    #[test]
    fn gen_mixture_shapes_and_sparsity() {
        let m = gen_mixture(500, 1000, 20, 3);
        assert_eq!((m.n, m.d), (500, 1000));
        // Expected nnz per row ≈ 0.05·1000·0.5 + 0.01·1000 = 35.
        let mean_nnz = m.nnz() as f64 / 500.0;
        assert!((20.0..55.0).contains(&mean_nnz), "mean nnz {mean_nnz}");
    }

    #[test]
    fn gen_mixture_has_components() {
        // Same-component points share active dims → markedly closer than
        // cross-component pairs on average.
        let m = gen_mixture(600, 500, 3, 4);
        let space = Space::euclidean(Data::Sparse(m));
        // Estimate: nearest-neighbor distance vs random-pair distance.
        let mut nn = 0.0;
        let mut rnd = 0.0;
        for i in 0..30 {
            let mut best = f64::INFINITY;
            for j in 0..space.n() {
                if i != j {
                    best = best.min(space.dist_uncounted(i, j));
                }
            }
            nn += best;
            rnd += space.dist_uncounted(i, space.n() - 1 - i);
        }
        assert!(nn / 30.0 < rnd / 30.0, "nn {} !< rnd {}", nn / 30.0, rnd / 30.0);
    }

    #[test]
    fn binomial_sampler_mean() {
        let mut rng = Rng::new(5);
        // Small-mean path.
        let mut acc = 0usize;
        for _ in 0..2000 {
            acc += binomial_sample(&mut rng, 1000, 0.01);
        }
        let mean = acc as f64 / 2000.0;
        assert!((mean - 10.0).abs() < 0.8, "small-path mean {mean}");
        // Large-mean path.
        let mut acc = 0usize;
        for _ in 0..2000 {
            acc += binomial_sample(&mut rng, 10000, 0.01);
        }
        let mean = acc as f64 / 2000.0;
        assert!((mean - 100.0).abs() < 3.0, "large-path mean {mean}");
    }
}
