//! Dataset import/export: CSV for dense data, a sparse triplet text
//! format for sparse data. Lets users bring their own data to the CLI
//! (`--dataset file:path.csv`) and lets the generators persist datasets
//! for external analysis.

use crate::data::{Data, DenseMatrix, SparseMatrix};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Save dense data as headerless CSV (one row per line).
pub fn save_dense_csv(m: &DenseMatrix, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    let mut line = String::new();
    for i in 0..m.n {
        line.clear();
        for (j, v) in m.row(i).iter().enumerate() {
            if j > 0 {
                line.push(',');
            }
            line.push_str(&format!("{v}"));
        }
        line.push('\n');
        w.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// Load headerless CSV as dense data. Rejects ragged rows with a line
/// number in the error.
pub fn load_dense_csv(path: impl AsRef<Path>) -> Result<DenseMatrix> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let reader = std::io::BufReader::new(f);
    let mut values: Vec<f32> = Vec::new();
    let mut d = None;
    let mut n = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row: Vec<f32> = line
            .split(',')
            .map(|tok| {
                tok.trim()
                    .parse::<f32>()
                    .map_err(|e| anyhow!("line {}: bad value {tok:?}: {e}", lineno + 1))
            })
            .collect::<Result<_>>()?;
        match d {
            None => d = Some(row.len()),
            Some(d0) if d0 != row.len() => {
                bail!("line {}: ragged row ({} vs {} columns)", lineno + 1, row.len(), d0)
            }
            _ => {}
        }
        values.extend_from_slice(&row);
        n += 1;
    }
    let d = d.ok_or_else(|| anyhow!("empty CSV"))?;
    Ok(DenseMatrix::new(n, d, values))
}

/// Save sparse data as a triplet format:
/// line 1: `n d nnz`, then one `row col value` per line (0-based).
pub fn save_sparse_triplets(m: &SparseMatrix, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "{} {} {}", m.n, m.d, m.nnz())?;
    for i in 0..m.n {
        let (idx, val) = m.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            writeln!(w, "{i} {j} {v}")?;
        }
    }
    Ok(())
}

/// Load the triplet format written by [`save_sparse_triplets`].
pub fn load_sparse_triplets(path: impl AsRef<Path>) -> Result<SparseMatrix> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let reader = std::io::BufReader::new(f);
    let mut lines = reader.lines();
    let header = lines.next().ok_or_else(|| anyhow!("empty file"))??;
    let parts: Vec<usize> = header
        .split_whitespace()
        .map(|t| t.parse().map_err(|e| anyhow!("bad header: {e}")))
        .collect::<Result<_>>()?;
    let [n, d, nnz] = parts.as_slice() else {
        bail!("header must be `n d nnz`");
    };
    let mut rows: Vec<Vec<(u32, f32)>> = vec![Vec::new(); *n];
    let mut seen = 0usize;
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(i), Some(j), Some(v)) = (it.next(), it.next(), it.next()) else {
            bail!("line {}: expected `row col value`", lineno + 2);
        };
        let i: usize = i.parse().map_err(|e| anyhow!("line {}: {e}", lineno + 2))?;
        let j: u32 = j.parse().map_err(|e| anyhow!("line {}: {e}", lineno + 2))?;
        let v: f32 = v.parse().map_err(|e| anyhow!("line {}: {e}", lineno + 2))?;
        if i >= *n || (j as usize) >= *d {
            bail!("line {}: index ({i},{j}) out of bounds", lineno + 2);
        }
        rows[i].push((j, v));
        seen += 1;
    }
    if seen != *nnz {
        bail!("nnz mismatch: header says {nnz}, file has {seen}");
    }
    for row in rows.iter_mut() {
        row.sort_unstable_by_key(|&(j, _)| j);
    }
    Ok(SparseMatrix::from_rows(*d, &rows))
}

/// Load either format based on extension: `.csv` → dense, `.spm` → sparse.
pub fn load_auto(path: impl AsRef<Path>) -> Result<Data> {
    let p = path.as_ref();
    match p.extension().and_then(|e| e.to_str()) {
        Some("csv") => Ok(Data::Dense(load_dense_csv(p)?)),
        Some("spm") => Ok(Data::Sparse(load_sparse_triplets(p)?)),
        other => bail!("unknown dataset extension {other:?} (want .csv or .spm)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{gen_mixture, squiggles};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ah-io-{}-{name}", std::process::id()))
    }

    #[test]
    fn dense_csv_roundtrip() {
        let m = squiggles(200, 1);
        let path = tmp("dense.csv");
        save_dense_csv(&m, &path).unwrap();
        let back = load_dense_csv(&path).unwrap();
        assert_eq!((back.n, back.d), (m.n, m.d));
        for i in 0..m.n {
            for (a, b) in m.row(i).iter().zip(back.row(i)) {
                assert!((a - b).abs() <= f32::EPSILON * a.abs().max(1.0));
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sparse_triplet_roundtrip() {
        let m = gen_mixture(150, 500, 3, 2);
        let path = tmp("sparse.spm");
        save_sparse_triplets(&m, &path).unwrap();
        let back = load_sparse_triplets(&path).unwrap();
        assert_eq!((back.n, back.d), (m.n, m.d));
        assert_eq!(back.nnz(), m.nnz());
        for i in 0..m.n {
            assert_eq!(m.row(i), back.row(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let path = tmp("ragged.csv");
        std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
        let err = load_dense_csv(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let path = tmp("comments.csv");
        std::fs::write(&path, "# header comment\n1,2\n\n3,4\n").unwrap();
        let m = load_dense_csv(&path).unwrap();
        assert_eq!((m.n, m.d), (2, 2));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn triplets_reject_bad_counts_and_bounds() {
        let path = tmp("bad.spm");
        std::fs::write(&path, "2 3 2\n0 0 1.0\n").unwrap();
        assert!(load_sparse_triplets(&path).unwrap_err().to_string().contains("nnz"));
        std::fs::write(&path, "2 3 1\n5 0 1.0\n").unwrap();
        assert!(load_sparse_triplets(&path)
            .unwrap_err()
            .to_string()
            .contains("out of bounds"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_auto_dispatches() {
        let m = squiggles(20, 3);
        let path = tmp("auto.csv");
        save_dense_csv(&m, &path).unwrap();
        assert!(matches!(load_auto(&path).unwrap(), Data::Dense(_)));
        std::fs::remove_file(&path).ok();
        assert!(load_auto(tmp("nope.xyz")).is_err());
    }
}
