//! Dense synthetic generators: the 2-d manifold datasets, the
//! cell/covtype surrogates, and the Figure-1 two-class spreadsheet.

use crate::data::DenseMatrix;
use crate::rng::Rng;

/// `squiggles` (Table 1): "two dimensional data generated from blurred
/// one-dimensional manifolds". We draw a handful of random smooth curves
/// (random-phase sinusoid mixtures along a random direction) and blur
/// points sampled uniformly along them.
pub fn squiggles(rows: usize, seed: u64) -> DenseMatrix {
    let mut rng = Rng::new(seed);
    let n_curves = 12.max(rows / 10_000);
    // Each curve: start point, heading, sinusoid amplitude/frequency mix.
    struct Curve {
        x0: f64,
        y0: f64,
        dir: f64,
        amp: [f64; 3],
        freq: [f64; 3],
        phase: [f64; 3],
        length: f64,
    }
    let curves: Vec<Curve> = (0..n_curves)
        .map(|_| Curve {
            x0: rng.uniform(-100.0, 100.0),
            y0: rng.uniform(-100.0, 100.0),
            dir: rng.uniform(0.0, std::f64::consts::TAU),
            amp: [rng.uniform(1.0, 8.0), rng.uniform(0.5, 4.0), rng.uniform(0.2, 2.0)],
            freq: [rng.uniform(0.02, 0.1), rng.uniform(0.1, 0.3), rng.uniform(0.3, 0.8)],
            phase: [
                rng.uniform(0.0, std::f64::consts::TAU),
                rng.uniform(0.0, std::f64::consts::TAU),
                rng.uniform(0.0, std::f64::consts::TAU),
            ],
            length: rng.uniform(40.0, 120.0),
        })
        .collect();
    let blur = 0.6;
    let mut values = Vec::with_capacity(rows * 2);
    for _ in 0..rows {
        let c = &curves[rng.below(curves.len())];
        let t = rng.uniform(0.0, c.length);
        let offset: f64 = (0..3)
            .map(|i| c.amp[i] * (c.freq[i] * t + c.phase[i]).sin())
            .sum();
        let (sin, cos) = c.dir.sin_cos();
        // point = start + t*direction + offset*normal + blur noise
        let x = c.x0 + t * cos - offset * sin + blur * rng.normal();
        let y = c.y0 + t * sin + offset * cos + blur * rng.normal();
        values.push(x as f32);
        values.push(y as f32);
    }
    DenseMatrix::new(rows, 2, values)
}

/// `voronoi` (Table 1): "two dimensional data with noisy filaments".
/// We scatter sites, then sample points near the perpendicular bisectors
/// of neighboring site pairs — the edges of the Voronoi diagram — with
/// noise.
pub fn voronoi(rows: usize, seed: u64) -> DenseMatrix {
    let mut rng = Rng::new(seed);
    let n_sites = 24;
    let sites: Vec<(f64, f64)> = (0..n_sites)
        .map(|_| (rng.uniform(-100.0, 100.0), rng.uniform(-100.0, 100.0)))
        .collect();
    // Candidate edges: each site paired with its 3 nearest neighbors.
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for i in 0..n_sites {
        let mut ds: Vec<(f64, usize)> = (0..n_sites)
            .filter(|&j| j != i)
            .map(|j| {
                let dx = sites[i].0 - sites[j].0;
                let dy = sites[i].1 - sites[j].1;
                (dx * dx + dy * dy, j)
            })
            .collect();
        ds.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for &(_, j) in ds.iter().take(3) {
            if i < j {
                edges.push((i, j));
            } else {
                edges.push((j, i));
            }
        }
    }
    edges.sort();
    edges.dedup();

    let noise = 1.2;
    let mut values = Vec::with_capacity(rows * 2);
    for _ in 0..rows {
        let &(i, j) = &edges[rng.below(edges.len())];
        let (ax, ay) = sites[i];
        let (bx, by) = sites[j];
        // Midpoint of the pair; bisector direction is perpendicular to ab.
        let (mx, my) = ((ax + bx) / 2.0, (ay + by) / 2.0);
        let (dx, dy) = (bx - ax, by - ay);
        let len = (dx * dx + dy * dy).sqrt().max(1e-9);
        let (px, py) = (-dy / len, dx / len); // unit perpendicular
        let t = rng.normal() * len * 0.35; // walk along the bisector
        let x = mx + t * px + noise * rng.normal();
        let y = my + t * py + noise * rng.normal();
        values.push(x as f32);
        values.push(y as f32);
    }
    DenseMatrix::new(rows, 2, values)
}

/// `cell` surrogate: 38 visual features from high-throughput screening.
/// Modeled as a 12-component Gaussian mixture with per-component diagonal
/// covariances of widely varying scale plus a shared random linear map —
/// heavy cluster structure in moderate dimension, the regime where the
/// paper reports solid metric-tree speedups.
pub fn cell_surrogate(rows: usize, seed: u64) -> DenseMatrix {
    gaussian_mixture(rows, 38, 12, 6.0, seed)
}

/// `covtype` surrogate: 54 features, 7 cover types. Mixture of 7 clusters
/// over 10 continuous dims (varying scales, like elevation/distances) with
/// 44 near-binary indicator dims tied to the component.
pub fn covtype_surrogate(rows: usize, seed: u64) -> DenseMatrix {
    let mut rng = Rng::new(seed);
    let k = 7;
    let d_cont = 10;
    let d_bin = 44;
    let d = d_cont + d_bin;
    // Component definitions.
    let mut means = Vec::new();
    let mut scales = Vec::new();
    let mut bin_probs = Vec::new();
    for _ in 0..k {
        means.push((0..d_cont).map(|_| rng.uniform(-40.0, 40.0)).collect::<Vec<f64>>());
        scales.push((0..d_cont).map(|_| rng.uniform(0.5, 8.0)).collect::<Vec<f64>>());
        // Each component activates a few indicator blocks strongly.
        bin_probs.push(
            (0..d_bin)
                .map(|_| if rng.bool(0.15) { rng.uniform(0.6, 0.95) } else { rng.uniform(0.0, 0.08) })
                .collect::<Vec<f64>>(),
        );
    }
    let weights: Vec<f64> = (0..k).map(|_| rng.uniform(0.5, 2.0)).collect();
    let mut values = Vec::with_capacity(rows * d);
    for _ in 0..rows {
        let c = rng.categorical(&weights);
        for j in 0..d_cont {
            values.push(rng.normal_ms(means[c][j], scales[c][j]) as f32);
        }
        for j in 0..d_bin {
            values.push(if rng.bool(bin_probs[c][j]) { 1.0 } else { 0.0 });
        }
    }
    DenseMatrix::new(rows, d, values)
}

/// Generic axis-aligned Gaussian mixture with a shared random rotation-ish
/// mixing matrix (adds cross-dimension correlation so kd-trees can't just
/// split single dimensions).
pub fn gaussian_mixture(rows: usize, d: usize, k: usize, spread: f64, seed: u64) -> DenseMatrix {
    let mut rng = Rng::new(seed);
    let means: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.uniform(-spread, spread)).collect())
        .collect();
    let scales: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.uniform(0.2, 1.5)).collect())
        .collect();
    let weights: Vec<f64> = (0..k).map(|_| rng.uniform(0.5, 2.0)).collect();
    // Sparse random mixing: each output dim blends 2 latent dims.
    let mix: Vec<(usize, usize, f64)> = (0..d)
        .map(|j| (j, rng.below(d), rng.uniform(0.1, 0.5)))
        .collect();
    let mut values = Vec::with_capacity(rows * d);
    let mut latent = vec![0f64; d];
    for _ in 0..rows {
        let c = rng.categorical(&weights);
        for j in 0..d {
            latent[j] = rng.normal_ms(means[c][j], scales[c][j]);
        }
        for &(a, b, w) in &mix {
            values.push((latent[a] + w * latent[b]) as f32);
        }
    }
    DenseMatrix::new(rows, d, values)
}

/// The Figure-1 spreadsheet: two classes, 1000 binary attributes.
/// Class A: attrs 0..200 are 1 w.p. 1/3; class B: w.p. 2/3; attrs
/// 200..1000 are 1 w.p. 1/2 for everyone. Returns (data, labels).
pub fn figure1(rows: usize, seed: u64) -> (DenseMatrix, Vec<u8>) {
    figure1_dims(rows, 1000, 200, seed)
}

/// Parameterized variant (smaller widths for fast tests).
pub fn figure1_dims(
    rows: usize,
    d: usize,
    informative: usize,
    seed: u64,
) -> (DenseMatrix, Vec<u8>) {
    assert!(informative <= d);
    let mut rng = Rng::new(seed);
    let mut values = Vec::with_capacity(rows * d);
    let mut labels = Vec::with_capacity(rows);
    for _ in 0..rows {
        let class_b = rng.bool(0.5);
        labels.push(class_b as u8);
        let p_info = if class_b { 2.0 / 3.0 } else { 1.0 / 3.0 };
        for j in 0..d {
            let p = if j < informative { p_info } else { 0.5 };
            values.push(if rng.bool(p) { 1.0 } else { 0.0 });
        }
    }
    (DenseMatrix::new(rows, d, values), labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squiggles_shape_and_spread() {
        let m = squiggles(2000, 1);
        assert_eq!((m.n, m.d), (2000, 2));
        // Points should span a wide area, not collapse.
        let xs: Vec<f32> = (0..m.n).map(|i| m.row(i)[0]).collect();
        let (lo, hi) = xs
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
        assert!(hi - lo > 50.0, "span {}", hi - lo);
    }

    #[test]
    fn squiggles_is_locally_1d() {
        // Manifold check: the nearest neighbor of a squiggle point is far
        // closer than a random point would be under a uniform distribution.
        let m = squiggles(3000, 2);
        let mut nn_sum = 0.0;
        for i in 0..50 {
            let mut best = f64::INFINITY;
            for j in 0..m.n {
                if i == j {
                    continue;
                }
                let dx = (m.row(i)[0] - m.row(j)[0]) as f64;
                let dy = (m.row(i)[1] - m.row(j)[1]) as f64;
                best = best.min(dx * dx + dy * dy);
            }
            nn_sum += best.sqrt();
        }
        assert!(nn_sum / 50.0 < 2.0, "mean NN dist {}", nn_sum / 50.0);
    }

    #[test]
    fn voronoi_shape() {
        let m = voronoi(1500, 3);
        assert_eq!((m.n, m.d), (1500, 2));
    }

    #[test]
    fn cell_surrogate_is_clustered() {
        let m = cell_surrogate(1000, 4);
        assert_eq!((m.n, m.d), (1000, 38));
        // Clustered: mean NN distance << mean pairwise distance.
        let mean_pair = {
            let mut acc = 0.0;
            for i in 0..40 {
                for j in 40..80 {
                    acc += crate::metrics::dense_euclidean(m.row(i), m.row(j));
                }
            }
            acc / 1600.0
        };
        let mean_nn = {
            let mut acc = 0.0;
            for i in 0..40 {
                let mut best = f64::INFINITY;
                for j in 0..m.n {
                    if i != j {
                        best = best.min(crate::metrics::dense_euclidean(m.row(i), m.row(j)));
                    }
                }
                acc += best;
            }
            acc / 40.0
        };
        assert!(mean_nn < mean_pair / 2.0, "nn {mean_nn} vs pair {mean_pair}");
    }

    #[test]
    fn covtype_surrogate_shape_and_binaries() {
        let m = covtype_surrogate(500, 5);
        assert_eq!((m.n, m.d), (500, 54));
        for i in 0..50 {
            for j in 10..54 {
                let v = m.row(i)[j];
                assert!(v == 0.0 || v == 1.0, "indicator not binary: {v}");
            }
        }
    }

    #[test]
    fn figure1_class_statistics() {
        let (m, labels) = figure1_dims(4000, 100, 20, 6);
        assert_eq!(m.n, 4000);
        // Informative block frequency per class.
        let mut sum = [0f64; 2];
        let mut cnt = [0usize; 2];
        for i in 0..m.n {
            let c = labels[i] as usize;
            let ones: f32 = m.row(i)[..20].iter().sum();
            sum[c] += ones as f64 / 20.0;
            cnt[c] += 1;
        }
        let pa = sum[0] / cnt[0] as f64;
        let pb = sum[1] / cnt[1] as f64;
        assert!((pa - 1.0 / 3.0).abs() < 0.03, "class A rate {pa}");
        assert!((pb - 2.0 / 3.0).abs() < 0.03, "class B rate {pb}");
        // Noise block is ~1/2 for both.
        let noise: f64 = (0..200)
            .map(|i| m.row(i)[20..].iter().sum::<f32>() as f64 / 80.0)
            .sum::<f64>()
            / 200.0;
        assert!((noise - 0.5).abs() < 0.05);
    }

    #[test]
    fn gaussian_mixture_deterministic() {
        let a = gaussian_mixture(100, 8, 3, 5.0, 42);
        let b = gaussian_mixture(100, 8, 3, 5.0, 42);
        assert_eq!(a.values, b.values);
        let c = gaussian_mixture(100, 8, 3, 5.0, 43);
        assert_ne!(a.values, c.values);
    }
}
