//! Benchmark support: a timing harness (criterion is unavailable offline)
//! and the generators that reproduce every table and figure of the paper.

pub mod harness;
pub mod tables;

/// Paper-style scientific notation (e.g. `4.08e+07`).
pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let exp = x.abs().log10().floor() as i32;
    let mantissa = x / 10f64.powi(exp);
    // Guard against 9.999 rounding up to 10.00.
    let (mantissa, exp) = if mantissa.abs() >= 9.995 {
        (mantissa / 10.0, exp + 1)
    } else {
        (mantissa, exp)
    };
    format!("{mantissa:.2}e{}{:02}", if exp < 0 { "-" } else { "+" }, exp.abs())
}

/// Fixed-width speedup formatting (matches the paper's bold column).
pub fn fmt_speedup(x: f64) -> String {
    if x >= 1000.0 {
        fmt_sci(x)
    } else if x >= 10.0 {
        format!("{x:.0}")
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sci_format() {
        assert_eq!(fmt_sci(4.08e7), "4.08e+07");
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(1474.0), "1.47e+03");
    }

    #[test]
    fn speedup_format() {
        assert_eq!(fmt_speedup(49.4), "49");
        assert_eq!(fmt_speedup(1474.0), "1.47e+03");
        assert_eq!(fmt_speedup(0.6), "0.6");
        assert_eq!(fmt_speedup(2.5), "2.5");
    }
}
