//! Regeneration of every table and figure in the paper's evaluation (§5).
//!
//! Each function returns structured rows AND knows how to print itself in
//! the paper's layout, so the CLI (`anchors-hierarchy table2 ...`), the
//! bench binaries, and docs/EXPERIMENTS.md all share one implementation.
//!
//! Scaling: the paper's full row counts (Table 1) are expensive on a
//! single machine, so every experiment takes a `scale` factor. Speedups
//! are *ratios* of distance counts, so the qualitative shape (who wins,
//! roughly by how much, Reuters' anti-speedup) is preserved at reduced
//! scale; docs/EXPERIMENTS.md records the scale used for each reported run.

use crate::algorithms::{allpairs, anomaly, kmeans};
use crate::dataset::{DatasetKind, DatasetSpec};
use crate::metrics::Space;
use crate::rng::Rng;
use crate::tree::middle_out::{self, MiddleOutConfig};
use crate::tree::{kdtree::KdTree, top_down, MetricTree};

use super::{fmt_sci, fmt_speedup};

// ---------------------------------------------------------------------
// Table 2: distance computations, naive vs tree, per dataset × operation.
// ---------------------------------------------------------------------

/// Configuration for the Table-2 sweep.
#[derive(Clone, Debug)]
pub struct Table2Config {
    /// Row-count multiplier vs the paper's dataset sizes.
    pub scale: f64,
    /// K-means iterations per run (the ratio is insensitive to this).
    pub kmeans_iters: usize,
    /// Leaf size.
    pub rmin: usize,
    pub seed: u64,
    /// Subset of datasets (None = all of Table 1).
    pub datasets: Option<Vec<DatasetKind>>,
}

impl Default for Table2Config {
    fn default() -> Self {
        Table2Config {
            scale: 0.05,
            kmeans_iters: 5,
            rmin: 30,
            seed: 20130,
            datasets: None,
        }
    }
}

/// One experiment cell of Table 2.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub dataset: String,
    /// "k=3" | "k=20" | "k=100" | "allpairs" | "anomalies"
    pub op: String,
    pub regular: u64,
    pub fast: u64,
    /// Tree-build distance cost (amortized context, reported once per
    /// dataset on the first row).
    pub build: u64,
}

impl Table2Row {
    pub fn speedup(&self) -> f64 {
        self.regular as f64 / self.fast.max(1) as f64
    }
}

/// K for the K-means columns. The paper restricts gen datasets to the
/// matching k (§5).
fn kmeans_ks(kind: &DatasetKind) -> Vec<usize> {
    match kind {
        DatasetKind::Gen { components, .. } => vec![*components],
        _ => vec![3, 20, 100],
    }
}

/// Run the full Table-2 sweep.
pub fn table2(cfg: &Table2Config) -> Vec<Table2Row> {
    let kinds = cfg
        .datasets
        .clone()
        .unwrap_or_else(crate::dataset::table2_datasets);
    let mut rows = Vec::new();
    for kind in kinds {
        rows.extend(table2_dataset(&kind, cfg));
    }
    rows
}

/// Table-2 rows for a single dataset.
pub fn table2_dataset(kind: &DatasetKind, cfg: &Table2Config) -> Vec<Table2Row> {
    let spec = DatasetSpec { kind: kind.clone(), scale: cfg.scale, seed: cfg.seed };
    let space = spec.build();
    let name = kind.name();
    eprintln!("[table2] {} ({} rows x {} dims)…", name, space.n(), space.dim());
    let mut rows = Vec::new();

    // The shared middle-out tree (its build cost is reported alongside).
    let tree = middle_out::build(
        &space,
        &MiddleOutConfig { rmin: cfg.rmin, seed: cfg.seed, ..Default::default() },
    );
    let build = tree.build_dists;

    // --- K-means columns ---------------------------------------------
    for k in kmeans_ks(kind) {
        let seed = cfg.seed ^ (k as u64);
        let opts = kmeans::KmeansOpts { seed, ..Default::default() };
        space.reset_count();
        let naive = kmeans::naive_lloyd(&space, kmeans::Init::Random, k, cfg.kmeans_iters, &opts);
        space.reset_count();
        let fast = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, k, cfg.kmeans_iters, &opts);
        debug_assert!(
            (naive.distortion - fast.distortion).abs() <= 1e-4 * (1.0 + naive.distortion.abs()),
            "exactness violated on {name} k={k}"
        );
        rows.push(Table2Row {
            dataset: name.clone(),
            op: format!("k={k}"),
            regular: naive.dists,
            fast: fast.dists,
            build,
        });
    }

    // --- All-pairs column ----------------------------------------------
    eprintln!("[table2] {name}: allpairs…");
    let tau = calibrate_tau(&space, cfg.seed);
    space.reset_count();
    let naive_ap = allpairs::naive_close_pairs(&space, tau);
    space.reset_count();
    let fast_ap = allpairs::tree_close_pairs(&space, &tree, tau);
    debug_assert_eq!(naive_ap.pairs.len(), fast_ap.pairs.len());
    rows.push(Table2Row {
        dataset: name.clone(),
        op: "allpairs".into(),
        regular: naive_ap.dists,
        fast: fast_ap.dists,
        build,
    });

    // --- Anomalies column ------------------------------------------------
    eprintln!("[table2] {name}: anomalies…");
    let threshold = (space.n() / 100).clamp(5, 50) as u64;
    let radius = anomaly::calibrate_radius(&space, threshold, 0.10, 40, cfg.seed);
    let params = anomaly::AnomalyParams { radius, threshold };
    space.reset_count();
    let naive_an = anomaly::naive_sweep(&space, &params);
    space.reset_count();
    let fast_an = anomaly::tree_sweep(&space, &tree, &params);
    debug_assert_eq!(naive_an.flags, fast_an.flags);
    rows.push(Table2Row {
        dataset: name,
        op: "anomalies".into(),
        regular: naive_an.dists,
        fast: fast_an.dists,
        build,
    });
    rows
}

/// Pick an "interesting" all-pairs threshold (§5): the paper chooses
/// thresholds that neither trivially prune everything nor match
/// everything. We take the ~0.1% quantile of sampled pairwise distances.
pub fn calibrate_tau(space: &Space, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let n = space.n();
    let samples = 4000.min(n * (n - 1) / 2);
    let mut ds: Vec<f64> = (0..samples)
        .map(|_| {
            let i = rng.below(n);
            let mut j = rng.below(n);
            while j == i {
                j = rng.below(n);
            }
            space.dist_uncounted(i, j)
        })
        .collect();
    ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = (ds.len() / 1000).max(1).min(ds.len() - 1);
    ds[idx]
}

/// Render Table 2 in the paper's layout.
pub fn print_table2(rows: &[Table2Row]) {
    println!(
        "{:<14} {:<10} {:>12} {:>12} {:>10} {:>12}",
        "dataset", "op", "regular", "fast", "speedup", "tree-build"
    );
    let mut last = String::new();
    for r in rows {
        let ds = if r.dataset == last { String::new() } else { r.dataset.clone() };
        let build = if r.dataset == last { String::new() } else { fmt_sci(r.build as f64) };
        last = r.dataset.clone();
        println!(
            "{:<14} {:<10} {:>12} {:>12} {:>10} {:>12}",
            ds,
            r.op,
            fmt_sci(r.regular as f64),
            fmt_sci(r.fast as f64),
            fmt_speedup(r.speedup()),
            build,
        );
    }
}

// ---------------------------------------------------------------------
// Table 3: anchors-built tree vs top-down tree (K-means dist ratio).
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table3Row {
    pub dataset: String,
    pub k: usize,
    pub topdown_dists: u64,
    pub anchors_dists: u64,
}

impl Table3Row {
    /// "factor by which anchors improves over top-down" (paper Table 3).
    pub fn factor(&self) -> f64 {
        self.topdown_dists as f64 / self.anchors_dists.max(1) as f64
    }
}

/// The paper's Table-3 dataset list.
pub fn table3_datasets() -> Vec<DatasetKind> {
    vec![
        DatasetKind::Cell,
        DatasetKind::Covtype,
        DatasetKind::Squiggles,
        DatasetKind::Gen { dims: 10000, components: 20 },
    ]
}

pub fn table3(scale: f64, kmeans_iters: usize, rmin: usize, seed: u64) -> Vec<Table3Row> {
    let mut rows = Vec::new();
    for kind in table3_datasets() {
        let spec = DatasetSpec { kind: kind.clone(), scale, seed };
        eprintln!("[table3] {}…", kind.name());
        let space = spec.build();
        let anchors_tree = middle_out::build(
            &space,
            &MiddleOutConfig { rmin, seed, ..Default::default() },
        );
        let topdown_tree = top_down::build(&space, rmin);
        let ks = match &kind {
            DatasetKind::Gen { components, .. } => vec![*components],
            _ => vec![3, 20, 100],
        };
        for k in ks {
            let opts = kmeans::KmeansOpts { seed: seed ^ k as u64, ..Default::default() };
            let run = |tree: &MetricTree| {
                space.reset_count();
                kmeans::tree_lloyd(&space, tree, kmeans::Init::Random, k, kmeans_iters, &opts)
                    .dists
            };
            rows.push(Table3Row {
                dataset: kind.name(),
                k,
                topdown_dists: run(&topdown_tree),
                anchors_dists: run(&anchors_tree),
            });
        }
    }
    rows
}

pub fn print_table3(rows: &[Table3Row]) {
    println!(
        "{:<16} {:>6} {:>14} {:>14} {:>8}",
        "dataset", "k", "top-down", "anchors", "factor"
    );
    for r in rows {
        println!(
            "{:<16} {:>6} {:>14} {:>14} {:>8.1}",
            r.dataset,
            r.k,
            fmt_sci(r.topdown_dists as f64),
            fmt_sci(r.anchors_dists as f64),
            r.factor()
        );
    }
}

// ---------------------------------------------------------------------
// Table 4: K-means initialization quality, random vs anchors.
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table4Row {
    pub dataset: String,
    pub k: usize,
    pub random_start: f64,
    pub anchors_start: f64,
    pub random_end: f64,
    pub anchors_end: f64,
}

impl Table4Row {
    pub fn start_benefit(&self) -> f64 {
        self.random_start / self.anchors_start
    }
    pub fn end_benefit(&self) -> f64 {
        self.random_end / self.anchors_end
    }
}

pub fn table4_datasets() -> Vec<DatasetKind> {
    vec![
        DatasetKind::Cell,
        DatasetKind::Covtype,
        DatasetKind::Reuters { half: false },
        DatasetKind::Squiggles,
    ]
}

pub fn table4(scale: f64, iters: usize, rmin: usize, seed: u64) -> Vec<Table4Row> {
    let mut rows = Vec::new();
    for kind in table4_datasets() {
        let spec = DatasetSpec { kind: kind.clone(), scale, seed };
        eprintln!("[table4] {}…", kind.name());
        let space = spec.build();
        let tree = middle_out::build(
            &space,
            &MiddleOutConfig { rmin, seed, ..Default::default() },
        );
        for k in [100usize, 20, 3] {
            // Scaled-down datasets can have fewer rows than the paper's k.
            let k = k.min(space.n() / 2).max(1);
            let opts = kmeans::KmeansOpts { seed: seed ^ k as u64, ..Default::default() };
            let random = kmeans::random_init(&space, k, opts.seed);
            let anchors = kmeans::anchors_init(&space, k, opts.seed);
            let random_start = kmeans::distortion_of(&space, &random);
            let anchors_start = kmeans::distortion_of(&space, &anchors);
            let random_end = kmeans::tree_lloyd(
                &space,
                &tree,
                kmeans::Init::Given(random),
                k,
                iters,
                &opts,
            )
            .distortion;
            let anchors_end = kmeans::tree_lloyd(
                &space,
                &tree,
                kmeans::Init::Given(anchors),
                k,
                iters,
                &opts,
            )
            .distortion;
            rows.push(Table4Row {
                dataset: kind.name(),
                k,
                random_start,
                anchors_start,
                random_end,
                anchors_end,
            });
        }
    }
    rows
}

pub fn print_table4(rows: &[Table4Row]) {
    println!(
        "{:<12} {:>6} {:>13} {:>13} {:>13} {:>13} {:>9} {:>9}",
        "dataset", "k", "RandomStart", "AnchorsStart", "RandomEnd", "AnchorsEnd", "StartBen", "EndBen"
    );
    for r in rows {
        println!(
            "{:<12} {:>6} {:>13} {:>13} {:>13} {:>13} {:>9.2} {:>9.3}",
            r.dataset,
            format!("k={}", r.k),
            fmt_sci(r.random_start),
            fmt_sci(r.anchors_start),
            fmt_sci(r.random_end),
            fmt_sci(r.anchors_end),
            r.start_benefit(),
            r.end_benefit()
        );
    }
}

// ---------------------------------------------------------------------
// Figure 1: kd-trees vs metric trees on the two-class spreadsheet.
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Figure1Result {
    pub rows: usize,
    pub dims: usize,
    /// Purity of the metric tree's FIRST split (fraction of the majority
    /// class in each child).
    pub metric_first_split_purity: (f64, f64),
    /// kd-tree majority-class purity by depth (weighted by node size).
    pub kd_purity_by_depth: Vec<(usize, f64)>,
    /// Depth at which the kd-tree reaches the metric tree's first-split
    /// purity (None within the probed range).
    pub kd_depth_to_match: Option<usize>,
}

pub fn figure1(rows: usize, seed: u64) -> Figure1Result {
    use crate::data::Data;
    let (data, labels) = crate::dataset::figure1(rows, seed);
    let dims = data.d;
    let kd = KdTree::build(&data, 64.max(rows / 64));
    let space = Space::euclidean(Data::Dense(data));
    // A single metric-tree split: the middle-out builder with rmin = n/2
    // creates √R anchor leaves and agglomerates them; the root's two
    // children are the final merge — which, because merging is by
    // enclosing-ball radius, is almost exactly the two-class split.
    // (The plain farthest-pair top-down split scores ~5 points lower
    // here: its poles are extreme noise points.)
    let tree = middle_out::build(
        &space,
        &MiddleOutConfig { rmin: (rows / 2).max(2), seed, ..Default::default() },
    );
    let root = tree.root_node();
    let purity = |points: &[u32]| -> f64 {
        if points.is_empty() {
            return 1.0;
        }
        let ones = points.iter().filter(|&&p| labels[p as usize] == 1).count();
        let frac = ones as f64 / points.len() as f64;
        frac.max(1.0 - frac)
    };
    let (pa, pb) = match root.children {
        Some((a, b)) => (
            purity(tree.points_under(a)),
            purity(tree.points_under(b)),
        ),
        None => (purity(tree.points_under(tree.root)), 1.0),
    };

    // kd-tree purity by depth.
    let mut kd_purity_by_depth = Vec::new();
    let mut kd_depth_to_match = None;
    let target = pa.min(pb);
    for depth in 0..=14usize {
        let nodes = kd.nodes_at_depth(depth);
        let mut weighted = 0.0;
        let mut total = 0usize;
        for id in nodes {
            let pts = kd.points_under(id);
            weighted += purity(&pts) * pts.len() as f64;
            total += pts.len();
        }
        let p = weighted / total.max(1) as f64;
        kd_purity_by_depth.push((depth, p));
        if kd_depth_to_match.is_none() && p >= target {
            kd_depth_to_match = Some(depth);
        }
    }
    Figure1Result {
        rows,
        dims,
        metric_first_split_purity: (pa, pb),
        kd_purity_by_depth,
        kd_depth_to_match,
    }
}

pub fn print_figure1(r: &Figure1Result) {
    println!(
        "Figure 1 reproduction: {} rows x {} binary attributes (two hidden classes)",
        r.rows, r.dims
    );
    println!(
        "metric tree FIRST split purity: child1 {:.1}%  child2 {:.1}%",
        r.metric_first_split_purity.0 * 100.0,
        r.metric_first_split_purity.1 * 100.0
    );
    println!("kd-tree weighted purity by depth:");
    for (d, p) in &r.kd_purity_by_depth {
        println!("  depth {d:>2}: {:.1}%", p * 100.0);
    }
    match r.kd_depth_to_match {
        Some(d) => println!(
            "kd-tree needs depth {d} (≈{} nodes) to match the metric tree's one split",
            1u64 << d
        ),
        None => println!("kd-tree never reaches the metric tree's first-split purity in 14 levels"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_small_slice_has_expected_rows() {
        let cfg = Table2Config {
            scale: 0.004,
            kmeans_iters: 2,
            rmin: 16,
            datasets: Some(vec![DatasetKind::Squiggles]),
            ..Default::default()
        };
        let rows = table2(&cfg);
        // 3 kmeans + allpairs + anomalies.
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().all(|r| r.regular > 0 && r.fast > 0));
        // 2-d structured data: the tree should win clearly on k=3.
        let km3 = &rows[0];
        assert_eq!(km3.op, "k=3");
        assert!(
            km3.speedup() > 2.0,
            "squiggles k=3 speedup only {}",
            km3.speedup()
        );
    }

    #[test]
    fn table2_gen_uses_matching_k() {
        let cfg = Table2Config {
            scale: 0.003,
            kmeans_iters: 1,
            rmin: 16,
            datasets: Some(vec![DatasetKind::Gen { dims: 100, components: 3 }]),
            ..Default::default()
        };
        let rows = table2(&cfg);
        assert_eq!(rows.len(), 3); // k=3, allpairs, anomalies
        assert_eq!(rows[0].op, "k=3");
    }

    #[test]
    fn table3_factors_positive() {
        let rows = table3(0.003, 2, 16, 7);
        assert_eq!(rows.len(), 3 + 3 + 3 + 1); // 3 dense datasets ×3 ks + gen ×1
        for r in &rows {
            assert!(r.factor() > 0.0);
        }
    }

    #[test]
    fn table4_benefits_positive_for_clustered_data() {
        let rows = table4(0.004, 10, 16, 9);
        for r in rows.iter().filter(|r| r.dataset == "cell") {
            assert!(
                r.start_benefit() > 1.0,
                "cell k={}: start benefit {} <= 1",
                r.k,
                r.start_benefit()
            );
            // End distortions must both be <= start distortions.
            assert!(r.random_end <= r.random_start * 1.0001);
            assert!(r.anchors_end <= r.anchors_start * 1.0001);
        }
    }

    #[test]
    fn figure1_metric_tree_separates_classes() {
        let r = figure1(1500, 11);
        let (pa, pb) = r.metric_first_split_purity;
        assert!(
            pa > 0.95 && pb > 0.95,
            "first split impure: {pa:.3}/{pb:.3}"
        );
        // kd-tree is near-chance at depth 1.
        let depth1 = r.kd_purity_by_depth[1].1;
        assert!(depth1 < 0.75, "kd depth-1 purity {depth1}");
    }
}
