//! Minimal wall-clock benchmarking harness (offline replacement for
//! criterion): warmup, repeated timed runs, and summary statistics.

use std::time::Instant;

/// Statistics over the timed iterations, in seconds.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl BenchStats {
    /// criterion-ish one-liner.
    pub fn report(&self) -> String {
        format!(
            "{:<42} time: [{} {} {}]  ({} iters)",
            self.name,
            fmt_time(self.min),
            fmt_time(self.mean),
            fmt_time(self.max),
            self.iters
        )
    }
}

/// Human time units.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.2} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

/// A tiny bench runner. `warmup` un-timed runs, then `iters` timed runs.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, iters: 5 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { warmup, iters: iters.max(1) }
    }

    /// Time `f`, which receives the iteration index. The closure's result
    /// is returned from the last run so the optimizer can't delete work.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut(usize) -> T) -> (BenchStats, T) {
        for w in 0..self.warmup {
            std::hint::black_box(f(w));
        }
        let mut times = Vec::with_capacity(self.iters);
        let mut last = None;
        for i in 0..self.iters {
            let t0 = Instant::now();
            let out = std::hint::black_box(f(i));
            times.push(t0.elapsed().as_secs_f64());
            last = Some(out);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times
            .iter()
            .map(|t| (t - mean) * (t - mean))
            .sum::<f64>()
            / times.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters: self.iters,
            mean,
            std: var.sqrt(),
            min: times.iter().cloned().fold(f64::INFINITY, f64::min),
            max: times.iter().cloned().fold(0.0, f64::max),
        };
        (stats, last.unwrap())
    }

    /// Run + print the report line; returns the closure result.
    pub fn bench<T>(&self, name: &str, f: impl FnMut(usize) -> T) -> T {
        let (stats, out) = self.run(name, f);
        println!("{}", stats.report());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_expected_iterations() {
        let b = Bencher::new(2, 4);
        let count = std::cell::Cell::new(0usize);
        let (stats, _) = b.run("counting", |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 6); // 2 warmup + 4 timed
        assert_eq!(stats.iters, 4);
        assert!(stats.mean >= 0.0);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max + 1e-12);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(3.0e-6).contains("µs"));
        assert!(fmt_time(1.5e-3).contains("ms"));
        assert!(fmt_time(2.0).contains("s"));
    }

    #[test]
    fn returns_result() {
        let b = Bencher::new(0, 3);
        let (_, out) = b.run("id", |i| i * 2);
        assert_eq!(out, 4);
    }
}
