//! Deterministic fault injection for robustness drills.
//!
//! Default **off**: every hook below is behind one relaxed atomic load
//! ([`active`]), so production and ordinary test runs pay nothing and
//! observe nothing. A drill installs a [`FaultPlan`] — programmatically
//! ([`install`] / [`ScopedFaults`]) or from the environment
//! (`PALLAS_FAULTS=seed:spec`, parsed by [`from_env`] and installed
//! explicitly by `main.rs`; a set-but-unparsable value is a loud error,
//! never a silent no-faults run) — and the plan then forces failures at
//! fixed injection points:
//!
//! | key          | value        | injection point                              |
//! |--------------|--------------|----------------------------------------------|
//! | `panic`      | prob (0..=1) | job execution panics (coordinator `run_job`) |
//! | `queue_full` | prob         | `submit` rejects as if the queue were full   |
//! | `slow_leaf`  | duration     | every traversal checkpoint sleeps this long  |
//! | `snap_trunc` | prob         | snapshot reads see a truncated stream        |
//! | `sock_drop`  | prob         | server drops an accepted connection          |
//!
//! Example: `PALLAS_FAULTS=7:panic=0.3,slow_leaf=200us,queue_full=0.2`.
//!
//! **Determinism.** Every probabilistic decision is a pure function of
//! `(plan seed, fault tag, decision key)` through a splitmix64 mix —
//! no RNG state, no wall clock. Decision keys are deterministic
//! sequence numbers (submit attempts, snapshot reads, accepted
//! connections) or job ids, and [`install`] resets the sequences, so
//! re-running a drill with the same plan against the same request
//! stream reproduces the same faults, fault for fault.
//! `tests/fault_injection.rs` pins this.
//!
//! This module is in pallas-lint D5 (panic-wire) scope: failure-path
//! code must not itself panic, so everything here returns values and
//! recovers poisoned locks.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// One part per million; probabilities are stored as ppm so decisions
/// stay in integer arithmetic.
const PPM: u64 = 1_000_000;

const TAG_PANIC: u64 = 0x9e37_79b9_7f4a_7c15;
const TAG_QUEUE: u64 = 0xbf58_476d_1ce4_e5b9;
const TAG_SNAP: u64 = 0x94d0_49bb_1331_11eb;
const TAG_SOCK: u64 = 0xd6e8_feb8_6659_fd93;

/// A parsed drill: which faults fire, at what rate, under which seed.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for every probabilistic decision this plan makes.
    pub seed: u64,
    /// ppm probability that a job's execution panics.
    pub panic_ppm: u32,
    /// ppm probability that a submit is rejected as queue-full.
    pub queue_full_ppm: u32,
    /// ppm probability that a snapshot read sees a truncated stream.
    pub snap_trunc_ppm: u32,
    /// ppm probability that the server drops an accepted connection.
    pub sock_drop_ppm: u32,
    /// Artificial delay at every traversal checkpoint.
    pub slow_leaf: Option<Duration>,
}

impl FaultPlan {
    /// Parse the `seed:spec` form (see the module docs for the grammar).
    pub fn parse(raw: &str) -> Result<FaultPlan, String> {
        let (seed_s, rest) = raw
            .split_once(':')
            .ok_or_else(|| format!("fault spec {raw:?}: expected \"seed:key=value,...\""))?;
        let seed = seed_s
            .trim()
            .parse::<u64>()
            .map_err(|e| format!("fault spec seed {seed_s:?}: {e}"))?;
        let mut plan = FaultPlan { seed, ..FaultPlan::default() };
        for part in rest.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec item {part:?}: expected key=value"))?;
            match key.trim() {
                "panic" => plan.panic_ppm = parse_ppm(value)?,
                "queue_full" => plan.queue_full_ppm = parse_ppm(value)?,
                "snap_trunc" => plan.snap_trunc_ppm = parse_ppm(value)?,
                "sock_drop" => plan.sock_drop_ppm = parse_ppm(value)?,
                "slow_leaf" => plan.slow_leaf = Some(parse_duration(value)?),
                other => return Err(format!("fault spec: unknown fault {other:?}")),
            }
        }
        Ok(plan)
    }
}

fn parse_ppm(value: &str) -> Result<u32, String> {
    let p = value
        .trim()
        .parse::<f64>()
        .map_err(|e| format!("fault probability {value:?}: {e}"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("fault probability {value:?}: must be in [0, 1]"));
    }
    // In-range by the check above, so the cast is exact up to rounding.
    Ok((p * PPM as f64).round() as u32)
}

fn parse_duration(value: &str) -> Result<Duration, String> {
    let v = value.trim();
    let (digits, mul_us) = if let Some(d) = v.strip_suffix("ms") {
        (d, 1_000u64)
    } else if let Some(d) = v.strip_suffix("us") {
        (d, 1u64)
    } else if let Some(d) = v.strip_suffix('s') {
        (d, 1_000_000u64)
    } else {
        return Err(format!("fault duration {v:?}: expected a us/ms/s suffix"));
    };
    let n = digits
        .trim()
        .parse::<u64>()
        .map_err(|e| format!("fault duration {v:?}: {e}"))?;
    Ok(Duration::from_micros(n.saturating_mul(mul_us)))
}

/// Fast gate: `false` (one relaxed load) unless a plan is installed.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);
/// Decision sequence numbers (reset by [`install`] so drills replay).
static SUBMIT_SEQ: AtomicU64 = AtomicU64::new(0);
static SNAP_SEQ: AtomicU64 = AtomicU64::new(0);
static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// Install (or clear, with `None`) the process-wide fault plan and
/// reset the decision sequences — the same plan then reproduces the
/// same drill against the same request stream.
pub fn install(plan: Option<FaultPlan>) {
    let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    SUBMIT_SEQ.store(0, Ordering::SeqCst);
    SNAP_SEQ.store(0, Ordering::SeqCst);
    SOCK_SEQ.store(0, Ordering::SeqCst);
    ACTIVE.store(plan.is_some(), Ordering::SeqCst);
    *slot = plan.map(Arc::new);
}

/// Parse `PALLAS_FAULTS` without installing it. Unset → `Ok(None)`;
/// set but unparsable → `Err` (a drill that silently doesn't run would
/// turn CI coverage green while testing nothing — same loud-error
/// policy as `PALLAS_SHARDS`).
pub fn from_env() -> Result<Option<FaultPlan>, String> {
    match std::env::var("PALLAS_FAULTS") {
        Err(_) => Ok(None),
        Ok(raw) => FaultPlan::parse(&raw)
            .map(Some)
            .map_err(|e| format!("$PALLAS_FAULTS: {e}")),
    }
}

fn current() -> Option<Arc<FaultPlan>> {
    if !active() {
        return None;
    }
    PLAN.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// The deterministic coin: a pure function of (seed, tag, key).
fn decide(seed: u64, tag: u64, key: u64, ppm: u32) -> bool {
    ppm > 0 && splitmix64(seed ^ tag ^ splitmix64(key)) % PPM < u64::from(ppm)
}

/// Should the job with this id panic? Keyed by the (globally unique)
/// job id, so the same submission stream faults the same jobs.
pub fn should_panic_job(job_id: u64) -> bool {
    match current() {
        Some(p) => decide(p.seed, TAG_PANIC, job_id, p.panic_ppm),
        None => false,
    }
}

/// Should this submit be rejected as if the queue were full? Keyed by a
/// global submit-attempt sequence number.
pub fn should_reject_submit() -> bool {
    match current() {
        Some(p) if p.queue_full_ppm > 0 => {
            let n = SUBMIT_SEQ.fetch_add(1, Ordering::SeqCst);
            decide(p.seed, TAG_QUEUE, n, p.queue_full_ppm)
        }
        _ => false,
    }
}

/// Byte limit to truncate the next snapshot read at, if the fault
/// fires. Keyed by a global snapshot-read sequence number; the limit
/// itself is derived from the same mix, so a given read in the stream
/// always truncates at the same offset.
pub fn snapshot_truncation() -> Option<u64> {
    let p = current()?;
    if p.snap_trunc_ppm == 0 {
        return None;
    }
    let n = SNAP_SEQ.fetch_add(1, Ordering::SeqCst);
    if !decide(p.seed, TAG_SNAP, n, p.snap_trunc_ppm) {
        return None;
    }
    // Cut somewhere in the header/early-node region: past the magic
    // often enough to exercise mid-record EOF paths, never the full file.
    Some(4 + splitmix64(p.seed ^ TAG_SNAP ^ n) % 512)
}

/// Should the server drop this accepted connection? Keyed by a global
/// accepted-connection sequence number.
pub fn should_drop_socket() -> bool {
    match current() {
        Some(p) if p.sock_drop_ppm > 0 => {
            let n = SOCK_SEQ.fetch_add(1, Ordering::SeqCst);
            decide(p.seed, TAG_SOCK, n, p.sock_drop_ppm)
        }
        _ => false,
    }
}

/// Slow-leaf hook, called from `Space::checkpoint` behind the
/// [`active`] gate: sleep the configured delay at every traversal
/// checkpoint. Timing-only — results and counters are untouched.
pub fn leaf_checkpoint() {
    if let Some(d) = current().and_then(|p| p.slow_leaf) {
        // pallas-lint: allow(threads, fault-injected slow leaves need a real sleep; gated off unless a drill is installed)
        std::thread::sleep(d);
    }
}

static TEST_LOCK: Mutex<()> = Mutex::new(());

/// RAII drill scope for tests: installs a plan, serializes against
/// other drills in the process (the plan is process-global), and
/// uninstalls on drop.
pub struct ScopedFaults {
    _guard: MutexGuard<'static, ()>,
}

impl ScopedFaults {
    pub fn install(plan: FaultPlan) -> ScopedFaults {
        let guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        install(Some(plan));
        ScopedFaults { _guard: guard }
    }

    /// Serialize a faults-off section against concurrent drills (e.g. a
    /// clean baseline run that must not overlap another test's plan).
    pub fn none() -> ScopedFaults {
        let guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        install(None);
        ScopedFaults { _guard: guard }
    }
}

impl Drop for ScopedFaults {
    fn drop(&mut self) {
        install(None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("7:panic=0.3,slow_leaf=200us,queue_full=0.2,snap_trunc=1,sock_drop=0")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.panic_ppm, 300_000);
        assert_eq!(p.queue_full_ppm, 200_000);
        assert_eq!(p.snap_trunc_ppm, 1_000_000);
        assert_eq!(p.sock_drop_ppm, 0);
        assert_eq!(p.slow_leaf, Some(Duration::from_micros(200)));
        // Duration suffixes.
        assert_eq!(
            FaultPlan::parse("1:slow_leaf=2ms").unwrap().slow_leaf,
            Some(Duration::from_millis(2))
        );
        assert_eq!(
            FaultPlan::parse("1:slow_leaf=1s").unwrap().slow_leaf,
            Some(Duration::from_secs(1))
        );
        // Empty spec after the seed is a valid no-op plan.
        assert_eq!(FaultPlan::parse("9:").unwrap(), FaultPlan { seed: 9, ..Default::default() });
    }

    #[test]
    fn parse_rejects_garbage_loudly() {
        for bad in [
            "no-seed",
            "x:panic=0.5",
            "1:panic",
            "1:panic=1.5",
            "1:panic=-0.1",
            "1:slow_leaf=10",
            "1:slow_leaf=abcms",
            "1:warp_core=0.5",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn decisions_are_deterministic_in_seed_and_key() {
        let hits = |seed: u64| -> Vec<bool> {
            (0..256).map(|k| decide(seed, TAG_PANIC, k, 250_000)).collect()
        };
        assert_eq!(hits(7), hits(7), "same seed, same decisions");
        assert_ne!(hits(7), hits(8), "different seed, different drill");
        let n = hits(7).iter().filter(|&&b| b).count();
        // ~25% rate, loose bounds: the mix must not be degenerate.
        assert!(n > 256 / 8 && n < 256 / 2, "rate off: {n}/256");
    }

    #[test]
    fn install_resets_sequences() {
        let _scope = ScopedFaults::install(
            FaultPlan { seed: 3, queue_full_ppm: 500_000, ..Default::default() },
        );
        let first: Vec<bool> = (0..32).map(|_| should_reject_submit()).collect();
        install(Some(FaultPlan { seed: 3, queue_full_ppm: 500_000, ..Default::default() }));
        let second: Vec<bool> = (0..32).map(|_| should_reject_submit()).collect();
        assert_eq!(first, second, "reinstall must replay the drill");
        assert!(first.iter().any(|&b| b) && first.iter().any(|&b| !b));
    }

    #[test]
    fn inactive_means_no_faults_anywhere() {
        let _scope = ScopedFaults::none();
        assert!(!active());
        assert!(!should_panic_job(1));
        assert!(!should_reject_submit());
        assert!(snapshot_truncation().is_none());
        assert!(!should_drop_socket());
        leaf_checkpoint(); // no plan: returns immediately
    }
}
