//! Blocked distance kernels for leaf scans and assignment passes.
//!
//! Every algorithm family ends up in the same hot loop: "distances from a
//! set of dataset rows to one or more targets". Point-at-a-time
//! [`Space::dist_to_vec`] pays a counter increment and a metric dispatch
//! per distance; these kernels hoist the dispatch out of the loop,
//! account whole tiles at once through [`Space::count_bulk`], and write
//! into a caller-owned scratch buffer so the scan loop that follows
//! (heap pushes, threshold tests, arg-min selection) runs branch-free of
//! the distance math. This is the metrics-level promotion of the scalar
//! kernel that previously lived inside `runtime::BatchDistanceEngine`
//! (which now delegates its non-XLA fallback to [`dist2_block`]), so the
//! leaf scans of knn/ballquery/anomaly/allpairs and the k-means naive
//! pass share one cache-friendly implementation instead of only the
//! kmeans-via-XLA path enjoying it. Before/after throughput is recorded
//! in `BENCH_hot_paths.json` (docs/EXPERIMENTS.md §Blocked kernels).
//!
//! ## Gather vs contiguous forms
//!
//! Each shape comes in two forms. The **gather** forms
//! ([`dists_to_vec`], [`dists_to_centers`], [`dists_rows`]) take an
//! explicit `rows: &[u32]` id list and chase one pointer per row — the
//! only option when the candidate set is scattered (and the honest
//! baseline the `hot_paths` bench measures). The **contiguous** forms
//! ([`dists_contig_to_vec`], [`dists_contig_to_centers`],
//! [`dists_contig_rows`]) take a `Range<usize>` and stream the rows as
//! one sequential slab — zero index indirection, hardware-prefetcher
//! friendly. Since the tree-order layout ([`crate::tree::Layout`])
//! made every leaf a contiguous range of the permuted arena, **all**
//! tree leaf scans use the contiguous forms; the gather forms remain
//! for genuinely scattered row sets and as the before/after reference.
//!
//! ## Bit-identity contract
//!
//! Each element is computed by *exactly* the expression the scalar
//! [`Space::dist_to_vec_uncounted`] / [`Space::dist_uncounted`] paths
//! use (same cached squared norms, same [`dense_dot`] accumulation
//! order, same `max(0)·sqrt` clamping), so swapping a scalar loop for a
//! blocked kernel changes neither a single result bit nor the distance
//! count — `tests/parallel_equivalence.rs` asserts both on dense and
//! sparse data. That is what lets the tree algorithms adopt the kernels
//! without perturbing the paper's Table-2 accounting.
//!
//! ## The f32 filter tier
//!
//! [`F32Filter`] + [`dists_contig_to_vec_f32`] implement the opt-in
//! reduced-precision tier ([`Space::set_f32_tier`]): leaf scans that
//! prune against a threshold (knn kth-best, ball radius, the anomaly
//! rules) first compute d² in 8-wide f32 lanes, discard rows whose f32
//! value puts them **conclusively** outside the threshold — further out
//! than a rigorous error bound ε could explain — and recompute only the
//! remaining candidates with the exact f64 expression above. Because a
//! pruned row provably satisfies `d₆₄ > thr`, the tier-off scan would
//! have rejected it too, so tier-on results (values, orders, heap
//! states, tie-breaks, distance counts) are **bit-identical** to
//! tier-off; only the work split changes. f32 pre-pass evaluations are
//! accounted in a separate counter cell ([`Space::count_bulk_f32`]),
//! never in the Table-2 f64 budget. Derivation of ε is on [`f32_eps`];
//! `tests/kernel_lanes.rs` proves the end-to-end bit-identity.

use super::{dense_dot, dense_dot_f32, dense_l1, Metric, Space};
use crate::data::Data;
use std::ops::Range;

/// Rows per accounting tile. Each tile is one `count_bulk` call and one
/// metric dispatch; the tile's distances land contiguously in the output
/// buffer while its rows are still warm in cache.
pub const TILE: usize = 128;

/// Rows per *streamed* chunk for full-dataset scans that consume
/// distances as they go (naive knn / ball stats): big enough to
/// amortize the kernel call, small enough that the `f64` buffer stays
/// cache-resident instead of growing O(n).
pub const SCAN_CHUNK: usize = 4096;

/// Distances from each listed dataset row to a single dense query
/// vector with precomputed squared norm — the leaf-scan shape of knn,
/// ball queries and the anomaly sweep. Counted: `rows.len()` distances,
/// accounted per tile. `out` is cleared and refilled (reuse it across
/// leaves to stay allocation-free).
pub fn dists_to_vec(space: &Space, rows: &[u32], q: &[f32], q_sq: f64, out: &mut Vec<f64>) {
    out.clear();
    out.reserve(rows.len());
    let mut lo = 0usize;
    while lo < rows.len() {
        let hi = (lo + TILE).min(rows.len());
        let tile = &rows[lo..hi];
        match (&space.data, space.metric) {
            (Data::Dense(m), Metric::Euclidean) => {
                for &p in tile {
                    let i = p as usize;
                    let d2 = m.sqnorm(i) + q_sq - 2.0 * dense_dot(m.row(i), q);
                    out.push(d2.max(0.0).sqrt());
                }
            }
            (Data::Dense(m), Metric::L1) => {
                for &p in tile {
                    out.push(dense_l1(m.row(p as usize), q));
                }
            }
            (Data::Sparse(m), Metric::Euclidean) => {
                for &p in tile {
                    let i = p as usize;
                    let d2 = m.sqnorm(i) + q_sq - 2.0 * m.dot_vec(i, q);
                    out.push(d2.max(0.0).sqrt());
                }
            }
            (Data::Sparse(_), Metric::L1) => unreachable!("rejected in Space::new"),
        }
        space.count_bulk((hi - lo) as u64);
        lo = hi;
    }
}

/// Distances from dataset rows to a candidate subset of centers — the
/// leaf-assignment shape of the k-means tree pass. `cand` indexes into
/// `centroids`/`c_sq`, so call sites pass their full center table plus
/// the surviving candidate list without cloning center vectors. Output
/// is row-major `rows.len() × cand.len()`; counted `rows·cand` per tile.
pub fn dists_to_centers(
    space: &Space,
    rows: &[u32],
    cand: &[u32],
    centroids: &[Vec<f32>],
    c_sq: &[f64],
    out: &mut Vec<f64>,
) {
    fill_centers(space, rows.len(), |t| rows[t] as usize, cand, centroids, c_sq, out);
}

/// [`dists_to_vec`] over a contiguous row range, reading the rows as
/// one sequential slab — the zero-gather form every tree leaf scan
/// (knn / ball / anomaly) uses on the tree-order arena, and the
/// streamed form of the naive full-dataset scans.
pub fn dists_contig_to_vec(
    space: &Space,
    rows: Range<usize>,
    q: &[f32],
    q_sq: f64,
    out: &mut Vec<f64>,
) {
    out.clear();
    out.reserve(rows.len());
    let mut lo = rows.start;
    while lo < rows.end {
        let hi = (lo + TILE).min(rows.end);
        match (&space.data, space.metric) {
            // Dense Euclidean: one values slab + one norms slice per
            // tile ([`crate::data::DenseMatrix::rows_slab`]) — same
            // math as the per-row form, no per-row slice arithmetic.
            (Data::Dense(m), Metric::Euclidean) if m.d > 0 => {
                let (slab, norms) = m.rows_slab(lo..hi);
                for (row, &r_sq) in slab.chunks_exact(m.d).zip(norms) {
                    let d2 = r_sq + q_sq - 2.0 * dense_dot(row, q);
                    out.push(d2.max(0.0).sqrt());
                }
            }
            (Data::Dense(m), Metric::Euclidean) => {
                // d == 0: every distance degenerates to √q_sq.
                for i in lo..hi {
                    let d2 = m.sqnorm(i) + q_sq;
                    out.push(d2.max(0.0).sqrt());
                }
            }
            (Data::Dense(m), Metric::L1) => {
                for i in lo..hi {
                    out.push(dense_l1(m.row(i), q));
                }
            }
            (Data::Sparse(m), Metric::Euclidean) => {
                for i in lo..hi {
                    let d2 = m.sqnorm(i) + q_sq - 2.0 * m.dot_vec(i, q);
                    out.push(d2.max(0.0).sqrt());
                }
            }
            (Data::Sparse(_), Metric::L1) => unreachable!("rejected in Space::new"),
        }
        space.count_bulk((hi - lo) as u64);
        lo = hi;
    }
}

/// [`dists_to_centers`] over a contiguous row range — the k-means leaf
/// assignment on the tree-order arena and the chunked naive pass
/// (chunks are ranges, not id lists). Also the gaussian-EM leaf shape
/// (every mixture component as a "center").
pub fn dists_contig_to_centers(
    space: &Space,
    rows: Range<usize>,
    cand: &[u32],
    centroids: &[Vec<f32>],
    c_sq: &[f64],
    out: &mut Vec<f64>,
) {
    // Dense Euclidean (the hot arm) streams each tile as one values
    // slab + norms slice; everything else shares the gather-form body
    // through a sequential row_of.
    if let (Data::Dense(m), Metric::Euclidean) = (&space.data, space.metric) {
        if m.d > 0 {
            let k = cand.len();
            out.clear();
            out.reserve(rows.len() * k);
            let mut lo = rows.start;
            while lo < rows.end {
                let hi = (lo + TILE).min(rows.end);
                let (slab, norms) = m.rows_slab(lo..hi);
                for (row, &r_sq) in slab.chunks_exact(m.d).zip(norms) {
                    for &c in cand {
                        let cu = c as usize;
                        let d2 = r_sq + c_sq[cu] - 2.0 * dense_dot(row, &centroids[cu]);
                        out.push(d2.max(0.0).sqrt());
                    }
                }
                space.count_bulk(((hi - lo) * k) as u64);
                lo = hi;
            }
            return;
        }
    }
    let base = rows.start;
    fill_centers(space, rows.len(), |t| base + t, cand, centroids, c_sq, out);
}

fn fill_centers(
    space: &Space,
    n: usize,
    row_of: impl Fn(usize) -> usize,
    cand: &[u32],
    centroids: &[Vec<f32>],
    c_sq: &[f64],
    out: &mut Vec<f64>,
) {
    let k = cand.len();
    out.clear();
    out.reserve(n * k);
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + TILE).min(n);
        match (&space.data, space.metric) {
            (Data::Dense(m), Metric::Euclidean) => {
                for t in lo..hi {
                    let i = row_of(t);
                    let (row, r_sq) = (m.row(i), m.sqnorm(i));
                    for &c in cand {
                        let cu = c as usize;
                        let d2 = r_sq + c_sq[cu] - 2.0 * dense_dot(row, &centroids[cu]);
                        out.push(d2.max(0.0).sqrt());
                    }
                }
            }
            (Data::Dense(m), Metric::L1) => {
                for t in lo..hi {
                    let row = m.row(row_of(t));
                    for &c in cand {
                        out.push(dense_l1(row, &centroids[c as usize]));
                    }
                }
            }
            (Data::Sparse(m), Metric::Euclidean) => {
                for t in lo..hi {
                    let i = row_of(t);
                    let r_sq = m.sqnorm(i);
                    for &c in cand {
                        let cu = c as usize;
                        let d2 = r_sq + c_sq[cu] - 2.0 * m.dot_vec(i, &centroids[cu]);
                        out.push(d2.max(0.0).sqrt());
                    }
                }
            }
            (Data::Sparse(_), Metric::L1) => unreachable!("rejected in Space::new"),
        }
        space.count_bulk(((hi - lo) * k) as u64);
        lo = hi;
    }
}

/// Row-to-row distances for a pair of dataset row lists — the dual-tree
/// leaf-leaf shape of all-pairs search. Output is row-major
/// `a.len() × b.len()`; counted `|a|·|b|` per tile. Per-element math is
/// exactly [`Space::dist_uncounted`].
pub fn dists_rows(space: &Space, a: &[u32], b: &[u32], out: &mut Vec<f64>) {
    out.clear();
    out.reserve(a.len() * b.len());
    let mut lo = 0usize;
    while lo < a.len() {
        let hi = (lo + TILE).min(a.len());
        let tile = &a[lo..hi];
        match (&space.data, space.metric) {
            (Data::Dense(m), Metric::Euclidean) => {
                for &p in tile {
                    let i = p as usize;
                    let (row, r_sq) = (m.row(i), m.sqnorm(i));
                    for &q in b {
                        let j = q as usize;
                        let d2 = r_sq + m.sqnorm(j) - 2.0 * dense_dot(row, m.row(j));
                        out.push(d2.max(0.0).sqrt());
                    }
                }
            }
            (Data::Dense(m), Metric::L1) => {
                for &p in tile {
                    let row = m.row(p as usize);
                    for &q in b {
                        out.push(dense_l1(row, m.row(q as usize)));
                    }
                }
            }
            (Data::Sparse(m), Metric::Euclidean) => {
                for &p in tile {
                    let i = p as usize;
                    let r_sq = m.sqnorm(i);
                    for &q in b {
                        let j = q as usize;
                        let d2 = r_sq + m.sqnorm(j) - 2.0 * m.dot_rows(i, j);
                        out.push(d2.max(0.0).sqrt());
                    }
                }
            }
            (Data::Sparse(_), Metric::L1) => unreachable!("rejected in Space::new"),
        }
        space.count_bulk(((hi - lo) * b.len()) as u64);
        lo = hi;
    }
}

/// [`dists_rows`] over two contiguous row ranges — the dual-tree
/// leaf-leaf shape of all-pairs search on the tree-order arena, where a
/// node's points are one sequential slab on each side. Output is
/// row-major `a.len() × b.len()`; counted `|a|·|b|` per tile;
/// per-element math is exactly [`Space::dist_uncounted`].
pub fn dists_contig_rows(space: &Space, a: Range<usize>, b: Range<usize>, out: &mut Vec<f64>) {
    out.clear();
    out.reserve(a.len() * b.len());
    let mut lo = a.start;
    while lo < a.end {
        let hi = (lo + TILE).min(a.end);
        match (&space.data, space.metric) {
            (Data::Dense(m), Metric::Euclidean) if m.d > 0 => {
                // Both sides stream as slabs: the a-tile's rows
                // sequentially, the whole b-side re-read per a-row
                // (b is a leaf — small and cache-resident).
                let (a_slab, a_norms) = m.rows_slab(lo..hi);
                let (b_slab, b_norms) = m.rows_slab(b.clone());
                for (row, &r_sq) in a_slab.chunks_exact(m.d).zip(a_norms) {
                    for (brow, &b_sq) in b_slab.chunks_exact(m.d).zip(b_norms) {
                        let d2 = r_sq + b_sq - 2.0 * dense_dot(row, brow);
                        out.push(d2.max(0.0).sqrt());
                    }
                }
            }
            (Data::Dense(m), Metric::Euclidean) => {
                // d == 0: all distances degenerate to 0.
                for i in lo..hi {
                    for j in b.clone() {
                        let d2 = m.sqnorm(i) + m.sqnorm(j);
                        out.push(d2.max(0.0).sqrt());
                    }
                }
            }
            (Data::Dense(m), Metric::L1) => {
                for i in lo..hi {
                    let row = m.row(i);
                    for j in b.clone() {
                        out.push(dense_l1(row, m.row(j)));
                    }
                }
            }
            (Data::Sparse(m), Metric::Euclidean) => {
                for i in lo..hi {
                    let r_sq = m.sqnorm(i);
                    for j in b.clone() {
                        let d2 = r_sq + m.sqnorm(j) - 2.0 * m.dot_rows(i, j);
                        out.push(d2.max(0.0).sqrt());
                    }
                }
            }
            (Data::Sparse(_), Metric::L1) => unreachable!("rejected in Space::new"),
        }
        space.count_bulk(((hi - lo) * b.len()) as u64);
        lo = hi;
    }
}

/// Rigorous bound on `|d²₆₄ − d²₃₂|` for the f32 filter tier, as a
/// function of the dimension count `d` and `m2 = M²` where
/// `M = max(max|xᵢⱼ| over the data, max|qⱼ|)`.
///
/// Derivation (u = 2⁻²⁴ = f32 unit roundoff, every addend of every sum
/// below has magnitude ≤ M²):
///
/// * the 8-lane f32 dot ([`dense_dot_f32`]) is, per scalar product, a
///   chain of ≤ ⌈d/8⌉ lane adds + 7 tail adds + 7 combine adds + 1
///   product rounding ≤ `N = d + 16` roundings; the sparse
///   single-accumulator chain (`dot_vec_f32`) is ≤ d + 1 ≤ N. The
///   standard forward bound (Higham, *Accuracy and Stability of
///   Numerical Algorithms*, §3.1) gives
///   `|fl(x·q) − x·q| ≤ γ_N · d·M²` with `γ_N = N·u/(1 − N·u)`;
/// * the cached norms `r²₃₂`, `q²₃₂` are f32 roundings of sums of d
///   squares: same bound each, plus one as-cast rounding ≤ u·d·M²;
/// * combining `r²₃₂ + q²₃₂ − 2·dot₃₂` takes 3 more f32 ops on values
///   of magnitude ≤ 4·d·M².
///
/// Summing: `|d²₆₄ − d²₃₂| ≤ u·d·M²·(3γ_N/u + 2 + 12) + subnormals`.
/// With the [`F32Filter::new`] guard `N·u ≤ 0.01` we have
/// `γ_N ≤ 1.011·N·u`, so the total is `< u·d·M²·(3.04·(d+16) + 14)`,
/// and `2·d·(d+32) = 2d² + 64d` dominates `3.04·d + 62.6` for every
/// d ≥ 1 — the factor-2 leading term plus the enlarged constant leave
/// ≥ 25% slack at every dimension. Products that underflow to
/// subnormals break the relative-error model; the additive floor
/// `16·(d+1)·MIN_POSITIVE` covers one absolute underflow error per
/// rounding with room to spare.
pub fn f32_eps(d: usize, m2: f64) -> f64 {
    const U: f64 = 1.0 / (1u64 << 24) as f64;
    let df = d as f64;
    2.0 * U * df * (df + 32.0) * m2 + 16.0 * (df + 1.0) * (f32::MIN_POSITIVE as f64)
}

/// Per-query state of the f32 filter tier: the error margin ε and the
/// query's f32 squared norm. Built once per query by [`F32Filter::new`],
/// which returns `None` whenever the filter cannot be applied *safely*
/// — callers then take the plain f64 kernel, so a `None` is always
/// correct, just unaccelerated. The decision is a pure function of
/// (space flag, metric, d, cached max|x|, q), hence deterministic.
pub struct F32Filter {
    /// Rigorous upper bound on |d²₆₄ − d²₃₂| ([`f32_eps`]).
    pub eps: f64,
    /// ‖q‖² accumulated by the f32 kernel itself.
    q_sq32: f32,
}

impl F32Filter {
    /// Build the filter for one query, or decline. Declines when:
    /// the space's tier flag is off; the metric is not Euclidean;
    /// `d == 0` (nothing to accelerate) or `d > 100_000` (keeps
    /// `N·u ≤ 0.01` so the γ_N linearization in [`f32_eps`] holds);
    /// `4·d·M²` is not comfortably below `f32::MAX` (the 8-lane partial
    /// sums could overflow to ±inf, and an inf d²₃₂ from two
    /// overflowing norms could wrongly prune a genuinely close pair);
    /// or M is non-finite (data or query contains ±inf/NaN — the
    /// comparison below fails on NaN, falling through to `None`).
    pub fn new(space: &Space, q: &[f32]) -> Option<F32Filter> {
        if !space.f32_tier() || space.metric != Metric::Euclidean {
            return None;
        }
        let d = space.dim();
        if d == 0 || d > 100_000 {
            return None;
        }
        let mut m = space.data.max_abs();
        for &v in q {
            let a = v.abs();
            if a > m {
                m = a;
            }
            if !a.is_finite() {
                m = f32::INFINITY;
            }
        }
        let m2 = m as f64 * m as f64;
        if !(4.0 * d as f64 * m2 < f32::MAX as f64 / 2.0) {
            return None;
        }
        Some(F32Filter { eps: f32_eps(d, m2), q_sq32: dense_dot_f32(q, q) })
    }
}

/// [`dists_contig_to_vec`] behind the f32 filter tier: every row in the
/// range gets an 8-wide f32 d² evaluation; rows conclusively beyond the
/// threshold (`d²₃₂ − ε > thr²`) are pruned, the rest are recomputed
/// with the **exact** tier-off f64 expression, in range order, and
/// emitted as `(absolute row index, f64 distance)` pairs. A NaN d²₃₂
/// compares false and therefore survives to the exact path — the filter
/// never trusts a garbage f32 value to prune.
///
/// Soundness of the prune: `d²₆₄ ≥ d²₃₂ − ε > thr²`, and since `thr` is
/// representable and sqrt is correctly rounded and monotone,
/// `d₆₄ = √d²₆₄ > thr` — the tier-off scan would reject this row too.
///
/// Counted: `rows.len()` f32 evaluations ([`Space::count_bulk_f32`])
/// plus one f64 evaluation per survivor (the Table-2 budget), both
/// accounted per tile. `out_rows`/`out_d` are cleared and refilled.
pub fn dists_contig_to_vec_f32(
    space: &Space,
    rows: Range<usize>,
    q: &[f32],
    q_sq: f64,
    filter: &F32Filter,
    thr: f64,
    out_rows: &mut Vec<u32>,
    out_d: &mut Vec<f64>,
) {
    out_rows.clear();
    out_d.clear();
    let thr2 = thr * thr;
    let q_sq32 = filter.q_sq32;
    let eps = filter.eps;
    let mut lo = rows.start;
    while lo < rows.end {
        let hi = (lo + TILE).min(rows.end);
        let survivors_before = out_rows.len();
        match &space.data {
            Data::Dense(m) => {
                let (slab, norms32) = m.rows_slab_f32(lo..hi);
                for (t, (row, &r_sq32)) in slab.chunks_exact(m.d).zip(norms32).enumerate() {
                    let d2_32 = r_sq32 + q_sq32 - 2.0f32 * dense_dot_f32(row, q);
                    if d2_32 as f64 - eps > thr2 {
                        continue;
                    }
                    let i = lo + t;
                    let d2 = m.sqnorm(i) + q_sq - 2.0 * dense_dot(row, q);
                    out_rows.push(i as u32);
                    out_d.push(d2.max(0.0).sqrt());
                }
            }
            Data::Sparse(m) => {
                for i in lo..hi {
                    let d2_32 = m.sqnorm32(i) + q_sq32 - 2.0f32 * m.dot_vec_f32(i, q);
                    if d2_32 as f64 - eps > thr2 {
                        continue;
                    }
                    let d2 = m.sqnorm(i) + q_sq - 2.0 * m.dot_vec(i, q);
                    out_rows.push(i as u32);
                    out_d.push(d2.max(0.0).sqrt());
                }
            }
        }
        space.count_bulk_f32((hi - lo) as u64);
        space.count_bulk((out_rows.len() - survivors_before) as u64);
        lo = hi;
    }
}

/// Squared distances between dataset rows and dense centers, row-major
/// `rows.len() × centers.len()` as `f32` — the tile layout the XLA batch
/// engine produces. This is the scalar kernel promoted out of
/// `runtime::BatchDistanceEngine` (which now calls it as its non-XLA
/// fallback). NOT counted: callers decide the accounting, matching the
/// engine's bulk-count convention.
pub fn dist2_block(space: &Space, rows: &[u32], centers: &[Vec<f32>]) -> Vec<f32> {
    let k = centers.len();
    let c_sq: Vec<f64> = centers.iter().map(|c| dense_dot(c, c)).collect();
    let mut out = vec![0f32; rows.len() * k];
    for (ri, &p) in rows.iter().enumerate() {
        for (ci, center) in centers.iter().enumerate() {
            let d = space.dist_to_vec_uncounted(p as usize, center, c_sq[ci]);
            out[ri * k + ci] = (d * d) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DenseMatrix, SparseMatrix};
    use crate::rng::Rng;

    fn dense_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 2.0).collect();
        Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
    }

    fn sparse_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<(u32, f32)>> = (0..n)
            .map(|_| {
                (0..d as u32)
                    .filter(|_| rng.below(3) == 0)
                    .map(|j| (j, rng.normal() as f32))
                    .collect()
            })
            .collect();
        Space::euclidean(Data::Sparse(SparseMatrix::from_rows(d, &rows)))
    }

    #[test]
    fn to_vec_bit_identical_and_counted() {
        for space in [dense_space(300, 9, 1), sparse_space(300, 40, 2)] {
            let q: Vec<f32> = (0..space.dim()).map(|j| (j as f32).sin()).collect();
            let q_sq: f64 = q.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let rows: Vec<u32> = (0..space.n() as u32).step_by(2).collect();
            space.reset_count();
            let mut blocked = Vec::new();
            dists_to_vec(&space, &rows, &q, q_sq, &mut blocked);
            let blocked_count = space.dist_count();
            space.reset_count();
            let scalar: Vec<f64> = rows
                .iter()
                .map(|&p| space.dist_to_vec(p as usize, &q, q_sq))
                .collect();
            assert_eq!(space.dist_count(), blocked_count, "count mismatch");
            assert_eq!(blocked.len(), scalar.len());
            for (b, s) in blocked.iter().zip(&scalar) {
                assert_eq!(b.to_bits(), s.to_bits(), "blocked {b} vs scalar {s}");
            }
            // The contiguous form agrees with the gather form bit-wise.
            let ids: Vec<u32> = (20..170).collect();
            let mut by_ids = Vec::new();
            dists_to_vec(&space, &ids, &q, q_sq, &mut by_ids);
            let mut by_range = Vec::new();
            dists_contig_to_vec(&space, 20..170, &q, q_sq, &mut by_range);
            assert_eq!(by_ids, by_range);
        }
    }

    #[test]
    fn to_centers_bit_identical_and_counted() {
        for space in [dense_space(200, 6, 3), sparse_space(200, 30, 4)] {
            let mut rng = Rng::new(9);
            let centroids: Vec<Vec<f32>> = (0..7)
                .map(|_| (0..space.dim()).map(|_| rng.normal() as f32).collect())
                .collect();
            let c_sq: Vec<f64> = centroids.iter().map(|c| dense_dot(c, c)).collect();
            let cand: Vec<u32> = vec![0, 2, 5, 6];
            let rows: Vec<u32> = (0..space.n() as u32).step_by(3).collect();
            space.reset_count();
            let mut blocked = Vec::new();
            dists_to_centers(&space, &rows, &cand, &centroids, &c_sq, &mut blocked);
            let blocked_count = space.dist_count();
            space.reset_count();
            let mut scalar = Vec::new();
            for &p in &rows {
                for &c in &cand {
                    scalar.push(space.dist_to_vec(
                        p as usize,
                        &centroids[c as usize],
                        c_sq[c as usize],
                    ));
                }
            }
            assert_eq!(space.dist_count(), blocked_count, "count mismatch");
            for (b, s) in blocked.iter().zip(&scalar) {
                assert_eq!(b.to_bits(), s.to_bits());
            }
            // The contiguous form agrees with the gather form bit-wise.
            let mut by_range = Vec::new();
            let ident: Vec<u32> = (0..centroids.len() as u32).collect();
            dists_contig_to_centers(&space, 10..60, &ident, &centroids, &c_sq, &mut by_range);
            let ids: Vec<u32> = (10..60).collect();
            let mut by_ids = Vec::new();
            dists_to_centers(&space, &ids, &ident, &centroids, &c_sq, &mut by_ids);
            assert_eq!(by_range, by_ids);
        }
    }

    #[test]
    fn rows_bit_identical_and_counted() {
        for space in [dense_space(120, 5, 5), sparse_space(120, 25, 6)] {
            let a: Vec<u32> = (0..40).collect();
            let b: Vec<u32> = (60..110).collect();
            space.reset_count();
            let mut blocked = Vec::new();
            dists_rows(&space, &a, &b, &mut blocked);
            let blocked_count = space.dist_count();
            space.reset_count();
            let mut scalar = Vec::new();
            for &p in &a {
                for &q in &b {
                    scalar.push(space.dist(p as usize, q as usize));
                }
            }
            assert_eq!(space.dist_count(), blocked_count, "count mismatch");
            for (x, y) in blocked.iter().zip(&scalar) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            // The contiguous form agrees with the gather form bit-wise,
            // counts included.
            space.reset_count();
            let mut contig = Vec::new();
            dists_contig_rows(&space, 0..40, 60..110, &mut contig);
            assert_eq!(space.dist_count(), blocked_count, "contig count mismatch");
            assert_eq!(contig.len(), blocked.len());
            for (x, y) in contig.iter().zip(&blocked) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn l1_variant_matches_scalar() {
        let space = Space::new(
            Data::Dense(DenseMatrix::new(4, 3, vec![
                0., 0., 0., 1., -2., 3., 4., 4., 4., -1., 0., 1.,
            ])),
            Metric::L1,
        );
        let q = [1.0f32, 1.0, 1.0];
        let rows: Vec<u32> = (0..4).collect();
        let mut blocked = Vec::new();
        dists_to_vec(&space, &rows, &q, 3.0, &mut blocked);
        for (i, b) in blocked.iter().enumerate() {
            let s = space.dist_to_vec_uncounted(i, &q, 3.0);
            assert_eq!(b.to_bits(), s.to_bits());
        }
    }

    #[test]
    fn dist2_block_matches_pointwise() {
        let space = dense_space(30, 5, 7);
        let centers = vec![vec![0.0f32; 5], vec![1.0f32; 5]];
        let out = dist2_block(&space, &[3, 7, 11], &centers);
        assert_eq!(out.len(), 6);
        let expect = space.dist_to_vec_uncounted(7, &centers[1], 5.0).powi(2);
        assert!((out[3] as f64 - expect).abs() < 1e-4);
    }

    #[test]
    fn f32_filter_survivors_are_exact_and_pruning_is_sound() {
        for mut space in [dense_space(500, 9, 11), sparse_space(500, 40, 12)] {
            space.set_f32_tier(true);
            let q: Vec<f32> = (0..space.dim()).map(|j| (j as f32 * 0.3).cos()).collect();
            let q_sq: f64 = q.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let filter = F32Filter::new(&space, &q).expect("filter should build");
            let mut reference = Vec::new();
            dists_contig_to_vec(&space, 0..space.n(), &q, q_sq, &mut reference);
            // Pick a threshold that splits the data roughly in half.
            let mut sorted = reference.clone();
            sorted.sort_by(f64::total_cmp);
            let thr = sorted[space.n() / 2];
            space.reset_count();
            let (mut out_rows, mut out_d) = (Vec::new(), Vec::new());
            dists_contig_to_vec_f32(
                &space, 0..space.n(), &q, q_sq, &filter, thr, &mut out_rows, &mut out_d,
            );
            assert_eq!(space.f32_dist_count(), space.n() as u64);
            assert_eq!(space.dist_count(), out_rows.len() as u64);
            // Every non-pruned row carries the exact tier-off bits; every
            // pruned row is truly beyond the threshold.
            let survivors: std::collections::HashSet<u32> = out_rows.iter().copied().collect();
            for (row, &d_ref) in reference.iter().enumerate() {
                if d_ref <= thr {
                    assert!(survivors.contains(&(row as u32)), "row {row} wrongly pruned");
                }
            }
            for (&row, &d) in out_rows.iter().zip(&out_d) {
                assert_eq!(
                    d.to_bits(),
                    reference[row as usize].to_bits(),
                    "survivor {row} not bit-exact"
                );
            }
            // And some pruning actually happened at this threshold.
            assert!(out_rows.len() < space.n(), "filter pruned nothing");
        }
    }

    #[test]
    fn f32_filter_declines_when_unsafe() {
        // Tier off.
        let space = dense_space(10, 4, 13);
        assert!(F32Filter::new(&space, &[0.0; 4]).is_none());
        // L1 metric.
        let mut l1 = Space::new(
            Data::Dense(DenseMatrix::new(2, 2, vec![0., 0., 1., 1.])),
            Metric::L1,
        );
        l1.set_f32_tier(true);
        assert!(F32Filter::new(&l1, &[0.0; 2]).is_none());
        // d == 0.
        let mut empty = Space::euclidean(Data::Dense(DenseMatrix::new(3, 0, vec![])));
        empty.set_f32_tier(true);
        assert!(F32Filter::new(&empty, &[]).is_none());
        // Magnitudes near f32 overflow.
        let mut huge = Space::euclidean(Data::Dense(DenseMatrix::new(
            2,
            2,
            vec![1e19, 0., 0., 1e19],
        )));
        huge.set_f32_tier(true);
        assert!(F32Filter::new(&huge, &[0.0; 2]).is_none());
        // Non-finite query.
        let mut ok = dense_space(10, 4, 14);
        ok.set_f32_tier(true);
        assert!(F32Filter::new(&ok, &[0.0, f32::NAN, 0.0, 0.0]).is_none());
        assert!(F32Filter::new(&ok, &[0.0; 4]).is_some());
    }

    #[test]
    fn f32_eps_grows_with_dim_and_magnitude() {
        assert!(f32_eps(64, 1.0) < f32_eps(2000, 1.0));
        assert!(f32_eps(64, 1.0) < f32_eps(64, 100.0));
        // Sanity of scale: at d=64, M=1 the bound is ~2·2⁻²⁴·64·96 ≈ 7e-4.
        assert!(f32_eps(64, 1.0) < 1e-3);
        assert!(f32_eps(64, 1.0) > 1e-5);
    }

    #[test]
    fn empty_inputs() {
        let space = dense_space(10, 3, 8);
        let mut out = vec![1.0];
        dists_to_vec(&space, &[], &[0.0; 3], 0.0, &mut out);
        assert!(out.is_empty());
        dists_rows(&space, &[], &[1, 2], &mut out);
        assert!(out.is_empty());
        dists_rows(&space, &[1, 2], &[], &mut out);
        assert!(out.is_empty());
    }
}
