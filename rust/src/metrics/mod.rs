//! Metric spaces with instrumented distance counting.
//!
//! The paper's primary experimental metric is the **number of distance
//! computations** (Table 2), so every distance evaluated anywhere in this
//! crate flows through a [`Space`], which bumps a shared [`DistCounter`].
//! Batched XLA evaluations (rust/src/runtime/) count `n·k` per tile — the
//! same accounting a scalar loop would produce.

pub mod block;
mod counter;

pub use counter::DistCounter;

use crate::data::Data;
use std::sync::Arc;

/// Supported metrics. The triangle inequality holds for all of them —
/// that is the only property the trees rely on (paper §2).
///
/// Cosine dissimilarity is not listed because it is handled by L2-
/// normalizing rows at load time, after which Euclidean distance equals
/// `sqrt(2 − 2·cos)` — a metric, unlike `1 − cos` itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Euclidean,
    /// Manhattan / city-block. Dense data only.
    L1,
}

/// A dataset + metric + distance counter: the object every algorithm in
/// this crate operates on.
pub struct Space {
    pub data: Data,
    pub metric: Metric,
    counter: Arc<DistCounter>,
    /// Traversal/pruning statistics sink ([`crate::obs::ObsSink`]),
    /// shared exactly like the distance counter: every algorithm
    /// records nodes visited / pruned / leaf rows into the space it
    /// was handed, and views made by [`Space::select_rows`] charge the
    /// same sink. Pure counting — deterministic at every thread count.
    obs: Arc<crate::obs::ObsSink>,
    /// Cooperative cancellation flag ([`crate::cancel::CancelSlot`]),
    /// shared exactly like the counter and the obs sink: views made by
    /// [`Space::select_rows`] poll the same slot, so a traversal over
    /// the tree-order arena observes a cancel armed on the parent
    /// space. Polled only at [`Space::checkpoint`] — one relaxed load
    /// on the happy path, so results and distance counts are untouched
    /// unless a cancel actually fires.
    cancel: Arc<crate::cancel::CancelSlot>,
    /// Opt-in f32 filter tier ([`block::F32Filter`]): when set, the
    /// threshold-pruning leaf scans (knn / ball / anomaly) may run an
    /// 8-wide f32 pre-pass and only recompute ε-margin candidates in
    /// f64. Default **off**; results are bit-identical either way — the
    /// flag only trades f64 evaluations for cheaper f32 ones.
    f32_tier: bool,
}

impl Space {
    pub fn new(data: Data, metric: Metric) -> Self {
        if metric == Metric::L1 {
            assert!(
                !data.is_sparse(),
                "L1 metric is only implemented for dense data"
            );
        }
        Space {
            data,
            metric,
            counter: Arc::new(DistCounter::new()),
            obs: Arc::new(crate::obs::ObsSink::new()),
            cancel: Arc::new(crate::cancel::CancelSlot::new()),
            f32_tier: false,
        }
    }

    pub fn euclidean(data: Data) -> Self {
        Space::new(data, Metric::Euclidean)
    }

    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn dim(&self) -> usize {
        self.data.dim()
    }

    /// Shared handle to the distance counter.
    pub fn counter(&self) -> Arc<DistCounter> {
        Arc::clone(&self.counter)
    }

    /// The traversal/pruning statistics sink. Algorithms record into
    /// it (`space.obs().visit(depth)` etc.); the engine snapshots it
    /// around a query to attribute [`crate::obs::QueryStats`].
    #[inline]
    pub fn obs(&self) -> &crate::obs::ObsSink {
        &self.obs
    }

    /// Shared handle to the statistics sink (for callers that need to
    /// hold it across a space's lifetime, mirroring [`Space::counter`]).
    pub fn obs_shared(&self) -> Arc<crate::obs::ObsSink> {
        Arc::clone(&self.obs)
    }

    /// Shared handle to the cancellation slot (mirroring
    /// [`Space::counter`]). The coordinator holds this across a
    /// dataset's lifetime: the worker arms it before each job's
    /// traversal, `cancel`/the deadline timer set it from outside.
    pub fn cancel_shared(&self) -> Arc<crate::cancel::CancelSlot> {
        Arc::clone(&self.cancel)
    }

    /// Traversal checkpoint: called at frontier pops and leaf-scan
    /// chunk boundaries — never inside a distance kernel. On the happy
    /// path this is one relaxed load (plus one more when a fault drill
    /// is installed); when the slot has been set it unwinds with a
    /// typed [`crate::cancel::CancelUnwind`] payload that the
    /// coordinator catches and classifies.
    #[inline]
    pub fn checkpoint(&self) {
        self.cancel.check();
        if crate::faults::active() {
            crate::faults::leaf_checkpoint();
        }
    }

    /// Whether the opt-in f32 filter tier is enabled for this space.
    pub fn f32_tier(&self) -> bool {
        self.f32_tier
    }

    /// Enable/disable the f32 filter tier. Answers are bit-identical
    /// either way; only the (f64, f32) evaluation split changes.
    pub fn set_f32_tier(&mut self, on: bool) {
        self.f32_tier = on;
    }

    /// A new space holding the listed rows (in order), **sharing this
    /// space's distance counter** — so distances evaluated on the view
    /// are charged to the same Table-2 budget as distances on the
    /// original. This is how the tree-order arena is built
    /// ([`crate::tree::Layout`]): row `r` of the view is a bit-exact
    /// copy of row `ids[r]`, cached norms included, so every distance
    /// expression evaluates to the identical bits on either space.
    pub fn select_rows(&self, ids: &[u32]) -> Space {
        Space {
            data: self.data.select_rows(ids),
            metric: self.metric,
            counter: Arc::clone(&self.counter),
            obs: Arc::clone(&self.obs),
            cancel: Arc::clone(&self.cancel),
            // The arena inherits the tier flag (and, via Data::select_rows,
            // the parent's cached max|x|), so arena scans behave exactly
            // like original-order scans: same filter decision, same ε.
            f32_tier: self.f32_tier,
        }
    }

    /// Distances computed so far.
    pub fn dist_count(&self) -> u64 {
        self.counter.get()
    }

    /// f32 filter-tier evaluations so far (0 unless the tier is on).
    pub fn f32_dist_count(&self) -> u64 {
        self.counter.get_f32()
    }

    pub fn reset_count(&self) {
        self.counter.reset()
    }

    // ---------------------------------------------------------------
    // Counted distance evaluations.
    // ---------------------------------------------------------------

    /// Distance between datapoints `i` and `j`.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f64 {
        self.counter.add(1);
        self.dist_uncounted(i, j)
    }

    /// Distance between datapoint `i` and an arbitrary dense vector `q`
    /// with precomputed squared norm `q_sq` (Euclidean path). `q_sq` is
    /// ignored for L1.
    #[inline]
    pub fn dist_to_vec(&self, i: usize, q: &[f32], q_sq: f64) -> f64 {
        self.counter.add(1);
        self.dist_to_vec_uncounted(i, q, q_sq)
    }

    /// Distance between two arbitrary dense vectors (e.g. two node pivots).
    #[inline]
    pub fn dist_vv(&self, a: &[f32], b: &[f32]) -> f64 {
        self.counter.add(1);
        match self.metric {
            Metric::Euclidean => dense_euclidean(a, b),
            Metric::L1 => dense_l1(a, b),
        }
    }

    // ---------------------------------------------------------------
    // Uncounted primitives (used by tests and by callers that account
    // in bulk, e.g. the XLA tile path).
    // ---------------------------------------------------------------

    #[inline]
    pub fn dist_uncounted(&self, i: usize, j: usize) -> f64 {
        match (&self.data, self.metric) {
            (Data::Dense(m), Metric::Euclidean) => {
                // Expansion form with both norms cached: one fused
                // multiply-add per element (vs subtract+square), and the
                // dot kernel is 4-way unrolled. ~1.7× faster at d ≥ 54
                // (see docs/EXPERIMENTS.md §Perf).
                let d2 = m.sqnorm(i) + m.sqnorm(j) - 2.0 * dense_dot(m.row(i), m.row(j));
                d2.max(0.0).sqrt()
            }
            (Data::Dense(m), Metric::L1) => dense_l1(m.row(i), m.row(j)),
            (Data::Sparse(m), Metric::Euclidean) => {
                let d2 = m.sqnorm(i) + m.sqnorm(j) - 2.0 * m.dot_rows(i, j);
                d2.max(0.0).sqrt()
            }
            (Data::Sparse(_), Metric::L1) => unreachable!("rejected in Space::new"),
        }
    }

    #[inline]
    pub fn dist_to_vec_uncounted(&self, i: usize, q: &[f32], q_sq: f64) -> f64 {
        match (&self.data, self.metric) {
            (Data::Dense(m), Metric::Euclidean) => {
                // Expansion form with cached row norm: one pass over d.
                let d2 = m.sqnorm(i) + q_sq - 2.0 * dense_dot(m.row(i), q);
                d2.max(0.0).sqrt()
            }
            (Data::Dense(m), Metric::L1) => dense_l1(m.row(i), q),
            (Data::Sparse(m), Metric::Euclidean) => {
                let d2 = m.sqnorm(i) + q_sq - 2.0 * m.dot_vec(i, q);
                d2.max(0.0).sqrt()
            }
            (Data::Sparse(_), Metric::L1) => unreachable!("rejected in Space::new"),
        }
    }

    /// Record `n` distance computations performed out-of-band (XLA tiles).
    #[inline]
    pub fn count_bulk(&self, n: u64) {
        self.counter.add(n);
    }

    /// Record `n` f32 filter-tier evaluations (the f32 pre-pass of
    /// [`block::dists_contig_to_vec_f32`]). Kept out of the f64 Table-2
    /// budget by construction.
    #[inline]
    pub fn count_bulk_f32(&self, n: u64) {
        self.counter.add_f32(n);
    }

    // ---------------------------------------------------------------
    // Sufficient-statistic helpers (Euclidean only; the paper's footnote 1:
    // centroids require the ability to sum and scale datapoints).
    // ---------------------------------------------------------------

    /// Accumulate datapoint `i` into a dense f64 accumulator.
    #[inline]
    pub fn accumulate(&self, i: usize, acc: &mut [f64]) {
        match &self.data {
            Data::Dense(m) => {
                for (a, &v) in acc.iter_mut().zip(m.row(i)) {
                    *a += v as f64;
                }
            }
            Data::Sparse(m) => {
                let (idx, val) = m.row(i);
                for (&j, &v) in idx.iter().zip(val) {
                    acc[j as usize] += v as f64;
                }
            }
        }
    }

    /// Accumulate the elementwise *square* of datapoint `i` into a dense
    /// f64 accumulator — the per-dimension second moment Σxᵢ² cached on
    /// tree nodes ([`crate::tree::Node::sum2`]). For sparse rows only the
    /// stored entries contribute, exactly as in [`Space::accumulate`].
    #[inline]
    pub fn accumulate_sq(&self, i: usize, acc: &mut [f64]) {
        match &self.data {
            Data::Dense(m) => {
                for (a, &v) in acc.iter_mut().zip(m.row(i)) {
                    *a += v as f64 * v as f64;
                }
            }
            Data::Sparse(m) => {
                let (idx, val) = m.row(i);
                for (&j, &v) in idx.iter().zip(val) {
                    acc[j as usize] += v as f64 * v as f64;
                }
            }
        }
    }

    /// Single coordinate `j` of datapoint `i` (0.0 for absent sparse
    /// entries) — the response lookup of the kernel-regression path.
    #[inline]
    pub fn coord(&self, i: usize, j: usize) -> f32 {
        match &self.data {
            Data::Dense(m) => m.row(i)[j],
            Data::Sparse(m) => {
                let (idx, val) = m.row(i);
                match idx.iter().position(|&x| x as usize == j) {
                    Some(k) => val[k],
                    None => 0.0,
                }
            }
        }
    }

    /// Centroid of a set of datapoints.
    pub fn centroid(&self, points: &[u32]) -> Vec<f32> {
        let d = self.dim();
        let mut acc = vec![0f64; d];
        for &p in points {
            self.accumulate(p as usize, &mut acc);
        }
        let inv = if points.is_empty() { 0.0 } else { 1.0 / points.len() as f64 };
        acc.into_iter().map(|v| (v * inv) as f32).collect()
    }

    /// Sum of squared norms of a set of datapoints (the second moment the
    /// tree caches; gives exact within-node distortion in O(d)).
    pub fn sumsq(&self, points: &[u32]) -> f64 {
        points.iter().map(|&p| self.data.sqnorm(p as usize)).sum()
    }

    /// Densify row `i` into `out` (length >= dim; excess zero-padded).
    pub fn fill_row(&self, i: usize, out: &mut [f32]) {
        match &self.data {
            Data::Dense(m) => {
                let r = m.row(i);
                out[..r.len()].copy_from_slice(r);
                for v in &mut out[r.len()..] {
                    *v = 0.0;
                }
            }
            Data::Sparse(m) => m.fill_row(i, out),
        }
    }
}

// ---------------------------------------------------------------------
// Lane-structured dense kernels.
//
// Every dense kernel below is written as a fixed-width multi-accumulator
// loop: independent accumulators per lane, lane bodies free of bounds
// checks (`chunks_exact`), a deterministic scalar tail that folds the
// remainder into lane 0, and a *fixed* final combine order. No FMA, no
// reassociation left to the compiler's discretion: the laned order IS
// the canonical summation order of the repo, the same bits on every
// target, thread count and run. naive/tree and gather/contig paths all
// call these same functions (pallas-lint D3 pins that), so their
// bit-equivalences hold by construction. `tests/kernel_lanes.rs` pins
// lane-remainder dims (d mod 4 ∈ {0,1,2,3}) explicitly.
// ---------------------------------------------------------------------

#[inline]
pub fn dense_dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4 independent f64 lanes: breaks the serial dependence on a single
    // accumulator (the hot loop of every distance in the repo).
    let mut acc0 = 0.0f64;
    let mut acc1 = 0.0f64;
    let mut acc2 = 0.0f64;
    let mut acc3 = 0.0f64;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        acc0 += xa[0] as f64 * xb[0] as f64;
        acc1 += xa[1] as f64 * xb[1] as f64;
        acc2 += xa[2] as f64 * xb[2] as f64;
        acc3 += xa[3] as f64 * xb[3] as f64;
    }
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        acc0 += x as f64 * y as f64;
    }
    acc0 + acc1 + acc2 + acc3
}

#[inline]
pub fn dense_sqdist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // Same 4-lane structure as dense_dot, same combine order.
    let mut acc0 = 0.0f64;
    let mut acc1 = 0.0f64;
    let mut acc2 = 0.0f64;
    let mut acc3 = 0.0f64;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        let d0 = xa[0] as f64 - xb[0] as f64;
        let d1 = xa[1] as f64 - xb[1] as f64;
        let d2 = xa[2] as f64 - xb[2] as f64;
        let d3 = xa[3] as f64 - xb[3] as f64;
        acc0 += d0 * d0;
        acc1 += d1 * d1;
        acc2 += d2 * d2;
        acc3 += d3 * d3;
    }
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x as f64 - y as f64;
        acc0 += d * d;
    }
    acc0 + acc1 + acc2 + acc3
}

#[inline]
pub fn dense_euclidean(a: &[f32], b: &[f32]) -> f64 {
    dense_sqdist(a, b).sqrt()
}

#[inline]
pub fn dense_l1(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // Laned like dense_dot. This changed the L1 summation order (the old
    // kernel was a single-accumulator fold); the 4-lane order is now the
    // canonical L1 order everywhere, so naive≡tree still holds bit-wise.
    let mut acc0 = 0.0f64;
    let mut acc1 = 0.0f64;
    let mut acc2 = 0.0f64;
    let mut acc3 = 0.0f64;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        acc0 += (xa[0] as f64 - xb[0] as f64).abs();
        acc1 += (xa[1] as f64 - xb[1] as f64).abs();
        acc2 += (xa[2] as f64 - xb[2] as f64).abs();
        acc3 += (xa[3] as f64 - xb[3] as f64).abs();
    }
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        acc0 += (x as f64 - y as f64).abs();
    }
    acc0 + acc1 + acc2 + acc3
}

/// 8-wide f32 dot product — the filter-tier kernel. Twice the lane
/// width of [`dense_dot`] because the lanes are half as wide; all
/// arithmetic stays in f32 (the point of the tier is to never touch
/// f64 until a candidate survives). Deterministic for the same reasons
/// as the f64 kernels: fixed lanes, tail into lane 0, fixed pairwise
/// combine. The error-bound derivation in [`block::f32_eps`] counts
/// this exact chain: ≤ ⌈d/8⌉ lane adds + 7 tail adds + 7 combine adds.
#[inline]
pub fn dense_dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let mut acc4 = 0.0f32;
    let mut acc5 = 0.0f32;
    let mut acc6 = 0.0f32;
    let mut acc7 = 0.0f32;
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        acc0 += xa[0] * xb[0];
        acc1 += xa[1] * xb[1];
        acc2 += xa[2] * xb[2];
        acc3 += xa[3] * xb[3];
        acc4 += xa[4] * xb[4];
        acc5 += xa[5] * xb[5];
        acc6 += xa[6] * xb[6];
        acc7 += xa[7] * xb[7];
    }
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        acc0 += x * y;
    }
    ((acc0 + acc1) + (acc2 + acc3)) + ((acc4 + acc5) + (acc6 + acc7))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DenseMatrix, SparseMatrix};

    fn small_dense() -> Space {
        Space::euclidean(Data::Dense(DenseMatrix::new(
            3,
            2,
            vec![0.0, 0.0, 3.0, 4.0, 6.0, 8.0],
        )))
    }

    #[test]
    fn euclidean_distances() {
        let s = small_dense();
        assert!((s.dist(0, 1) - 5.0).abs() < 1e-9);
        assert!((s.dist(1, 2) - 5.0).abs() < 1e-9);
        assert!((s.dist(0, 2) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn counting() {
        let s = small_dense();
        assert_eq!(s.dist_count(), 0);
        s.dist(0, 1);
        s.dist_to_vec(0, &[1.0, 1.0], 2.0);
        s.dist_vv(&[0.0, 0.0], &[1.0, 0.0]);
        assert_eq!(s.dist_count(), 3);
        s.count_bulk(10);
        assert_eq!(s.dist_count(), 13);
        s.reset_count();
        assert_eq!(s.dist_count(), 0);
        // Uncounted primitives really don't count.
        s.dist_uncounted(0, 1);
        assert_eq!(s.dist_count(), 0);
    }

    #[test]
    fn dist_to_vec_matches_pointwise() {
        let s = small_dense();
        let q = [3.0f32, 4.0];
        let qsq = 25.0;
        assert!((s.dist_to_vec(0, &q, qsq) - 5.0).abs() < 1e-6);
        assert!((s.dist_to_vec(1, &q, qsq) - 0.0).abs() < 1e-6);
    }

    #[test]
    fn metric_axioms_euclidean_samples() {
        let s = small_dense();
        for i in 0..3 {
            assert_eq!(s.dist(i, i), 0.0);
            for j in 0..3 {
                assert_eq!(s.dist(i, j), s.dist(j, i));
                for k in 0..3 {
                    assert!(s.dist(i, k) <= s.dist(i, j) + s.dist(j, k) + 1e-9);
                }
            }
        }
    }

    #[test]
    fn l1_metric() {
        let s = Space::new(
            Data::Dense(DenseMatrix::new(2, 3, vec![0., 0., 0., 1., -2., 3.])),
            Metric::L1,
        );
        assert!((s.dist(0, 1) - 6.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "L1 metric")]
    fn l1_rejects_sparse() {
        let m = SparseMatrix::from_rows(4, &[vec![(0, 1.0)]]);
        Space::new(Data::Sparse(m), Metric::L1);
    }

    #[test]
    fn sparse_euclidean_matches_dense() {
        let rows = vec![
            vec![(0u32, 1.0f32), (2, 2.0)],
            vec![(1u32, 3.0f32)],
            vec![(0u32, 1.0f32), (1, 3.0), (2, 2.0)],
        ];
        let sp = Space::euclidean(Data::Sparse(SparseMatrix::from_rows(3, &rows)));
        let dn = Space::euclidean(Data::Dense(DenseMatrix::new(
            3,
            3,
            vec![1., 0., 2., 0., 3., 0., 1., 3., 2.],
        )));
        for i in 0..3 {
            for j in 0..3 {
                assert!(
                    (sp.dist(i, j) - dn.dist(i, j)).abs() < 1e-6,
                    "mismatch at ({i},{j})"
                );
            }
            let q = [0.5f32, -1.0, 2.0];
            let qsq = q.iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((sp.dist_to_vec(i, &q, qsq) - dn.dist_to_vec(i, &q, qsq)).abs() < 1e-6);
        }
    }

    #[test]
    fn centroid_and_sumsq() {
        let s = small_dense();
        let c = s.centroid(&[0, 1, 2]);
        assert_eq!(c, vec![3.0, 4.0]);
        assert_eq!(s.sumsq(&[1, 2]), 25.0 + 100.0);
    }

    #[test]
    fn accumulate_sq_matches_dense_and_sparse() {
        let s = small_dense();
        let mut acc = vec![0f64; 2];
        s.accumulate_sq(1, &mut acc);
        s.accumulate_sq(2, &mut acc);
        assert_eq!(acc, vec![9.0 + 36.0, 16.0 + 64.0]);
        // Trace of the per-dim second moments equals the cached sumsq.
        assert_eq!(acc.iter().sum::<f64>(), s.sumsq(&[1, 2]));

        let rows = vec![vec![(0u32, 2.0f32), (2, -3.0)], vec![(1u32, 4.0f32)]];
        let sp = Space::euclidean(Data::Sparse(SparseMatrix::from_rows(3, &rows)));
        let mut acc = vec![0f64; 3];
        sp.accumulate_sq(0, &mut acc);
        sp.accumulate_sq(1, &mut acc);
        assert_eq!(acc, vec![4.0, 16.0, 9.0]);
        // Single-coordinate lookup, absent sparse entries read as 0.
        assert_eq!(s.coord(1, 0), 3.0);
        assert_eq!(sp.coord(0, 2), -3.0);
        assert_eq!(sp.coord(0, 1), 0.0);
    }

    #[test]
    fn fill_row_pads() {
        let s = small_dense();
        let mut out = vec![7f32; 4];
        s.fill_row(1, &mut out);
        assert_eq!(out, vec![3.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn laned_kernels_handle_all_tail_lengths() {
        // Every lane remainder (d mod 4, and d mod 8 for the f32 kernel)
        // plus empty input; laned result must match a reference fold to
        // floating tolerance and be bit-stable across calls.
        for d in 0..=17usize {
            let a: Vec<f32> = (0..d).map(|i| (i as f32 * 0.7).sin()).collect();
            let b: Vec<f32> = (0..d).map(|i| (i as f32 * 1.3).cos()).collect();
            let dot_ref: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let l1_ref: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (x as f64 - y as f64).abs())
                .sum();
            assert!((dense_dot(&a, &b) - dot_ref).abs() < 1e-12, "d={d}");
            assert!((dense_l1(&a, &b) - l1_ref).abs() < 1e-12, "d={d}");
            assert!((dense_dot_f32(&a, &b) as f64 - dot_ref).abs() < 1e-5, "d={d}");
            assert_eq!(dense_dot(&a, &b).to_bits(), dense_dot(&a, &b).to_bits());
            assert_eq!(dense_l1(&a, &b).to_bits(), dense_l1(&a, &b).to_bits());
            assert_eq!(
                dense_dot_f32(&a, &b).to_bits(),
                dense_dot_f32(&a, &b).to_bits()
            );
        }
    }

    #[test]
    fn checkpoint_polls_the_shared_cancel_slot() {
        let s = small_dense();
        s.checkpoint(); // live slot: free no-op
        let view = s.select_rows(&[2, 0]);
        s.cancel_shared().set(crate::cancel::CancelReason::Deadline);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| view.checkpoint()))
            .expect_err("view must observe the parent's cancel");
        let cu = err
            .downcast_ref::<crate::cancel::CancelUnwind>()
            .expect("typed payload");
        assert_eq!(cu.reason, crate::cancel::CancelReason::Deadline);
        s.cancel_shared().arm();
        view.checkpoint(); // re-armed: live again
    }

    #[test]
    fn f32_tier_flag_defaults_off_and_propagates_to_views() {
        let mut s = small_dense();
        assert!(!s.f32_tier());
        s.set_f32_tier(true);
        assert!(s.f32_tier());
        let view = s.select_rows(&[2, 0]);
        assert!(view.f32_tier(), "select_rows must inherit the tier flag");
        assert_eq!(s.f32_dist_count(), 0);
        s.count_bulk_f32(7);
        assert_eq!(s.f32_dist_count(), 7);
        assert_eq!(view.f32_dist_count(), 7, "views share the counter");
        assert_eq!(s.dist_count(), 0, "f32 evals stay out of the f64 budget");
        s.reset_count();
        assert_eq!(s.f32_dist_count(), 0);
    }
}
