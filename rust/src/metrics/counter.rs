//! The distance-computation counter — the paper's measuring stick.

use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe counter of distance computations. Relaxed ordering is
/// sufficient: the counter is only read after the algorithm completes (or
/// for monitoring, where approximate freshness is fine), never used for
/// synchronization.
#[derive(Debug, Default)]
pub struct DistCounter {
    count: AtomicU64,
}

impl DistCounter {
    pub fn new() -> Self {
        DistCounter { count: AtomicU64::new(0) }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }

    /// Run `f` and return (result, distances incurred by `f`). Only valid
    /// when no other thread touches the counter concurrently.
    pub fn scoped<T>(&self, f: impl FnOnce() -> T) -> (T, u64) {
        let before = self.get();
        let out = f();
        (out, self.get() - before)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn add_get_reset() {
        let c = DistCounter::new();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn scoped_measures_delta() {
        let c = DistCounter::new();
        c.add(5);
        let (out, delta) = c.scoped(|| {
            c.add(10);
            "x"
        });
        assert_eq!(out, "x");
        assert_eq!(delta, 10);
        assert_eq!(c.get(), 15);
    }

    #[test]
    fn concurrent_adds_do_not_lose_counts() {
        let c = Arc::new(DistCounter::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    c.add(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }
}
