//! The distance-computation counter — the paper's measuring stick.
//!
//! Sharded per thread: with the parallel execution layer
//! ([`crate::parallel`]) many workers bump the counter concurrently, and
//! a single cache line of `AtomicU64` would serialize every distance
//! evaluation in the machine through one contended cell. Each thread is
//! instead assigned one of `SHARDS` cache-line-aligned cells
//! (round-robin at first use) and adds there; reads sum the shards.
//! Totals stay **exact** under any concurrency — each shard add is
//! atomic and the total is a plain sum — which is what lets the
//! serial ≡ parallel equivalence tests assert identical distance counts
//! across thread counts.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Number of counter cells. More shards than typical worker counts so
/// round-robin assignment rarely aliases two hot threads onto one line.
const SHARDS: usize = 16;

/// One cache line worth of counter, so two shards never false-share.
/// The f32 filter-tier cell rides in the same line: both counters are
/// bumped by the same thread in the same kernel tile, so sharing the
/// line is the cheap layout, not false sharing.
#[repr(align(64))]
#[derive(Debug, Default)]
struct Shard {
    count: AtomicU64,
    f32_count: AtomicU64,
}

/// Monotonically increasing round-robin source of shard assignments.
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// This thread's shard index, fixed at first use.
    static SHARD_INDEX: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % SHARDS;
}

/// Thread-safe counter of distance computations. Relaxed ordering is
/// sufficient: the counter is only read after the algorithm completes (or
/// for monitoring, where approximate freshness is fine), never used for
/// synchronization.
#[derive(Debug, Default)]
pub struct DistCounter {
    shards: [Shard; SHARDS],
}

impl DistCounter {
    pub fn new() -> Self {
        DistCounter::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        let shard = SHARD_INDEX.with(|i| *i);
        self.shards[shard].count.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.count.load(Ordering::Relaxed))
            .sum()
    }

    /// Record `n` reduced-precision (f32 filter-tier) evaluations. Kept
    /// in a separate cell so the paper's Table-2 f64 budget is never
    /// polluted by filter passes: an f32 scan over a tile counts here,
    /// and only the survivors recomputed exactly count in [`Self::add`].
    #[inline]
    pub fn add_f32(&self, n: u64) {
        let shard = SHARD_INDEX.with(|i| *i);
        self.shards[shard].f32_count.fetch_add(n, Ordering::Relaxed);
    }

    /// f32 filter-tier evaluations recorded so far.
    #[inline]
    pub fn get_f32(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.f32_count.load(Ordering::Relaxed))
            .sum()
    }

    pub fn reset(&self) {
        for s in &self.shards {
            s.count.store(0, Ordering::Relaxed);
            s.f32_count.store(0, Ordering::Relaxed);
        }
    }

    /// Run `f` and return (result, distances incurred by `f`). Only valid
    /// when no other thread touches the counter concurrently — `f` may
    /// itself be internally parallel (its workers' shards are included in
    /// the delta), but a concurrent *unrelated* workload would pollute it.
    pub fn scoped<T>(&self, f: impl FnOnce() -> T) -> (T, u64) {
        let before = self.get();
        let out = f();
        (out, self.get() - before)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn add_get_reset() {
        let c = DistCounter::new();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn f32_cell_is_independent_and_reset_clears_both() {
        let c = DistCounter::new();
        c.add(5);
        c.add_f32(100);
        c.add_f32(23);
        assert_eq!(c.get(), 5, "f32 adds must not leak into the f64 total");
        assert_eq!(c.get_f32(), 123);
        c.reset();
        assert_eq!(c.get(), 0);
        assert_eq!(c.get_f32(), 0);
    }

    #[test]
    fn scoped_measures_delta() {
        let c = DistCounter::new();
        c.add(5);
        let (out, delta) = c.scoped(|| {
            c.add(10);
            "x"
        });
        assert_eq!(out, "x");
        assert_eq!(delta, 10);
        assert_eq!(c.get(), 15);
    }

    #[test]
    fn concurrent_adds_do_not_lose_counts() {
        let c = Arc::new(DistCounter::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    c.add(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn shards_spread_across_threads_but_total_is_exact() {
        // Many short-lived threads each add a distinct amount; whatever
        // shard each lands on, the sum must be exact.
        let c = Arc::new(DistCounter::new());
        let mut handles = Vec::new();
        for i in 1..=32u64 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || c.add(i)));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), (1..=32).sum::<u64>());
    }
}
