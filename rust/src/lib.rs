//! # anchors-hierarchy
//!
//! A production-grade reproduction of **"The Anchors Hierarchy: Using the
//! Triangle Inequality to Survive High Dimensional Data"** (Andrew W.
//! Moore, UAI 2000): metric trees decorated with cached sufficient
//! statistics, built *middle-out* via the anchors hierarchy, and the three
//! tree-accelerated statistical algorithms the paper evaluates — exact
//! K-means, non-parametric anomaly detection, and all-pairs (correlated
//! attribute) search — plus the §6 extensions (dual-tree MST /
//! dependency trees, accelerated spherical Gaussian mixtures, k-NN).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — all tree/anchor algorithms, dataset suite,
//!   distance accounting, the batch-job coordinator, and the bench harness
//!   that regenerates every table and figure of the paper.
//! * **L2/L1 (python/, build-time only)** — a JAX compute graph wrapping a
//!   Pallas tiled pairwise-distance kernel, AOT-lowered to HLO text in
//!   `artifacts/`. The rust [`runtime`] loads those artifacts through
//!   PJRT (the `xla` crate) and uses them for dense leaf-level distance
//!   blocks. Python never runs at request time.
//!
//! ## Quickstart
//!
//! Build one [`engine::Index`] over a dataset, then run any of the eight
//! query families against it — the build-once / query-many model the
//! paper argues for:
//!
//! ```no_run
//! use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
//! use anchors_hierarchy::engine::{IndexBuilder, KmeansQuery, KnnQuery, KnnTarget, Query,
//!                                 QueryResult};
//!
//! let index = IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Cell, 0.1))
//!     .rmin(30)
//!     .build();
//! let results = index.run_batch(&[
//!     Query::Kmeans(KmeansQuery { k: 20, iters: 10, ..Default::default() }),
//!     Query::Knn(KnnQuery { target: KnnTarget::Point(0), k: 5, ..Default::default() }),
//! ]);
//! if let QueryResult::Kmeans { distortion, .. } = &results[0] {
//!     println!("distortion {distortion} ({} distance computations)", index.dist_count());
//! }
//! ```
//!
//! The free functions in [`algorithms`] remain available for
//! fine-grained control; the [`engine`] facade is how the CLI, the batch
//! [`coordinator`] and the TCP server construct and execute work.

pub mod algorithms;
pub mod anchors;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dataset;
pub mod engine;
pub mod json;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod tree;
