//! # anchors-hierarchy
//!
//! A production-grade reproduction of **"The Anchors Hierarchy: Using the
//! Triangle Inequality to Survive High Dimensional Data"** (Andrew W.
//! Moore, UAI 2000): metric trees decorated with cached sufficient
//! statistics, built *middle-out* via the anchors hierarchy, and the three
//! tree-accelerated statistical algorithms the paper evaluates — exact
//! K-means, non-parametric anomaly detection, and all-pairs (correlated
//! attribute) search — plus the §6 extensions (dual-tree MST /
//! dependency trees, accelerated spherical Gaussian mixtures, k-NN).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — all tree/anchor algorithms, dataset suite,
//!   distance accounting, the batch-job coordinator, and the bench harness
//!   that regenerates every table and figure of the paper.
//! * **L2/L1 (python/, build-time only)** — a JAX compute graph wrapping a
//!   Pallas tiled pairwise-distance kernel, AOT-lowered to HLO text in
//!   `artifacts/`. The rust [`runtime`] loads those artifacts through
//!   PJRT (the `xla` crate) and uses them for dense leaf-level distance
//!   blocks. Python never runs at request time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
//! use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
//! use anchors_hierarchy::algorithms::kmeans;
//!
//! let space = DatasetSpec::scaled(DatasetKind::Cell, 0.1).build();
//! let tree = middle_out::build(&space, &MiddleOutConfig::default());
//! let result = kmeans::tree_lloyd(
//!     &space, &tree, kmeans::Init::Anchors, 20, 50, &kmeans::KmeansOpts::default());
//! println!("distortion {}", result.distortion);
//! ```

pub mod algorithms;
pub mod anchors;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dataset;
pub mod json;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod tree;
