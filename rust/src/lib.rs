//! # anchors-hierarchy
//!
//! A production-grade reproduction of **"The Anchors Hierarchy: Using the
//! Triangle Inequality to Survive High Dimensional Data"** (Andrew W.
//! Moore, UAI 2000): metric trees decorated with cached sufficient
//! statistics, built *middle-out* via the anchors hierarchy, and the three
//! tree-accelerated statistical algorithms the paper evaluates — exact
//! K-means, non-parametric anomaly detection, and all-pairs (correlated
//! attribute) search — plus the §6 extensions (dual-tree MST /
//! dependency trees, accelerated spherical Gaussian mixtures, k-NN).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — all tree/anchor algorithms, dataset suite,
//!   distance accounting, the [`parallel`] execution layer, the batch-job
//!   coordinator (shardable via
//!   [`coordinator::ShardedCoordinator`]: consistent-hash dataset
//!   routing over N independent shards), and the bench harness that
//!   regenerates every table and figure of the paper.
//! * **L2/L1 (python/, build-time only)** — a JAX compute graph wrapping a
//!   Pallas tiled pairwise-distance kernel, AOT-lowered to HLO text in
//!   `artifacts/`. The rust [`runtime`] loads those artifacts through
//!   PJRT (the `xla` crate) and uses them for dense leaf-level distance
//!   blocks. Python never runs at request time.
//!
//! `docs/ARCHITECTURE.md` maps every paper section to its module and
//! traces a query's life from TCP op to tree traversal.
//!
//! ## Quickstart
//!
//! Build one [`engine::Index`] over a dataset, then run any of the eight
//! query families against it — the build-once / query-many model the
//! paper argues for. The [`parallel::Parallelism`] knob sets the worker
//! budget for the tree build and for batch dispatch; every setting
//! produces bit-identical results, so it is purely a wall-clock control:
//!
//! ```
//! use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
//! use anchors_hierarchy::engine::{IndexBuilder, KmeansQuery, KnnQuery, KnnTarget, Query,
//!                                 QueryResult};
//! use anchors_hierarchy::parallel::Parallelism;
//!
//! let index = IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Squiggles, 0.004))
//!     .rmin(16)
//!     .parallelism(Parallelism::Fixed(2)) // or Auto (default) / Serial
//!     .build();
//! let results = index.run_batch(&[
//!     Query::Kmeans(KmeansQuery { k: 4, iters: 3, ..Default::default() }),
//!     Query::Knn(KnnQuery { target: KnnTarget::Point(0), k: 5, ..Default::default() }),
//! ]);
//! assert_eq!(results.len(), 2);
//! let QueryResult::Kmeans { distortion, .. } = &results[0] else { panic!("wrong variant") };
//! assert!(distortion.is_finite() && index.dist_count() > 0);
//! ```
//!
//! The free functions in [`algorithms`] remain available for
//! fine-grained control; the [`engine`] facade is how the CLI, the batch
//! [`coordinator`] and the TCP server construct and execute work.

// `deny` rather than `forbid` for unsafe_code: the one sanctioned unsafe
// surface is the worker pool in `parallel/` (scoped-lifetime transmute +
// Send assertion), which opts back in with documented `#[allow]`s. A
// `forbid` here would make those local opt-ins impossible.
#![deny(unsafe_code)]
#![deny(unreachable_pub)]

pub mod algorithms;
pub mod anchors;
pub mod bench;
pub mod cancel;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dataset;
pub mod engine;
pub mod faults;
pub mod ids;
pub mod json;
pub mod metrics;
pub mod obs;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod tree;
