//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and run them
//! from the rust hot path.
//!
//! `make artifacts` (python, build-time only) lowers each program variant
//! to HLO **text** — the interchange format that survives the jax≥0.5 /
//! xla_extension 0.5.1 proto-id mismatch — plus `manifest.json`. This
//! module parses the manifest, compiles variants on the PJRT CPU client
//! *lazily* (first use) and exposes [`BatchDistanceEngine`], which answers
//! dense (points × centers) squared-distance blocks of arbitrary shape by
//! tiling/padding to the compiled (tile_n × tile_k × d) shapes.
//!
//! Zero padding is exact for squared Euclidean distances, so results for
//! the real rows/cols are bit-stable; padded rows/cols are sliced away
//! before returning. Counting: callers account `n·k` distances per block
//! via [`crate::metrics::Space::count_bulk`] — identical to the scalar
//! accounting.

mod artifacts;

pub use artifacts::{Manifest, Variant};

use crate::metrics::Space;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Which AOT program a variant implements.
pub const PROGRAM_PAIRWISE: &str = "pairwise_d2";
pub const PROGRAM_KMEANS_ACC: &str = "kmeans_accumulate";
pub const PROGRAM_RANGE_COUNT: &str = "range_count";

/// A compiled executable plus its shape contract.
struct LoadedVariant {
    exe: xla::PjRtLoadedExecutable,
    variant: Variant,
}

/// The PJRT engine: owns the client and the lazily-compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    loaded: Mutex<HashMap<(String, usize), std::sync::Arc<LoadedVariant>>>,
}

impl Engine {
    /// Open the artifact directory (usually `artifacts/`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, dir, manifest, loaded: Mutex::new(HashMap::new()) })
    }

    /// Open `artifacts/` relative to the repo root, walking up from cwd —
    /// convenient for tests/benches/examples run from any directory.
    pub fn open_default() -> Result<Engine> {
        let mut dir = std::env::current_dir()?;
        loop {
            let candidate = dir.join("artifacts");
            if candidate.join("manifest.json").exists() {
                return Engine::open(candidate);
            }
            if !dir.pop() {
                return Err(anyhow!(
                    "no artifacts/manifest.json found; run `make artifacts`"
                ));
            }
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Smallest compiled feature width ≥ `dim`, if any.
    pub fn width_for(&self, program: &str, dim: usize) -> Option<usize> {
        self.manifest
            .variants
            .iter()
            .filter(|v| v.program == program && v.d >= dim)
            .map(|v| v.d)
            .min()
    }

    /// Fetch (compiling on first use) the variant of `program` with
    /// feature width exactly `d`.
    fn load(&self, program: &str, d: usize) -> Result<std::sync::Arc<LoadedVariant>> {
        let key = (program.to_string(), d);
        let mut guard = self.loaded.lock().unwrap();
        if let Some(v) = guard.get(&key) {
            return Ok(v.clone());
        }
        let variant = self
            .manifest
            .variants
            .iter()
            .find(|v| v.program == program && v.d == d)
            .ok_or_else(|| anyhow!("no variant {program} d={d} in manifest"))?
            .clone();
        let path = self.dir.join(&variant.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        let loaded = std::sync::Arc::new(LoadedVariant { exe, variant });
        guard.insert(key, loaded.clone());
        Ok(loaded)
    }

    /// Run the raw pairwise program once on pre-padded buffers.
    /// `x` is `tile_n × d` row-major, `c` is `tile_k × d`. Returns the
    /// `tile_n × tile_k` squared-distance tile.
    pub fn pairwise_tile(&self, d: usize, x: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        let lv = self.load(PROGRAM_PAIRWISE, d)?;
        let (n, k) = (lv.variant.n, lv.variant.k);
        debug_assert_eq!(x.len(), n * d);
        debug_assert_eq!(c.len(), k * d);
        let xl = xla::Literal::vec1(x)
            .reshape(&[n as i64, d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let cl = xla::Literal::vec1(c)
            .reshape(&[k as i64, d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let result = lv
            .exe
            .execute::<xla::Literal>(&[xl, cl])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Run one `kmeans_accumulate` tile: returns (counts[k], sums[k·d],
    /// distortion, assign[n]) for the padded tile.
    pub fn kmeans_accumulate_tile(
        &self,
        d: usize,
        x: &[f32],
        c: &[f32],
        xmask: &[f32],
        cmask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, f32, Vec<i32>)> {
        let lv = self.load(PROGRAM_KMEANS_ACC, d)?;
        let (n, k) = (lv.variant.n, lv.variant.k);
        debug_assert_eq!(x.len(), n * d);
        debug_assert_eq!(c.len(), k * d);
        let xl = xla::Literal::vec1(x)
            .reshape(&[n as i64, d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let cl = xla::Literal::vec1(c)
            .reshape(&[k as i64, d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let xm = xla::Literal::vec1(xmask);
        let cm = xla::Literal::vec1(cmask);
        let result = lv
            .exe
            .execute::<xla::Literal>(&[xl, cl, xm, cm])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (counts, sums, distortion, assign) =
            result.to_tuple4().map_err(|e| anyhow!("{e:?}"))?;
        Ok((
            counts.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            sums.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            distortion
                .get_first_element::<f32>()
                .map_err(|e| anyhow!("{e:?}"))?,
            assign.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Run one `range_count` tile: counts[k] of dataset rows within the
    /// per-query radius.
    pub fn range_count_tile(
        &self,
        d: usize,
        x: &[f32],
        q: &[f32],
        xmask: &[f32],
        radius2: &[f32],
    ) -> Result<Vec<f32>> {
        let lv = self.load(PROGRAM_RANGE_COUNT, d)?;
        let (n, k) = (lv.variant.n, lv.variant.k);
        debug_assert_eq!(x.len(), n * d);
        debug_assert_eq!(q.len(), k * d);
        let xl = xla::Literal::vec1(x)
            .reshape(&[n as i64, d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let ql = xla::Literal::vec1(q)
            .reshape(&[k as i64, d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let xm = xla::Literal::vec1(xmask);
        let r2 = xla::Literal::vec1(radius2);
        let result = lv
            .exe
            .execute::<xla::Literal>(&[xl, ql, xm, r2])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    pub fn tile_n(&self) -> usize {
        self.manifest.tile_n
    }

    pub fn tile_k(&self) -> usize {
        self.manifest.tile_k
    }
}

/// High-level batched-distance service used by the algorithms: answers
/// arbitrary (rows × centers) squared-distance blocks by padding into the
/// compiled tiles.
///
/// **Threading model.** The xla crate's PJRT client is `Rc`-based and
/// neither `Send` nor `Sync`, so this facade holds only `Send + Sync`
/// metadata (artifact path + manifest) and each thread lazily opens its
/// own [`Engine`] on first use (cached in a thread-local). Workers are
/// long-lived, so the per-thread client cost amortizes to zero.
#[derive(Debug)]
pub struct BatchDistanceEngine {
    dir: PathBuf,
    manifest: Manifest,
    /// Blocks smaller than this (n·k product) are not worth the FFI trip;
    /// callers fall back to scalar loops below it.
    min_block: usize,
}

thread_local! {
    static TL_ENGINES: std::cell::RefCell<HashMap<PathBuf, std::rc::Rc<Engine>>> =
        std::cell::RefCell::new(HashMap::new());
}

impl BatchDistanceEngine {
    /// Open the artifact directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(BatchDistanceEngine { dir, manifest, min_block: 512 })
    }

    /// Open `artifacts/` relative to the repo root, walking up from cwd.
    pub fn open_default() -> Result<Self> {
        let mut dir = std::env::current_dir()?;
        loop {
            let candidate = dir.join("artifacts");
            if candidate.join("manifest.json").exists() {
                return Self::open(candidate);
            }
            if !dir.pop() {
                return Err(anyhow!(
                    "no artifacts/manifest.json found; run `make artifacts`"
                ));
            }
        }
    }

    pub fn with_min_block(mut self, min_block: usize) -> Self {
        self.min_block = min_block;
        self
    }

    pub fn min_block(&self) -> usize {
        self.min_block
    }

    pub fn tile_n(&self) -> usize {
        self.manifest.tile_n
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Smallest compiled feature width ≥ `dim`, if any.
    pub fn width_for(&self, program: &str, dim: usize) -> Option<usize> {
        self.manifest
            .variants
            .iter()
            .filter(|v| v.program == program && v.d >= dim)
            .map(|v| v.d)
            .min()
    }

    /// Run `f` against this thread's engine (opened lazily).
    pub fn with_engine<T>(&self, f: impl FnOnce(&Engine) -> Result<T>) -> Result<T> {
        TL_ENGINES.with(|cell| {
            let engine = {
                let mut map = cell.borrow_mut();
                match map.get(&self.dir) {
                    Some(e) => e.clone(),
                    None => {
                        let e = std::rc::Rc::new(Engine::open(&self.dir)?);
                        map.insert(self.dir.clone(), e.clone());
                        e
                    }
                }
            };
            f(&engine)
        })
    }

    /// Squared distances between dataset rows `rows` and dense `centers`.
    /// Returns row-major `rows.len() × centers.len()`. Falls back to a
    /// scalar loop when no compiled width fits the dimension or the
    /// engine errors.
    ///
    /// NOT counted here — callers decide the accounting (the algorithms
    /// count n·k in bulk, matching the scalar path).
    pub fn dist2_block(&self, space: &Space, rows: &[u32], centers: &[Vec<f32>]) -> Vec<f32> {
        let dim = space.dim();
        let k = centers.len();
        let width = match self.width_for(PROGRAM_PAIRWISE, dim) {
            Some(w) => w,
            None => return crate::metrics::block::dist2_block(space, rows, centers),
        };
        let (tn, tk) = (self.manifest.tile_n, self.manifest.tile_k);
        let mut out = vec![0f32; rows.len() * k];
        // Pre-pad centers once per K-tile.
        let mut x_tile = vec![0f32; tn * width];
        let mut c_tile = vec![0f32; tk * width];
        let mut kc = 0usize;
        while kc < k {
            let kh = (kc + tk).min(k);
            for v in c_tile.iter_mut() {
                *v = 0.0;
            }
            for (ci, center) in centers[kc..kh].iter().enumerate() {
                c_tile[ci * width..ci * width + dim].copy_from_slice(center);
            }
            let mut rc = 0usize;
            while rc < rows.len() {
                let rh = (rc + tn).min(rows.len());
                for v in x_tile.iter_mut() {
                    *v = 0.0;
                }
                for (ri, &p) in rows[rc..rh].iter().enumerate() {
                    space.fill_row(p as usize, &mut x_tile[ri * width..(ri + 1) * width]);
                }
                let tile = self.with_engine(|e| e.pairwise_tile(width, &x_tile, &c_tile));
                match tile {
                    Ok(tile) => {
                        for ri in 0..(rh - rc) {
                            for ci in 0..(kh - kc) {
                                out[(rc + ri) * k + (kc + ci)] = tile[ri * tk + ci];
                            }
                        }
                    }
                    Err(_) => {
                        // Degrade gracefully: scalar fill for this block.
                        for (ri, &p) in rows[rc..rh].iter().enumerate() {
                            for (ci, center) in centers[kc..kh].iter().enumerate() {
                                let d = space.dist_to_vec_uncounted(
                                    p as usize,
                                    center,
                                    crate::metrics::dense_dot(center, center),
                                );
                                out[(rc + ri) * k + (kc + ci)] = (d * d) as f32;
                            }
                        }
                    }
                }
                rc = rh;
            }
            kc = kh;
        }
        out
    }
}

/// Scalar fallback with identical output layout — the kernel itself now
/// lives at the metrics level ([`crate::metrics::block::dist2_block`])
/// so the non-XLA algorithm paths share it too.
#[cfg(test)]
use crate::metrics::block::dist2_block as scalar_block;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;

    fn random_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
    }

    fn engine() -> Option<BatchDistanceEngine> {
        BatchDistanceEngine::open_default().ok()
    }

    #[test]
    fn scalar_block_matches_pointwise() {
        let space = random_space(20, 5, 1);
        let centers = vec![vec![0.0f32; 5], vec![1.0f32; 5]];
        let out = scalar_block(&space, &[3, 7, 11], &centers);
        assert_eq!(out.len(), 6);
        let d = space.dist_uncounted(3, 3); // 0, sanity
        assert_eq!(d, 0.0);
        let expect = space.dist_to_vec_uncounted(7, &centers[1], 5.0).powi(2);
        assert!((out[3] as f64 - expect).abs() < 1e-4);
    }

    #[test]
    fn xla_block_matches_scalar_small_dim() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let space = random_space(300, 7, 2); // pads 7 -> 8
        let centers: Vec<Vec<f32>> = (0..5)
            .map(|i| vec![i as f32 * 0.5; 7])
            .collect();
        let rows: Vec<u32> = (0..300).collect();
        let got = eng.dist2_block(&space, &rows, &centers);
        let want = scalar_block(&space, &rows, &centers);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn xla_block_matches_scalar_wide_dim() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let space = random_space(40, 200, 3); // pads 200 -> 256
        let centers: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut rng = Rng::new(100 + i);
                (0..200).map(|_| rng.normal() as f32).collect()
            })
            .collect();
        let rows: Vec<u32> = (0..40).collect();
        let got = eng.dist2_block(&space, &rows, &centers);
        let want = scalar_block(&space, &rows, &centers);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-2 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn multi_tile_k() {
        // More centers than one K-tile (128).
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let space = random_space(64, 4, 4);
        let centers: Vec<Vec<f32>> = (0..150)
            .map(|i| vec![(i % 13) as f32, (i % 7) as f32, 0.0, 1.0])
            .collect();
        let rows: Vec<u32> = (0..64).collect();
        let got = eng.dist2_block(&space, &rows, &centers);
        let want = scalar_block(&space, &rows, &centers);
        assert_eq!(got.len(), 64 * 150);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn width_selection() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(eng.width_for(PROGRAM_PAIRWISE, 2), Some(8));
        assert_eq!(eng.width_for(PROGRAM_PAIRWISE, 8), Some(8));
        assert_eq!(eng.width_for(PROGRAM_PAIRWISE, 9), Some(64));
        assert_eq!(eng.width_for(PROGRAM_PAIRWISE, 1024), Some(1024));
        assert_eq!(eng.width_for(PROGRAM_PAIRWISE, 5000), None);
    }

    #[test]
    fn kmeans_accumulate_tile_roundtrip() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (tn, tk, d) = (eng.manifest().tile_n, eng.manifest().tile_k, 8usize);
        let mut rng = Rng::new(5);
        let mut x = vec![0f32; tn * d];
        let mut xmask = vec![0f32; tn];
        let n_real = 100;
        for i in 0..n_real {
            xmask[i] = 1.0;
            for j in 0..d {
                x[i * d + j] = rng.normal() as f32;
            }
        }
        let mut c = vec![0f32; tk * d];
        let mut cmask = vec![0f32; tk];
        let k_real = 4;
        for i in 0..k_real {
            cmask[i] = 1.0;
            for j in 0..d {
                c[i * d + j] = rng.normal() as f32 * 2.0;
            }
        }
        let (counts, sums, distortion, assign) = eng
            .with_engine(|e| e.kmeans_accumulate_tile(d, &x, &c, &xmask, &cmask))
            .unwrap();
        // Mass conservation.
        let total: f32 = counts.iter().sum();
        assert_eq!(total, n_real as f32);
        for ci in k_real..tk {
            assert_eq!(counts[ci], 0.0, "padded center got mass");
        }
        // Assignments in range for real rows.
        for i in 0..n_real {
            assert!((assign[i] as usize) < k_real);
        }
        // Distortion equals the sum over real rows of min d2.
        let mut manual = 0f64;
        for i in 0..n_real {
            let mut best = f64::INFINITY;
            for ci in 0..k_real {
                let mut d2 = 0f64;
                for j in 0..d {
                    let diff = (x[i * d + j] - c[ci * d + j]) as f64;
                    d2 += diff * diff;
                }
                best = best.min(d2);
            }
            manual += best;
        }
        assert!(
            (distortion as f64 - manual).abs() < 1e-2 * (1.0 + manual),
            "{distortion} vs {manual}"
        );
        let _ = sums;
    }

    #[test]
    fn range_count_tile_matches_manual() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (tn, tk, d) = (eng.manifest().tile_n, eng.manifest().tile_k, 8usize);
        let mut rng = Rng::new(6);
        let mut x = vec![0f32; tn * d];
        let mut xmask = vec![0f32; tn];
        for i in 0..50 {
            xmask[i] = 1.0;
            for j in 0..d {
                x[i * d + j] = rng.normal() as f32;
            }
        }
        let mut q = vec![0f32; tk * d];
        for j in 0..d {
            q[j] = 0.0; // query at origin
        }
        let mut r2 = vec![0f32; tk];
        r2[0] = (d as f32) * 1.0; // within ~1 std in each dim
        let counts = eng
            .with_engine(|e| e.range_count_tile(d, &x, &q, &xmask, &r2))
            .unwrap();
        let manual = (0..50)
            .filter(|&i| {
                let s: f64 = (0..d).map(|j| (x[i * d + j] as f64).powi(2)).sum();
                s <= r2[0] as f64
            })
            .count();
        assert_eq!(counts[0] as usize, manual);
    }
}
