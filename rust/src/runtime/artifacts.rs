//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use crate::json::{self, Value};
use anyhow::{anyhow, Result};
use std::path::Path;

/// One compiled (program, shape) variant.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    pub program: String,
    pub n: usize,
    pub k: usize,
    pub d: usize,
    pub file: String,
    pub outputs: Vec<String>,
}

/// The full artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tile_n: usize,
    pub tile_k: usize,
    pub variants: Vec<Variant>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let field_usize = |obj: &Value, key: &str| -> Result<usize> {
            obj.get(key)
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow!("manifest missing numeric field {key:?}"))
        };
        let field_str = |obj: &Value, key: &str| -> Result<String> {
            obj.get(key)
                .and_then(Value::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("manifest missing string field {key:?}"))
        };
        let tile_n = field_usize(&v, "tile_n")?;
        let tile_k = field_usize(&v, "tile_k")?;
        let variants = v
            .get("variants")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest missing variants array"))?
            .iter()
            .map(|item| {
                Ok(Variant {
                    program: field_str(item, "program")?,
                    n: field_usize(item, "n")?,
                    k: field_usize(item, "k")?,
                    d: field_usize(item, "d")?,
                    file: field_str(item, "file")?,
                    outputs: item
                        .get("outputs")
                        .and_then(Value::as_arr)
                        .map(|arr| {
                            arr.iter()
                                .filter_map(|o| o.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { tile_n, tile_k, variants })
    }

    /// Programs present in the manifest (deduped).
    pub fn programs(&self) -> Vec<&str> {
        let mut p: Vec<&str> = self.variants.iter().map(|v| v.program.as_str()).collect();
        p.sort();
        p.dedup();
        p
    }

    /// Feature widths available for a program, ascending.
    pub fn widths(&self, program: &str) -> Vec<usize> {
        let mut w: Vec<usize> = self
            .variants
            .iter()
            .filter(|v| v.program == program)
            .map(|v| v.d)
            .collect();
        w.sort_unstable();
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "tile_n": 256, "tile_k": 128,
      "variants": [
        {"program": "pairwise_d2", "n": 256, "k": 128, "d": 8,
         "file": "pairwise_d2_n256_k128_d8.hlo.txt", "outputs": ["d2[n,k]f32"]},
        {"program": "pairwise_d2", "n": 256, "k": 128, "d": 64,
         "file": "pairwise_d2_n256_k128_d64.hlo.txt", "outputs": ["d2[n,k]f32"]},
        {"program": "kmeans_accumulate", "n": 256, "k": 128, "d": 8,
         "file": "kmeans_accumulate_n256_k128_d8.hlo.txt",
         "outputs": ["counts[k]f32", "sums[k,d]f32", "distortion[]f32", "assign[n]i32"]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.tile_n, 256);
        assert_eq!(m.tile_k, 128);
        assert_eq!(m.variants.len(), 3);
        assert_eq!(m.variants[0].d, 8);
        assert_eq!(m.variants[2].outputs.len(), 4);
    }

    #[test]
    fn programs_and_widths() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.programs(), vec!["kmeans_accumulate", "pairwise_d2"]);
        assert_eq!(m.widths("pairwise_d2"), vec![8, 64]);
        assert!(m.widths("nope").is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("{\"tile_n\": 1}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must parse and
        // contain the three programs at the five widths.
        let path = {
            let mut dir = std::env::current_dir().unwrap();
            loop {
                let c = dir.join("artifacts/manifest.json");
                if c.exists() {
                    break Some(c);
                }
                if !dir.pop() {
                    break None;
                }
            }
        };
        let Some(path) = path else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(path).unwrap();
        assert_eq!(
            m.programs(),
            vec!["kmeans_accumulate", "pairwise_d2", "range_count"]
        );
        assert_eq!(m.widths("pairwise_d2"), vec![8, 64, 128, 256, 1024]);
    }
}
