//! Checked conversions for ids, counts and wire numbers.
//!
//! The JSON wire protocol carries every integer as an `f64`, which is
//! exact only up to `2^53`; job ids keep an 8-bit shard tag at bit 44
//! precisely so they stay inside that window (see
//! [`crate::coordinator::shard`]). A raw `as` cast on an untrusted wire
//! number is silently wrong twice over: `-1.5 as u64` saturates to `0`
//! (aliasing a real id) and `1e300 as usize` saturates to `usize::MAX`
//! (turning a malformed request into an allocation attempt). This module
//! is the one sanctioned home for those conversions — everything here
//! validates or is provably lossless, and the `lossy-cast` lint
//! (docs/LINTS.md) denies `as` casts in the wire/serialization surfaces
//! so call sites must come through these helpers.

/// Largest integer magnitude an `f64` JSON number represents exactly.
pub const MAX_WIRE_INT: u64 = 1 << 53;

/// Parse an untrusted wire number as a `u64` id/count: finite,
/// non-negative, integral and at most `2^53`.
pub fn wire_u64(x: f64, what: &str) -> Result<u64, String> {
    if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= MAX_WIRE_INT as f64 {
        Ok(x as u64)
    } else {
        Err(format!("{what}: expected a non-negative integer <= 2^53, got {x}"))
    }
}

/// Parse an untrusted wire number as a `u32` (point/row ids).
pub fn wire_u32(x: f64, what: &str) -> Result<u32, String> {
    let v = wire_u64(x, what)?;
    u32::try_from(v).map_err(|_| format!("{what}: {v} does not fit in u32"))
}

/// Parse an untrusted wire number as a `usize` count (k, rmin, iters…).
/// Capped at `u32::MAX` so absurd requests fail loudly instead of
/// attempting an absurd allocation.
pub fn wire_usize(x: f64, what: &str) -> Result<usize, String> {
    let v = wire_u64(x, what)?;
    if v > u64::from(u32::MAX) {
        return Err(format!("{what}: {v} is implausibly large for a count"));
    }
    Ok(v as usize)
}

/// Serialize a `u64` id/count onto the wire. Exact for all values this
/// codebase produces (job ids are `< 2^52` by construction; distance
/// counts would need years of work to pass `2^53`).
pub fn wire_from_u64(x: u64) -> f64 {
    debug_assert!(x <= MAX_WIRE_INT, "wire integer {x} exceeds 2^53");
    x as f64
}

/// Serialize a `usize` count onto the wire (see [`wire_from_u64`]).
pub fn wire_from_usize(x: usize) -> f64 {
    wire_from_u64(x as u64)
}

/// Serialize a `u32` id onto the wire (always exact).
pub fn wire_from_u32(x: u32) -> f64 {
    f64::from(x)
}

/// Lossless named widening: row/node ids are `u32`, indexing wants
/// `usize` (always at least 32 bits on supported targets).
pub fn usize_from_u32(x: u32) -> usize {
    x as usize
}

/// Lossless named widening for shard/job arithmetic.
pub fn u64_from_usize(x: usize) -> u64 {
    x as u64
}

/// Narrow a small `u64` (a decoded shard tag, a bounded length) to
/// `usize`. Debug-asserts the bound the caller is relying on.
pub fn usize_from_u64(x: u64) -> usize {
    debug_assert!(x <= u64::from(u32::MAX), "value {x} too large for an index");
    x as usize
}

/// Checked narrowing with context for error messages.
pub fn u32_from_usize(x: usize, what: &str) -> Result<u32, String> {
    u32::try_from(x).map_err(|_| format!("{what}: {x} does not fit in u32"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_u64_accepts_integers() {
        assert_eq!(wire_u64(0.0, "id"), Ok(0));
        assert_eq!(wire_u64(7.0, "id"), Ok(7));
        assert_eq!(wire_u64(MAX_WIRE_INT as f64, "id"), Ok(MAX_WIRE_INT));
    }

    #[test]
    fn wire_u64_rejects_garbage() {
        assert!(wire_u64(-1.5, "id").is_err());
        assert!(wire_u64(-1.0, "id").is_err());
        assert!(wire_u64(0.5, "id").is_err());
        assert!(wire_u64(1e300, "id").is_err());
        assert!(wire_u64(f64::NAN, "id").is_err());
        assert!(wire_u64(f64::INFINITY, "id").is_err());
    }

    #[test]
    fn wire_u32_rejects_overflow() {
        assert_eq!(wire_u32(4294967295.0, "row"), Ok(u32::MAX));
        assert!(wire_u32(4294967296.0, "row").is_err());
    }

    #[test]
    fn wire_usize_caps_counts() {
        assert_eq!(wire_usize(10.0, "k"), Ok(10));
        assert!(wire_usize(1e18, "k").is_err());
    }

    #[test]
    fn roundtrips() {
        for v in [0u64, 1, 77, (1 << 44) + 3, MAX_WIRE_INT] {
            assert_eq!(wire_u64(wire_from_u64(v), "v"), Ok(v));
        }
        assert_eq!(wire_from_u32(9), 9.0);
        assert_eq!(usize_from_u32(u32::MAX), u32::MAX as usize);
        assert_eq!(u32_from_usize(12, "n"), Ok(12));
        assert!(u32_from_usize(usize::MAX, "n").is_err());
    }
}
