//! Env-gated JSONL trace-event sink.
//!
//! When `PALLAS_TRACE=<path>` is set, the serving edge emits one JSON
//! object per line for each job phase (queue-wait, build, run,
//! end-to-end). When unset — the default — [`enabled`] is `false` and
//! every [`span`] call is a no-op that never touches the filesystem.
//!
//! The sink is intentionally tiny: append-mode `File` behind a
//! `Mutex`, one `writeln!` per span, a monotonically increasing `seq`
//! so post-hoc tooling can order records without trusting timestamps.
//! It lives in `obs/` because pallas-lint D2 quarantines `std::env`
//! and wall-clock access to the observability/serving edge; algorithm
//! code cannot emit spans directly.

use crate::json::Value;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// The sink: `None` when `PALLAS_TRACE` is unset or the file cannot
/// be opened (tracing silently disabled — observability must never
/// take the serving path down).
static SINK: OnceLock<Option<Mutex<File>>> = OnceLock::new();

/// Monotone record counter across the whole process.
static SEQ: AtomicU64 = AtomicU64::new(0);

fn sink() -> &'static Option<Mutex<File>> {
    SINK.get_or_init(|| {
        let path = std::env::var("PALLAS_TRACE").ok()?;
        if path.is_empty() {
            return None;
        }
        let file = OpenOptions::new().create(true).append(true).open(path).ok()?;
        Some(Mutex::new(file))
    })
}

/// True when a trace sink is configured and open.
pub fn enabled() -> bool {
    sink().is_some()
}

/// Emit one span record: `{"seq":N,"span":name,...fields}`.
///
/// `fields` are appended in the order given; values use the crate's
/// canonical JSON encoder, so output is deterministic given the same
/// inputs. Duration fields should be pre-measured by the caller (in
/// microseconds) — this module never reads a clock itself.
pub fn span(name: &str, fields: &[(&str, Value)]) {
    let Some(file) = sink() else { return };
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let mut line = String::new();
    line.push_str("{\"seq\":");
    line.push_str(&seq.to_string());
    line.push_str(",\"span\":");
    line.push_str(&crate::json::write(&Value::Str(name.to_string())));
    for (k, v) in fields {
        line.push(',');
        line.push_str(&crate::json::write(&Value::Str((*k).to_string())));
        line.push(':');
        line.push_str(&crate::json::write(v));
    }
    line.push('}');
    if let Ok(mut f) = file.lock() {
        let _ = writeln!(f, "{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is process-global and env-gated; tests here only cover
    // the record formatting path via the disabled default (CI sets
    // PALLAS_TRACE for the socket smoke test, which validates the
    // JSONL output end to end).
    #[test]
    fn disabled_by_default_and_span_is_safe() {
        // Under `cargo test` PALLAS_TRACE is normally unset; either
        // way, span() must not panic.
        span("test", &[("micros", Value::Num(12.0))]);
        let _ = enabled();
    }
}
