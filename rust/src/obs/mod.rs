//! Observability layer 1: **deterministic in-algorithm query telemetry**.
//!
//! The paper's case is that triangle-inequality pruning makes queries
//! cheap — and Pestov (arXiv 0812.0146) proves that pruning provably
//! degrades in high dimension. Until now the engine could only report
//! one scalar (`dists`) per run, so nobody could see *where* a query
//! spent its work or whether the tree was still winning. This module is
//! the counter block that answers that: nodes visited, nodes/rows
//! pruned split by *which* rule fired, leaf rows scanned, the frontier
//! high-water mark and per-level fan-out — everything the ROADMAP's
//! adaptive planner needs to decide per (dataset, family) whether the
//! tree beats the blocked naive scan.
//!
//! ## Determinism contract
//!
//! Everything in this module is **pure counting**: u64 sums (and one
//! `fetch_max`) over events the algorithms emit. Sums and max are
//! commutative, so totals are bit-identical at every thread count,
//! shard count, and across repeated runs — the same contract
//! [`crate::metrics::DistCounter`] already keeps, proven by
//! `tests/obs_equivalence.rs`. The sink is sharded per worker exactly
//! like the distance counter (round-robin cache-line-aligned cells) so
//! concurrent bumps never contend on one line.
//!
//! No clocks, no environment reads live here in [`ObsSink`] — pallas-lint
//! D2 (wall-clock) quarantines timing at the serving edge. The *timed*
//! half of observability (latency histograms, trace spans) lives in
//! [`hist`] and [`trace`], which are only ever *recorded into* from
//! `coordinator/`, `server.rs` and `main.rs`.

pub mod hist;
pub mod trace;

pub use hist::{Histogram, HistogramSnapshot};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Why a traversal skipped work. Units differ per rule — see the
/// variant docs — but every cell is "work the naive path would have
/// paid that the rule avoided".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PruneRule {
    /// Triangle-inequality bound excluded a whole node (knn frontier,
    /// ball whole-in/whole-out, allpairs/mst node rejection, anomaly
    /// rules 1–2). Unit: nodes.
    Triangle = 0,
    /// A cached-statistics error budget settled a whole node at its
    /// midpoint (KDE / kernel-regression half-width test, EM τ-bracket
    /// award). Unit: nodes.
    Budget = 1,
    /// The f32 filter tier conclusively rejected rows, so the exact f64
    /// kernel never saw them. Unit: rows.
    F32Reject = 2,
    /// Anomaly rule 3: enough in-radius neighbors found to settle
    /// "not an anomaly" early. Unit: early exits.
    Rule3 = 3,
    /// Anomaly rule 4: remaining candidates cannot reach the threshold,
    /// settling "anomaly" early. Unit: early exits.
    Rule4 = 4,
}

/// Number of [`PruneRule`] cells.
pub const N_RULES: usize = 5;

impl PruneRule {
    /// All rules, in cell order.
    pub const ALL: [PruneRule; N_RULES] = [
        PruneRule::Triangle,
        PruneRule::Budget,
        PruneRule::F32Reject,
        PruneRule::Rule3,
        PruneRule::Rule4,
    ];

    /// Stable wire/exposition name.
    pub fn name(self) -> &'static str {
        match self {
            PruneRule::Triangle => "triangle",
            PruneRule::Budget => "budget",
            PruneRule::F32Reject => "f32_reject",
            PruneRule::Rule3 => "rule3",
            PruneRule::Rule4 => "rule4",
        }
    }

    fn cell(self) -> usize {
        self as usize
    }
}

/// Depth cells tracked for per-level fan-out. Deeper visits clamp into
/// the last cell; with `rmin ≥ 8` no realistic tree exceeds this.
pub const LEVEL_SLOTS: usize = 32;

/// Number of sink cells; mirrors the distance counter's shard count so
/// round-robin thread assignment rarely aliases two hot workers.
const SHARDS: usize = 16;

/// One cache line (and change) of counters for one worker shard. All
/// cells for one thread ride together: the same traversal bumps them
/// back to back, so sharing lines within a shard is the cheap layout.
#[repr(align(64))]
#[derive(Debug)]
struct ObsShard {
    nodes_visited: AtomicU64,
    pruned: [AtomicU64; N_RULES],
    leaf_rows: AtomicU64,
    level_fanout: [AtomicU64; LEVEL_SLOTS],
}

impl ObsShard {
    fn new() -> ObsShard {
        ObsShard {
            nodes_visited: AtomicU64::new(0),
            pruned: std::array::from_fn(|_| AtomicU64::new(0)),
            leaf_rows: AtomicU64::new(0),
            level_fanout: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Monotonic round-robin source of shard assignments (separate from the
/// distance counter's so neither perturbs the other's spread).
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// This thread's shard index, fixed at first use.
    static SHARD_INDEX: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % SHARDS;
}

/// The traversal-statistics sink one [`crate::metrics::Space`] owns,
/// shared (like the distance counter) by every view and arena derived
/// from it. Algorithms bump it through the `Space::obs_*` helpers;
/// [`crate::engine::Index::run_traced`] snapshots around a query to
/// attribute a per-query [`QueryStats`] delta.
///
/// Relaxed ordering is sufficient everywhere: cells are only read after
/// a query completes (the coordinator's per-dataset run lock, or the
/// CLI's single-query lifetime, guarantees exclusivity), never used for
/// synchronization.
#[derive(Debug)]
pub struct ObsSink {
    shards: [ObsShard; SHARDS],
    /// High-water mark of the best-first frontier, via `fetch_max`.
    /// Reset per query (it is a peak, not a monotone sum).
    frontier_peak: AtomicU64,
}

impl Default for ObsSink {
    fn default() -> Self {
        ObsSink::new()
    }
}

impl ObsSink {
    pub fn new() -> ObsSink {
        ObsSink {
            shards: std::array::from_fn(|_| ObsShard::new()),
            frontier_peak: AtomicU64::new(0),
        }
    }

    /// A traversal entered a node at `depth` (root = 0).
    #[inline]
    pub fn visit(&self, depth: usize) {
        let shard = SHARD_INDEX.with(|i| *i);
        let s = &self.shards[shard];
        s.nodes_visited.fetch_add(1, Ordering::Relaxed);
        s.level_fanout[depth.min(LEVEL_SLOTS - 1)].fetch_add(1, Ordering::Relaxed);
    }

    /// One prune event under `rule`.
    #[inline]
    pub fn prune(&self, rule: PruneRule) {
        self.prune_n(rule, 1);
    }

    /// `n` prune events under `rule` (e.g. rows a filter tier rejected,
    /// or the frontier remainder a bound cut off at once).
    #[inline]
    pub fn prune_n(&self, rule: PruneRule, n: u64) {
        if n == 0 {
            return;
        }
        let shard = SHARD_INDEX.with(|i| *i);
        self.shards[shard].pruned[rule.cell()].fetch_add(n, Ordering::Relaxed);
    }

    /// `n` leaf rows scanned by a blocked kernel or pointwise loop.
    #[inline]
    pub fn leaf_rows(&self, n: u64) {
        if n == 0 {
            return;
        }
        let shard = SHARD_INDEX.with(|i| *i);
        self.shards[shard].leaf_rows.fetch_add(n, Ordering::Relaxed);
    }

    /// Observe the current frontier length; keeps the maximum.
    #[inline]
    pub fn frontier(&self, len: usize) {
        self.frontier_peak
            .fetch_max(crate::ids::u64_from_usize(len), Ordering::Relaxed);
    }

    /// Reset the per-query frontier peak (called at query start by
    /// `run_traced`; the counters themselves are monotone and are read
    /// as before/after deltas instead).
    pub fn reset_frontier_peak(&self) {
        self.frontier_peak.store(0, Ordering::Relaxed);
    }

    /// Sum every shard into a point-in-time [`QueryStats`].
    pub fn snapshot(&self) -> QueryStats {
        let mut out = QueryStats::default();
        for s in &self.shards {
            out.nodes_visited += s.nodes_visited.load(Ordering::Relaxed);
            for (cell, p) in out.pruned.iter_mut().zip(&s.pruned) {
                *cell += p.load(Ordering::Relaxed);
            }
            out.leaf_rows += s.leaf_rows.load(Ordering::Relaxed);
            for (cell, l) in out.level_fanout.iter_mut().zip(&s.level_fanout) {
                *cell += l.load(Ordering::Relaxed);
            }
        }
        out.frontier_peak = self.frontier_peak.load(Ordering::Relaxed);
        out
    }

    /// Zero every cell (tests / bench isolation; production paths use
    /// before/after snapshots instead).
    pub fn reset(&self) {
        for s in &self.shards {
            s.nodes_visited.store(0, Ordering::Relaxed);
            for p in &s.pruned {
                p.store(0, Ordering::Relaxed);
            }
            s.leaf_rows.store(0, Ordering::Relaxed);
            for l in &s.level_fanout {
                l.store(0, Ordering::Relaxed);
            }
        }
        self.frontier_peak.store(0, Ordering::Relaxed);
    }
}

/// One query's traversal statistics: the delta of an [`ObsSink`] over
/// the query's execution. Plain data — every field a u64 sum (or the
/// frontier peak), so snapshots merge by field-wise addition and
/// compare bit-exactly across thread/shard counts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueryStats {
    /// Tree nodes a traversal entered (all families; dual-tree walks
    /// count node *pairs* visited).
    pub nodes_visited: u64,
    /// Prune events split by rule, indexed by [`PruneRule`] cell order.
    pub pruned: [u64; N_RULES],
    /// Leaf rows scanned (blocked kernels and pointwise loops alike;
    /// the naive paths count every row here).
    pub leaf_rows: u64,
    /// High-water mark of the best-first frontier (0 for traversals
    /// without one).
    pub frontier_peak: u64,
    /// Nodes visited per depth, root = slot 0 (deeper clamps into the
    /// last slot). `sum(level_fanout) == nodes_visited`.
    pub level_fanout: [u64; LEVEL_SLOTS],
}

impl Default for QueryStats {
    fn default() -> Self {
        QueryStats {
            nodes_visited: 0,
            pruned: [0; N_RULES],
            leaf_rows: 0,
            frontier_peak: 0,
            level_fanout: [0; LEVEL_SLOTS],
        }
    }
}

impl QueryStats {
    /// Count pruned under one rule.
    pub fn pruned_by(&self, rule: PruneRule) -> u64 {
        self.pruned[rule.cell()]
    }

    /// Total prune events across every rule.
    pub fn total_pruned(&self) -> u64 {
        self.pruned.iter().sum()
    }

    /// The per-query delta: `self` (the *after* snapshot) minus
    /// `before`, field-wise. The frontier peak is taken raw from
    /// `self` — `run_traced` resets it at query start, so it already
    /// is this query's peak rather than a lifetime maximum.
    pub fn delta_from(&self, before: &QueryStats) -> QueryStats {
        let mut out = QueryStats {
            nodes_visited: self.nodes_visited - before.nodes_visited,
            pruned: [0; N_RULES],
            leaf_rows: self.leaf_rows - before.leaf_rows,
            frontier_peak: self.frontier_peak,
            level_fanout: [0; LEVEL_SLOTS],
        };
        for i in 0..N_RULES {
            out.pruned[i] = self.pruned[i] - before.pruned[i];
        }
        for i in 0..LEVEL_SLOTS {
            out.level_fanout[i] = self.level_fanout[i] - before.level_fanout[i];
        }
        out
    }

    /// Field-wise accumulation (sums; peak keeps the max) — how the
    /// coordinator aggregates per-family lifetime stats across jobs.
    pub fn accumulate(&mut self, other: &QueryStats) {
        self.nodes_visited += other.nodes_visited;
        for (a, b) in self.pruned.iter_mut().zip(&other.pruned) {
            *a += b;
        }
        self.leaf_rows += other.leaf_rows;
        self.frontier_peak = self.frontier_peak.max(other.frontier_peak);
        for (a, b) in self.level_fanout.iter_mut().zip(&other.level_fanout) {
            *a += b;
        }
    }

    /// Deepest level with any visits, or `None` when no node was
    /// entered (naive scans).
    pub fn max_depth(&self) -> Option<usize> {
        self.level_fanout.iter().rposition(|&c| c > 0)
    }
}

/// The engine's query-family names, in the order the serving edge
/// indexes its per-family histograms and lifetime stats. Must match
/// `engine::Query::kind()` exactly for every variant (pinned by
/// `tests/obs_equivalence.rs`).
pub const FAMILIES: [&str; 11] = [
    "kmeans",
    "xmeans",
    "anomaly",
    "allpairs",
    "ball",
    "ballstats",
    "kde",
    "kreg",
    "em",
    "knn",
    "mst",
];

/// Index of a query family's cell in the serving-edge aggregates.
pub fn family_index(kind: &str) -> Option<usize> {
    FAMILIES.iter().position(|&f| f == kind)
}

/// The one end-of-run report formatter every CLI subcommand shares
/// (satellite of ISSUE 9): distance accounting, the f32-tier eval
/// split, and the traversal statistics, in a fixed human-readable
/// shape. `wall_secs` is measured by the *caller* (main.rs / the
/// coordinator — the timed edge); this function only formats it.
pub fn format_run_report(
    dists: u64,
    f32_evals: u64,
    stats: &QueryStats,
    wall_secs: Option<f64>,
) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = write!(out, "distance computations {dists}  f32-filter evals {f32_evals}");
    if let Some(w) = wall_secs {
        let _ = write!(out, "  wall {w:.2}s");
    }
    let _ = writeln!(out);
    let _ = write!(
        out,
        "nodes visited {}  leaf rows {}  frontier peak {}",
        stats.nodes_visited, stats.leaf_rows, stats.frontier_peak
    );
    let _ = writeln!(out);
    let _ = write!(out, "pruned:");
    for rule in PruneRule::ALL {
        let _ = write!(out, " {} {}", rule.name(), stats.pruned_by(rule));
    }
    let _ = writeln!(out);
    if let Some(deepest) = stats.max_depth() {
        let levels: Vec<String> = stats.level_fanout[..=deepest]
            .iter()
            .map(|c| c.to_string())
            .collect();
        let _ = write!(out, "level fan-out [{}]", levels.join(", "));
        let _ = writeln!(out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn visit_prune_leaf_snapshot() {
        let sink = ObsSink::new();
        sink.visit(0);
        sink.visit(1);
        sink.visit(1);
        sink.prune(PruneRule::Triangle);
        sink.prune_n(PruneRule::F32Reject, 40);
        sink.leaf_rows(123);
        sink.frontier(7);
        sink.frontier(3);
        let s = sink.snapshot();
        assert_eq!(s.nodes_visited, 3);
        assert_eq!(s.pruned_by(PruneRule::Triangle), 1);
        assert_eq!(s.pruned_by(PruneRule::F32Reject), 40);
        assert_eq!(s.total_pruned(), 41);
        assert_eq!(s.leaf_rows, 123);
        assert_eq!(s.frontier_peak, 7);
        assert_eq!(s.level_fanout[0], 1);
        assert_eq!(s.level_fanout[1], 2);
        assert_eq!(s.max_depth(), Some(1));
        assert_eq!(
            s.level_fanout.iter().sum::<u64>(),
            s.nodes_visited,
            "fan-out must partition the visits"
        );
    }

    #[test]
    fn deep_visits_clamp_into_last_slot() {
        let sink = ObsSink::new();
        sink.visit(LEVEL_SLOTS + 10);
        let s = sink.snapshot();
        assert_eq!(s.level_fanout[LEVEL_SLOTS - 1], 1);
        assert_eq!(s.max_depth(), Some(LEVEL_SLOTS - 1));
    }

    #[test]
    fn delta_subtracts_counters_and_keeps_raw_peak() {
        let sink = ObsSink::new();
        sink.visit(0);
        sink.leaf_rows(10);
        sink.frontier(99);
        let before = sink.snapshot();
        sink.reset_frontier_peak();
        sink.visit(1);
        sink.prune(PruneRule::Budget);
        sink.leaf_rows(5);
        sink.frontier(4);
        let after = sink.snapshot();
        let d = after.delta_from(&before);
        assert_eq!(d.nodes_visited, 1);
        assert_eq!(d.leaf_rows, 5);
        assert_eq!(d.pruned_by(PruneRule::Budget), 1);
        assert_eq!(d.frontier_peak, 4, "peak is per-query, not lifetime");
        assert_eq!(d.level_fanout[1], 1);
        assert_eq!(d.level_fanout[0], 0);
    }

    #[test]
    fn accumulate_sums_and_maxes() {
        let mut a = QueryStats::default();
        a.nodes_visited = 2;
        a.frontier_peak = 5;
        a.pruned[0] = 1;
        let mut b = QueryStats::default();
        b.nodes_visited = 3;
        b.frontier_peak = 4;
        b.pruned[0] = 2;
        b.leaf_rows = 7;
        a.accumulate(&b);
        assert_eq!(a.nodes_visited, 5);
        assert_eq!(a.frontier_peak, 5);
        assert_eq!(a.pruned[0], 3);
        assert_eq!(a.leaf_rows, 7);
    }

    #[test]
    fn concurrent_bumps_sum_exactly() {
        let sink = Arc::new(ObsSink::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let sink = Arc::clone(&sink);
            handles.push(std::thread::spawn(move || {
                for d in 0..1000usize {
                    sink.visit(d % 4);
                    sink.prune_n(PruneRule::Triangle, 2);
                    sink.leaf_rows(3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = sink.snapshot();
        assert_eq!(s.nodes_visited, 8_000);
        assert_eq!(s.pruned_by(PruneRule::Triangle), 16_000);
        assert_eq!(s.leaf_rows, 24_000);
        assert_eq!(s.level_fanout[0], 2_000);
    }

    #[test]
    fn family_table_is_total_and_unique() {
        for (i, f) in FAMILIES.iter().enumerate() {
            assert_eq!(family_index(f), Some(i));
        }
        assert_eq!(family_index("nope"), None);
    }

    #[test]
    fn report_formats_every_section() {
        let mut s = QueryStats::default();
        s.nodes_visited = 3;
        s.level_fanout[0] = 1;
        s.level_fanout[2] = 2;
        s.pruned[0] = 9;
        let text = format_run_report(100, 20, &s, Some(0.5));
        assert!(text.contains("distance computations 100"));
        assert!(text.contains("f32-filter evals 20"));
        assert!(text.contains("wall 0.50s"));
        assert!(text.contains("triangle 9"));
        assert!(text.contains("level fan-out [1, 0, 2]"));
        // Naive runs have no tree levels: the fan-out line disappears
        // instead of printing an empty list.
        let naive = format_run_report(5, 0, &QueryStats::default(), None);
        assert!(!naive.contains("level fan-out"));
        assert!(!naive.contains("wall"));
    }
}
