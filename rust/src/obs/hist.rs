//! Observability layer 2 support: **log-bucketed latency histograms**
//! (std-only, HDR-style powers-of-√2 buckets over microseconds).
//!
//! A [`Histogram`] is a fixed array of atomic buckets whose bounds grow
//! by a factor of √2 — two buckets per doubling, so quantile estimates
//! carry at most ~41% relative error while 63 finite bounds span 1 µs
//! to ~36 minutes. Recording is lock-free (`fetch_add`); snapshots are
//! plain vectors that merge by field-wise addition, which makes the
//! merge **order-invariant** — aggregating N coordinator shards gives
//! the same snapshot in any order, exactly like
//! [`crate::coordinator::MetricsSnapshot`].
//!
//! This module never reads a clock: callers at the serving edge
//! (`coordinator/`, `server.rs`, `main.rs` — the only homes pallas-lint
//! D2 permits timing in) measure durations and pass microseconds in.

use crate::ids;
use std::sync::atomic::{AtomicU64, Ordering};

/// Total buckets. The last bucket is the overflow (`+Inf`) bucket.
pub const N_BUCKETS: usize = 64;

/// √2 as a u64 ratio (numerator over [`SQRT2_DEN`]): integer bucket
/// bounds make the layout identical on every platform, with no float
/// rounding in sight.
const SQRT2_NUM: u128 = 1_414_213_562;
const SQRT2_DEN: u128 = 1_000_000_000;

/// Upper bound (exclusive), in microseconds, of bucket `i` for
/// `i < N_BUCKETS - 1`; bucket `N_BUCKETS - 1` is unbounded. Bounds:
/// 1, 1, 2, 2, 4, 5, 8, 11, 16, 22, 32, ... — even buckets are exact
/// powers of two, odd buckets the √2 midpoints.
pub fn bucket_bound(i: usize) -> u64 {
    debug_assert!(i < N_BUCKETS - 1, "the last bucket has no finite bound");
    let half = i / 2;
    if i % 2 == 0 {
        1u64 << half
    } else {
        // Exact in u128: (1 << 31) * SQRT2_NUM stays far below 2^128.
        let wide = ((1u128 << half) * SQRT2_NUM) / SQRT2_DEN;
        // pallas-lint scope note: hist.rs is not a wire file, and the
        // value provably fits (half ≤ 31 ⇒ wide < 2^32).
        wide as u64
    }
}

/// The bucket a microsecond value lands in.
pub fn bucket_index(micros: u64) -> usize {
    // Even bucket bounds are powers of two, so locate the doubling via
    // the bit width, then resolve the √2 midpoint — O(1), no scan.
    if micros == 0 {
        return 0;
    }
    let log2 = ids::usize_from_u64(u64::from(63 - micros.leading_zeros()));
    let candidate = 2 * log2 + 1; // first bound that can exceed `micros`
    for i in candidate..N_BUCKETS - 1 {
        if micros < bucket_bound(i) {
            return i;
        }
    }
    N_BUCKETS - 1
}

/// Lock-free latency histogram. Record with [`Histogram::record`];
/// read with [`Histogram::snapshot`]. Relaxed ordering throughout —
/// the cells are monitoring data, never synchronization.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }

    /// Record one observation of `micros`.
    pub fn record(&self, micros: u64) {
        self.buckets[bucket_index(micros)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
    }

    /// Point-in-time copy of every cell.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_micros: self.sum_micros.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data histogram state. Merging is field-wise addition, hence
/// commutative and associative: any merge order over any shard
/// grouping yields the identical snapshot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket observation counts, length [`N_BUCKETS`].
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum_micros: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum_micros: 0,
        }
    }
}

impl HistogramSnapshot {
    /// Field-wise sum — the aggregate view over coordinator shards.
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let mut buckets = vec![0u64; N_BUCKETS];
        for (i, cell) in buckets.iter_mut().enumerate() {
            *cell = self.buckets.get(i).copied().unwrap_or(0)
                + other.buckets.get(i).copied().unwrap_or(0);
        }
        HistogramSnapshot {
            buckets,
            count: self.count + other.count,
            sum_micros: self.sum_micros + other.sum_micros,
        }
    }

    /// Mean observation in microseconds (0 when empty).
    pub fn mean_micros(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            ids::wire_from_u64(self.sum_micros) / ids::wire_from_u64(self.count)
        }
    }

    /// Upper bound (µs) of the bucket containing the `q`-quantile
    /// observation (`0.0 < q ≤ 1.0`), or `None` when the histogram is
    /// empty or the quantile lands in the overflow bucket.
    pub fn quantile_upper_bound(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let target = (q.clamp(0.0, 1.0) * ids::wire_from_u64(self.count)).ceil();
        let mut seen = 0.0f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += ids::wire_from_u64(c);
            if seen >= target {
                return if i < N_BUCKETS - 1 {
                    Some(bucket_bound(i))
                } else {
                    None
                };
            }
        }
        None
    }
}

/// Append one histogram in Prometheus text exposition format:
/// cumulative `_bucket{le=...}` lines (trailing empty buckets elided —
/// their cumulative count equals the `+Inf` line), then `_sum` and
/// `_count`. `labels` is either empty or a pre-rendered
/// `key="value"`-list without braces.
pub fn prometheus_histogram(out: &mut String, name: &str, labels: &str, h: &HistogramSnapshot) {
    use std::fmt::Write as _;
    let sep = if labels.is_empty() { "" } else { "," };
    let _ = writeln!(out, "# TYPE {name} histogram");
    let last_nonzero = h.buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
    let mut cum = 0u64;
    for (i, &c) in h.buckets.iter().enumerate() {
        cum += c;
        if i > last_nonzero {
            break;
        }
        if i < N_BUCKETS - 1 {
            let _ = writeln!(
                out,
                "{name}_bucket{{{labels}{sep}le=\"{}\"}} {cum}",
                bucket_bound(i)
            );
        }
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", h.count);
    let brace = if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    };
    let _ = writeln!(out, "{name}_sum{brace} {}", h.sum_micros);
    let _ = writeln!(out, "{name}_count{brace} {}", h.count);
}

/// Append one plain counter in Prometheus text exposition format.
pub fn prometheus_counter(out: &mut String, name: &str, labels: &str, value: u64) {
    use std::fmt::Write as _;
    if labels.is_empty() {
        let _ = writeln!(out, "{name} {value}");
    } else {
        let _ = writeln!(out, "{name}{{{labels}}} {value}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_grow_by_sqrt2_and_stay_sorted() {
        let mut prev = 0u64;
        for i in 0..N_BUCKETS - 1 {
            let b = bucket_bound(i);
            assert!(b >= prev, "bounds must be non-decreasing at {i}");
            prev = b;
        }
        // Even buckets are exact powers of two.
        assert_eq!(bucket_bound(0), 1);
        assert_eq!(bucket_bound(2), 2);
        assert_eq!(bucket_bound(20), 1024);
        // Odd buckets are the √2 midpoints.
        assert_eq!(bucket_bound(21), 1448);
        // The top finite bound covers ~36 minutes of microseconds.
        assert!(bucket_bound(N_BUCKETS - 2) > 2_000_000_000);
    }

    #[test]
    fn bucket_index_matches_bounds() {
        for v in [0u64, 1, 2, 3, 5, 8, 100, 1_000_000, u64::MAX] {
            let i = bucket_index(v);
            if i < N_BUCKETS - 1 {
                assert!(v < bucket_bound(i), "{v} outside bucket {i}");
            }
            if i > 0 {
                assert!(v >= bucket_bound(i - 1), "{v} below bucket {i}");
            }
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn record_snapshot_quantiles() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum_micros, 1100);
        assert_eq!(s.buckets.iter().sum::<u64>(), 5);
        let p50 = s.quantile_upper_bound(0.5).unwrap();
        assert!((16..=45).contains(&p50), "p50 bound {p50}");
        let p100 = s.quantile_upper_bound(1.0).unwrap();
        assert!(p100 >= 1000);
        assert!((s.mean_micros() - 220.0).abs() < 1e-9);
    }

    #[test]
    fn merge_is_order_invariant() {
        let mk = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h.snapshot()
        };
        let a = mk(&[1, 5, 9]);
        let b = mk(&[100, 200]);
        let c = mk(&[1_000_000]);
        let abc = a.merge(&b).merge(&c);
        let cba = c.merge(&b).merge(&a);
        let bca = b.merge(&c.merge(&a));
        assert_eq!(abc, cba);
        assert_eq!(abc, bca);
        assert_eq!(abc.count, 6);
        let empty = HistogramSnapshot::default();
        assert_eq!(empty.merge(&a), a.merge(&empty));
        assert_eq!(empty.quantile_upper_bound(0.5), None);
    }

    #[test]
    fn prometheus_exposition_is_cumulative_and_parseable() {
        let h = Histogram::new();
        h.record(3);
        h.record(3);
        h.record(50);
        let mut text = String::new();
        prometheus_histogram(&mut text, "pallas_test_us", "family=\"knn\"", &h.snapshot());
        assert!(text.contains("# TYPE pallas_test_us histogram"));
        assert!(text.contains("pallas_test_us_bucket{family=\"knn\",le=\"+Inf\"} 3"));
        assert!(text.contains("pallas_test_us_sum{family=\"knn\"} 56"));
        assert!(text.contains("pallas_test_us_count{family=\"knn\"} 3"));
        // Cumulative counts never decrease down the bucket list.
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "non-cumulative line: {line}");
            prev = v;
        }
        let mut plain = String::new();
        prometheus_counter(&mut plain, "pallas_jobs_total", "", 7);
        assert_eq!(plain, "pallas_jobs_total 7\n");
    }
}
