//! The Anchors Hierarchy (paper §3): tree-free localization of points
//! around k anchors using only the triangle inequality.
//!
//! Each anchor `a` owns the points closer to it than to any other anchor,
//! kept **sorted in decreasing distance** to the anchor's pivot. When a
//! new anchor `a_new` tries to steal from `a`, the scan walks the sorted
//! list and stops at the first point with
//!
//! ```text
//! D(x, a_pivot) < D(a_new_pivot, a_pivot) / 2          (paper eq. 6)
//! ```
//!
//! — by the triangle inequality no later point in the list can possibly be
//! closer to `a_new` than to `a`, so the rest of the list (and often the
//! entire list, when the anchors are far apart) is skipped without a
//! single distance computation. That cutoff is the whole trick, and it is
//! what makes building √R anchors cost ≈ O(R·log k) distances instead of
//! R·k on structured data.

use crate::metrics::Space;
use crate::parallel::Executor;
use crate::rng::Rng;

/// Points per parallel work item in the chunked passes. Fixed (never a
/// function of thread count) so the merge order — and therefore every
/// result bit — is identical on any schedule.
const POINT_CHUNK: usize = 2048;

/// One anchor: a pivot datapoint plus the points it owns.
#[derive(Clone, Debug)]
pub struct Anchor {
    /// Index of the pivot datapoint.
    pub pivot: u32,
    /// `(distance_to_pivot, point_id)`, sorted in DECREASING distance.
    /// Always contains at least the pivot itself (at distance 0).
    pub owned: Vec<(f64, u32)>,
}

impl Anchor {
    /// Radius = distance to the farthest owned point (paper eq. 5).
    #[inline]
    pub fn radius(&self) -> f64 {
        self.owned.first().map_or(0.0, |&(d, _)| d)
    }

    pub fn len(&self) -> usize {
        self.owned.len()
    }

    pub fn is_empty(&self) -> bool {
        self.owned.is_empty()
    }

    /// Owned point ids (unsorted order of the distance-sorted list).
    pub fn point_ids(&self) -> Vec<u32> {
        self.owned.iter().map(|&(_, p)| p).collect()
    }
}

/// A set of anchors over (a subset of) a [`Space`], with the inter-anchor
/// distance matrix the paper's Figure 4 shows being cached explicitly.
pub struct AnchorSet {
    pub anchors: Vec<Anchor>,
    /// Row-major `k × k` matrix of pivot-to-pivot distances.
    pub interanchor: Vec<f64>,
}

impl AnchorSet {
    pub fn k(&self) -> usize {
        self.anchors.len()
    }

    #[inline]
    pub fn interanchor_dist(&self, i: usize, j: usize) -> f64 {
        self.interanchor[i * self.anchors.len() + j]
    }

    /// K-means seeds from the anchors: the centroid of each anchor's
    /// owned points ("Anchors Start" in Table 4).
    pub fn centroid_seeds(&self, space: &Space) -> Vec<Vec<f32>> {
        self.anchors
            .iter()
            .map(|a| space.centroid(&a.point_ids()))
            .collect()
    }

    /// K-means seeds from the anchor pivot datapoints themselves.
    pub fn pivot_seeds(&self, space: &Space) -> Vec<Vec<f32>> {
        self.anchors
            .iter()
            .map(|a| {
                let mut row = vec![0f32; space.dim()];
                space.fill_row(a.pivot as usize, &mut row);
                row
            })
            .collect()
    }
}

/// Build `k` anchors over the given subset of points (paper §3),
/// single-threaded. See [`build_anchors_ex`] for the parallel form; the
/// two produce bit-identical anchor sets.
pub fn build_anchors(space: &Space, points: &[u32], k: usize, rng: &mut Rng) -> AnchorSet {
    build_anchors_ex(space, points, k, rng, &Executor::serial())
}

/// Build `k` anchors over the given subset of points (paper §3).
///
/// The first anchor pivot is chosen at random from `points`; every later
/// pivot is the point farthest from its owner among the points of the
/// current largest-radius anchor (i.e. near a vertex of the current
/// Voronoi partition). May return fewer than `k` anchors if the points
/// collapse onto fewer than `k` distinct locations.
///
/// The two hot passes — the point-to-first-anchor assignment and the
/// scanned prefix of every steal pass — fan out over fixed-size point
/// chunks on `exec`, with per-chunk results merged in chunk order; the
/// result is bit-identical for every thread count, and the counted
/// distance evaluations are exactly the set the serial scan performs.
pub fn build_anchors_ex(
    space: &Space,
    points: &[u32],
    k: usize,
    rng: &mut Rng,
    exec: &Executor,
) -> AnchorSet {
    assert!(!points.is_empty(), "build_anchors on empty point set");
    let k = k.clamp(1, points.len());

    // --- first anchor owns everything ------------------------------------
    let first_pivot = points[rng.below(points.len())];
    let mut row = vec![0f32; space.dim()];
    space.fill_row(first_pivot as usize, &mut row);
    let row_sq = space.data.sqnorm(first_pivot as usize);
    let mut owned: Vec<(f64, u32)> = Vec::with_capacity(points.len());
    for chunk in exec.map_chunks(points.len(), POINT_CHUNK, |r| {
        points[r]
            .iter()
            .map(|&p| (space.dist_to_vec(p as usize, &row, row_sq), p))
            .collect::<Vec<_>>()
    }) {
        owned.extend(chunk);
    }
    sort_desc(&mut owned);
    let mut anchors = vec![Anchor { pivot: first_pivot, owned }];
    // Densified pivot rows, cached so the per-new-anchor distance pass
    // doesn't re-densify every existing pivot (perf: O(k²·d) copies saved).
    let mut pivot_rows: Vec<Vec<f32>> = vec![row];

    // Inter-anchor distances, grown as anchors are added (k × k at the end).
    let mut inter: Vec<Vec<f64>> = vec![vec![0.0]];

    while anchors.len() < k {
        // New pivot: farthest owned point of the largest-radius anchor.
        let (maxrad_idx, maxrad) = anchors
            .iter()
            .enumerate()
            .map(|(i, a)| (i, a.radius()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        if maxrad <= 0.0 {
            break; // all remaining points are duplicates of their pivots
        }
        let new_pivot = anchors[maxrad_idx].owned[0].1;
        let mut pivot_row = vec![0f32; space.dim()];
        space.fill_row(new_pivot as usize, &mut pivot_row);
        let pivot_sq = space.data.sqnorm(new_pivot as usize);

        // Distances from the new pivot to every existing pivot (cached —
        // this is the matrix of Figure 4, and it feeds the cutoff rule).
        let d_new: Vec<f64> = pivot_rows
            .iter()
            .map(|arow| space.dist_vv(&pivot_row, arow))
            .collect();

        // Steal pass over every existing anchor. The owned list is
        // sorted in decreasing distance, so eq. (6)'s early exit is a
        // binary search: everything from `cut` on is provably safe and
        // the scanned prefix `[0, cut)` has no cross-point dependencies —
        // it fans out over point chunks, merged back in chunk order.
        let mut stolen: Vec<(f64, u32)> = Vec::new();
        for (ai, anchor) in anchors.iter_mut().enumerate() {
            let threshold = d_new[ai] / 2.0;
            let cut = anchor.owned.partition_point(|&(d, _)| d >= threshold);
            if cut == 0 {
                // Whole list is inside the safe zone: nothing to check.
                continue;
            }
            let parts = {
                let scan = &anchor.owned[..cut];
                exec.map_chunks(cut, POINT_CHUNK, |r| {
                    let mut keep: Vec<(f64, u32)> = Vec::new();
                    let mut steal: Vec<(f64, u32)> = Vec::new();
                    for &(dist_a, x) in &scan[r] {
                        let d = space.dist_to_vec(x as usize, &pivot_row, pivot_sq);
                        if d < dist_a || x == new_pivot {
                            steal.push((d, x));
                        } else {
                            keep.push((dist_a, x));
                        }
                    }
                    (keep, steal)
                })
            };
            // Rebuild: scanned-but-kept prefix + untouched suffix. Both
            // halves are already in decreasing order.
            let mut keep_prefix: Vec<(f64, u32)> = Vec::with_capacity(anchor.owned.len());
            for (keep, steal) in parts {
                keep_prefix.extend(keep);
                stolen.extend(steal);
            }
            keep_prefix.extend_from_slice(&anchor.owned[cut..]);
            anchor.owned = keep_prefix;
        }

        sort_desc(&mut stolen);
        anchors.push(Anchor { pivot: new_pivot, owned: stolen });
        pivot_rows.push(pivot_row);

        // Grow the inter-anchor matrix.
        for (i, &d) in d_new.iter().enumerate() {
            inter[i].push(d);
        }
        let mut last = d_new;
        last.push(0.0);
        inter.push(last);
    }

    let kk = anchors.len();
    let mut interanchor = vec![0.0; kk * kk];
    for i in 0..kk {
        for j in 0..kk {
            interanchor[i * kk + j] = inter[i][j];
        }
    }
    AnchorSet { anchors, interanchor }
}

#[inline]
fn sort_desc(v: &mut [(f64, u32)]) {
    v.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(b.1.cmp(&a.1)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::metrics::Space;

    /// Clustered 2-d data: `c` tight blobs of `per` points.
    fn blobs(c: usize, per: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for ci in 0..c {
            let cx = (ci % 4) as f64 * 100.0;
            let cy = (ci / 4) as f64 * 100.0;
            for _ in 0..per {
                rows.push(vec![
                    (cx + rng.normal()) as f32,
                    (cy + rng.normal()) as f32,
                ]);
            }
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    fn all_points(space: &Space) -> Vec<u32> {
        (0..space.n() as u32).collect()
    }

    #[test]
    fn ownership_partitions_points() {
        let space = blobs(4, 50, 1);
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 8, &mut Rng::new(7));
        let mut seen = vec![false; space.n()];
        for a in &set.anchors {
            for &(_, p) in &a.owned {
                assert!(!seen[p as usize], "point {p} owned twice");
                seen[p as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some point unowned");
    }

    #[test]
    fn each_point_owned_by_nearest_anchor() {
        // The defining invariant (paper eq. 4).
        let space = blobs(3, 40, 2);
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 6, &mut Rng::new(3));
        let pivots: Vec<u32> = set.anchors.iter().map(|a| a.pivot).collect();
        for (ai, a) in set.anchors.iter().enumerate() {
            for &(_, p) in &a.owned {
                let d_own = space.dist_uncounted(p as usize, a.pivot as usize);
                for (bi, &bp) in pivots.iter().enumerate() {
                    if bi == ai {
                        continue;
                    }
                    let d_other = space.dist_uncounted(p as usize, bp as usize);
                    assert!(
                        d_own <= d_other + 1e-9,
                        "point {p}: owner {ai} at {d_own} but anchor {bi} at {d_other}"
                    );
                }
            }
        }
    }

    #[test]
    fn owned_lists_sorted_decreasing_and_radius_matches() {
        let space = blobs(2, 60, 3);
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 5, &mut Rng::new(11));
        for a in &set.anchors {
            for w in a.owned.windows(2) {
                assert!(w[0].0 >= w[1].0, "owned list not sorted desc");
            }
            if let Some(&(d, p)) = a.owned.first() {
                assert_eq!(a.radius(), d);
                let real = space.dist_uncounted(p as usize, a.pivot as usize);
                assert!((real - d).abs() < 1e-9, "cached distance wrong");
            }
        }
    }

    #[test]
    fn distances_in_owned_lists_are_correct() {
        let space = blobs(2, 30, 4);
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 4, &mut Rng::new(13));
        for a in &set.anchors {
            for &(d, p) in &a.owned {
                let real = space.dist_uncounted(p as usize, a.pivot as usize);
                assert!((real - d).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn interanchor_matrix_is_symmetric_and_correct() {
        let space = blobs(3, 30, 5);
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 6, &mut Rng::new(17));
        let k = set.k();
        for i in 0..k {
            assert_eq!(set.interanchor_dist(i, i), 0.0);
            for j in 0..k {
                assert!((set.interanchor_dist(i, j) - set.interanchor_dist(j, i)).abs() < 1e-9);
                let real = space.dist_uncounted(
                    set.anchors[i].pivot as usize,
                    set.anchors[j].pivot as usize,
                );
                assert!((set.interanchor_dist(i, j) - real).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn cutoff_saves_distances_on_clustered_data() {
        // The headline efficiency claim: building k anchors on well-
        // clustered data costs far fewer than R*k distances.
        let space = blobs(8, 200, 6);
        let pts = all_points(&space);
        let k = 40;
        space.reset_count();
        let set = build_anchors(&space, &pts, k, &mut Rng::new(19));
        assert_eq!(set.k(), k);
        let used = space.dist_count();
        let brute = (space.n() * k) as u64;
        assert!(
            used < brute / 3,
            "anchors used {used} distances, brute force would be {brute}"
        );
    }

    #[test]
    fn handles_duplicates_gracefully() {
        let rows: Vec<Vec<f32>> = (0..20).map(|_| vec![1.0, 2.0]).collect();
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 5, &mut Rng::new(23));
        // All duplicates: only one anchor can form.
        assert_eq!(set.k(), 1);
        assert_eq!(set.anchors[0].len(), 20);
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let space = blobs(1, 5, 7);
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 50, &mut Rng::new(29));
        assert!(set.k() <= 5);
    }

    #[test]
    fn works_on_subset_of_points() {
        let space = blobs(4, 50, 8);
        let subset: Vec<u32> = (0..space.n() as u32).filter(|p| p % 3 == 0).collect();
        let set = build_anchors(&space, &subset, 4, &mut Rng::new(31));
        let total: usize = set.anchors.iter().map(|a| a.len()).sum();
        assert_eq!(total, subset.len());
        for a in &set.anchors {
            for &(_, p) in &a.owned {
                assert!(subset.contains(&p));
            }
        }
    }

    #[test]
    fn parallel_build_is_bit_identical_and_counts_match() {
        use crate::parallel::{Executor, Parallelism};
        let space = blobs(6, 120, 41);
        let pts = all_points(&space);
        space.reset_count();
        let serial = build_anchors_ex(&space, &pts, 12, &mut Rng::new(5), &Executor::serial());
        let serial_dists = space.dist_count();
        for threads in [2usize, 8] {
            let exec = Executor::new(Parallelism::Fixed(threads));
            space.reset_count();
            let par = build_anchors_ex(&space, &pts, 12, &mut Rng::new(5), &exec);
            assert_eq!(space.dist_count(), serial_dists, "{threads} threads");
            assert_eq!(par.k(), serial.k());
            assert_eq!(par.interanchor, serial.interanchor);
            for (a, b) in serial.anchors.iter().zip(&par.anchors) {
                assert_eq!(a.pivot, b.pivot);
                assert_eq!(a.owned, b.owned);
            }
        }
    }

    #[test]
    fn anchor_seeds_have_right_shape() {
        let space = blobs(3, 40, 9);
        let pts = all_points(&space);
        let set = build_anchors(&space, &pts, 3, &mut Rng::new(37));
        let seeds = set.centroid_seeds(&space);
        assert_eq!(seeds.len(), 3);
        assert!(seeds.iter().all(|s| s.len() == 2));
        let pivots = set.pivot_seeds(&space);
        assert_eq!(pivots.len(), 3);
    }
}
