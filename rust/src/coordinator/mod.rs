//! The batch-analytics coordinator: the service layer that makes the
//! paper's algorithms consumable as *jobs* over named datasets.
//!
//! Clients submit [`JobSpec`]s (cluster / detect anomalies / find
//! correlated pairs / span a dependency tree over a dataset, naive or
//! tree-accelerated). A fixed worker pool executes them. Design points:
//!
//! * **Dataset cache** — generating a Table-1 dataset and building its
//!   metric tree is expensive; both are cached and shared (Arc) across
//!   jobs keyed by (dataset, rmin).
//! * **Per-dataset serialization** — a dataset's distance counter is
//!   shared state; the coordinator runs at most one job per dataset at a
//!   time so each job's distance accounting is exact. Different datasets
//!   run fully in parallel.
//! * **Backpressure** — the queue is bounded; `submit` fails fast with
//!   [`SubmitError::QueueFull`] instead of buffering unboundedly.
//! * **No lost or duplicated jobs** — every accepted job reaches exactly
//!   one terminal state ([`JobState::Done`] / [`JobState::Failed`]);
//!   verified by property tests.

pub mod server;

use crate::algorithms::{allpairs, anomaly, kmeans, mst};
use crate::dataset::DatasetSpec;
use crate::metrics::Space;
use crate::runtime::BatchDistanceEngine;
use crate::tree::middle_out::{self, MiddleOutConfig};
use crate::tree::MetricTree;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// What to run.
#[derive(Clone, Debug)]
pub enum JobKind {
    Kmeans { k: usize, iters: usize, anchors_init: bool },
    Anomaly { threshold: u64, target_frac: f64 },
    AllPairs { tau: f64 },
    Mst,
}

/// A complete job description.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub dataset: DatasetSpec,
    pub kind: JobKind,
    /// Tree-accelerated (true) or naive baseline (false).
    pub use_tree: bool,
    /// Leaf threshold for the cached tree.
    pub rmin: usize,
}

/// Job identifier.
pub type JobId = u64;

/// Algorithm-specific result payload.
#[derive(Clone, Debug, PartialEq)]
pub enum JobOutput {
    Kmeans { distortion: f64, iterations: usize },
    Anomaly { n_anomalies: usize, radius: f64 },
    AllPairs { n_pairs: usize },
    Mst { total_weight: f64, n_edges: usize },
}

/// Terminal result of a job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: JobId,
    pub output: JobOutput,
    /// Distance computations attributed to this job (tree build included
    /// on first use of a dataset/rmin pair).
    pub dists: u64,
    pub wall_ms: f64,
}

/// Lifecycle of a job.
#[derive(Clone, Debug)]
pub enum JobState {
    Queued,
    Running,
    Done(JobResult),
    Failed(String),
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done(_) | JobState::Failed(_))
    }
}

/// Submission failures.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
}

/// Aggregate counters (monotonic).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub total_dists: AtomicU64,
}

/// Point-in-time metric values.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub total_dists: u64,
}

struct CachedDataset {
    space: Arc<Space>,
    /// Trees per rmin (built lazily under the dataset lock).
    trees: Mutex<HashMap<usize, Arc<MetricTree>>>,
    /// Serializes jobs touching this dataset (exact distance accounting).
    run_lock: Mutex<()>,
}

struct Inner {
    queue: Mutex<VecDeque<(JobId, JobSpec)>>,
    queue_cv: Condvar,
    capacity: usize,
    states: Mutex<HashMap<JobId, JobState>>,
    state_cv: Condvar,
    datasets: Mutex<HashMap<String, Arc<CachedDataset>>>,
    metrics: Metrics,
    shutdown: AtomicBool,
    engine: Option<Arc<BatchDistanceEngine>>,
    next_id: AtomicU64,
}

/// The coordinator service.
pub struct Coordinator {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start `n_workers` workers with a queue bound of `capacity`.
    pub fn new(n_workers: usize, capacity: usize) -> Coordinator {
        Self::with_engine(n_workers, capacity, None)
    }

    /// Start with an optional XLA batch engine shared by all jobs.
    pub fn with_engine(
        n_workers: usize,
        capacity: usize,
        engine: Option<Arc<BatchDistanceEngine>>,
    ) -> Coordinator {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            capacity: capacity.max(1),
            states: Mutex::new(HashMap::new()),
            state_cv: Condvar::new(),
            datasets: Mutex::new(HashMap::new()),
            metrics: Metrics::default(),
            shutdown: AtomicBool::new(false),
            engine,
            next_id: AtomicU64::new(1),
        });
        let workers = (0..n_workers.max(1))
            .map(|wid| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("coord-worker-{wid}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn worker")
            })
            .collect();
        Coordinator { inner, workers }
    }

    /// Submit a job; fails fast when the queue is at capacity.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        let mut queue = self.inner.queue.lock().unwrap();
        if queue.len() >= self.inner.capacity {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        queue.push_back((id, spec));
        self.inner
            .states
            .lock()
            .unwrap()
            .insert(id, JobState::Queued);
        self.inner.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.inner.queue_cv.notify_one();
        Ok(id)
    }

    /// Snapshot a job's state.
    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.states.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self, id: JobId) -> JobState {
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                Some(s) if s.is_terminal() => return s.clone(),
                Some(_) => {
                    states = self.inner.state_cv.wait(states).unwrap();
                }
                None => panic!("unknown job id {id}"),
            }
        }
    }

    /// Current queue depth.
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let m = &self.inner.metrics;
        MetricsSnapshot {
            submitted: m.submitted.load(Ordering::Relaxed),
            rejected: m.rejected.load(Ordering::Relaxed),
            completed: m.completed.load(Ordering::Relaxed),
            failed: m.failed.load(Ordering::Relaxed),
            total_dists: m.total_dists.load(Ordering::Relaxed),
        }
    }

    /// Drain the queue, stop accepting work, and join the workers.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let job = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if let Some(job) = queue.pop_front() {
                    break Some(job);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = inner.queue_cv.wait(queue).unwrap();
            }
        };
        let Some((id, spec)) = job else { return };
        set_state(&inner, id, JobState::Running);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(&inner, id, &spec)
        }));
        match outcome {
            Ok(Ok(result)) => {
                inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
                inner
                    .metrics
                    .total_dists
                    .fetch_add(result.dists, Ordering::Relaxed);
                set_state(&inner, id, JobState::Done(result));
            }
            Ok(Err(msg)) => {
                inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                set_state(&inner, id, JobState::Failed(msg));
            }
            Err(panic) => {
                inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                let msg = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "job panicked".into());
                set_state(&inner, id, JobState::Failed(msg));
            }
        }
    }
}

fn set_state(inner: &Inner, id: JobId, state: JobState) {
    inner.states.lock().unwrap().insert(id, state);
    inner.state_cv.notify_all();
}

fn dataset_key(spec: &DatasetSpec) -> String {
    format!("{}@{}@{}", spec.kind.name(), spec.scale, spec.seed)
}

fn get_dataset(inner: &Inner, spec: &DatasetSpec) -> Arc<CachedDataset> {
    let key = dataset_key(spec);
    // Fast path.
    if let Some(ds) = inner.datasets.lock().unwrap().get(&key) {
        return ds.clone();
    }
    // Build outside the map lock (generation can be slow), then insert —
    // first writer wins so concurrent builders converge on one copy.
    let built = Arc::new(CachedDataset {
        space: Arc::new(spec.build()),
        trees: Mutex::new(HashMap::new()),
        run_lock: Mutex::new(()),
    });
    let mut map = inner.datasets.lock().unwrap();
    map.entry(key).or_insert(built).clone()
}

fn get_tree(ds: &CachedDataset, rmin: usize, seed: u64) -> Arc<MetricTree> {
    let mut trees = ds.trees.lock().unwrap();
    if let Some(t) = trees.get(&rmin) {
        return t.clone();
    }
    let cfg = MiddleOutConfig { rmin, seed, exact_radii: false };
    let tree = Arc::new(middle_out::build(&ds.space, &cfg));
    trees.insert(rmin, tree.clone());
    tree
}

fn run_job(inner: &Inner, _id: JobId, spec: &JobSpec) -> Result<JobResult, String> {
    let ds = get_dataset(inner, &spec.dataset);
    // Serialize jobs on this dataset: exact per-job distance accounting.
    let _guard = ds.run_lock.lock().unwrap();
    let space = &*ds.space;
    let start = Instant::now();
    let before = space.dist_count();

    let output = match &spec.kind {
        JobKind::Kmeans { k, iters, anchors_init } => {
            let init = if *anchors_init {
                kmeans::Init::Anchors
            } else {
                kmeans::Init::Random
            };
            let opts = kmeans::KmeansOpts {
                engine: inner.engine.clone(),
                ..Default::default()
            };
            let r = if spec.use_tree {
                let tree = get_tree(&ds, spec.rmin, spec.dataset.seed);
                kmeans::tree_lloyd(space, &tree, init, *k, *iters, &opts)
            } else {
                kmeans::naive_lloyd(space, init, *k, *iters, &opts)
            };
            JobOutput::Kmeans { distortion: r.distortion, iterations: r.iterations }
        }
        JobKind::Anomaly { threshold, target_frac } => {
            let radius = anomaly::calibrate_radius(space, *threshold, *target_frac, 50, 7);
            let params = anomaly::AnomalyParams { radius, threshold: *threshold };
            let sweep = if spec.use_tree {
                let tree = get_tree(&ds, spec.rmin, spec.dataset.seed);
                anomaly::tree_sweep(space, &tree, &params)
            } else {
                anomaly::naive_sweep(space, &params)
            };
            JobOutput::Anomaly { n_anomalies: sweep.n_anomalies, radius }
        }
        JobKind::AllPairs { tau } => {
            let r = if spec.use_tree {
                let tree = get_tree(&ds, spec.rmin, spec.dataset.seed);
                allpairs::tree_close_pairs(space, &tree, *tau)
            } else {
                allpairs::naive_close_pairs(space, *tau)
            };
            JobOutput::AllPairs { n_pairs: r.pairs.len() }
        }
        JobKind::Mst => {
            let edges = if spec.use_tree {
                let tree = get_tree(&ds, spec.rmin, spec.dataset.seed);
                mst::tree_mst(space, &tree)
            } else {
                mst::naive_mst(space)
            };
            JobOutput::Mst {
                total_weight: mst::total_weight(&edges),
                n_edges: edges.len(),
            }
        }
    };

    Ok(JobResult {
        id: _id,
        output,
        dists: space.dist_count() - before,
        wall_ms: start.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;

    fn tiny(kind: DatasetKind) -> DatasetSpec {
        DatasetSpec::scaled(kind, 0.004) // a few hundred rows
    }

    fn km(k: usize, use_tree: bool) -> JobSpec {
        JobSpec {
            dataset: tiny(DatasetKind::Squiggles),
            kind: JobKind::Kmeans { k, iters: 4, anchors_init: false },
            use_tree,
            rmin: 16,
        }
    }

    #[test]
    fn runs_one_job() {
        let coord = Coordinator::new(2, 16);
        let id = coord.submit(km(3, true)).unwrap();
        match coord.wait(id) {
            JobState::Done(r) => {
                assert!(r.dists > 0);
                assert!(matches!(r.output, JobOutput::Kmeans { .. }));
            }
            other => panic!("unexpected state {other:?}"),
        }
    }

    #[test]
    fn naive_and_tree_jobs_agree() {
        let coord = Coordinator::new(2, 16);
        let a = coord.submit(km(4, false)).unwrap();
        let b = coord.submit(km(4, true)).unwrap();
        let (ra, rb) = (coord.wait(a), coord.wait(b));
        let (JobState::Done(ra), JobState::Done(rb)) = (ra, rb) else {
            panic!("jobs failed");
        };
        let (JobOutput::Kmeans { distortion: da, .. }, JobOutput::Kmeans { distortion: db, .. }) =
            (&ra.output, &rb.output)
        else {
            panic!("wrong outputs");
        };
        assert!((da - db).abs() < 1e-6 * (1.0 + da), "{da} vs {db}");
        // And the tree job used fewer distances (cache shares the build).
        assert!(rb.dists < ra.dists * 2, "tree {} naive {}", rb.dists, ra.dists);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, capacity 2, and jobs slow enough to pile up.
        let coord = Coordinator::new(1, 2);
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for _ in 0..12 {
            match coord.submit(km(3, true)) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e:?}"),
            }
        }
        assert!(rejected > 0, "queue never filled");
        for id in accepted {
            assert!(coord.wait(id).is_terminal());
        }
        let m = coord.metrics();
        assert_eq!(m.rejected, rejected);
        assert_eq!(m.completed + m.failed, m.submitted);
    }

    #[test]
    fn all_kinds_execute() {
        let coord = Coordinator::new(3, 32);
        let specs = vec![
            JobSpec {
                dataset: tiny(DatasetKind::Squiggles),
                kind: JobKind::Anomaly { threshold: 5, target_frac: 0.1 },
                use_tree: true,
                rmin: 16,
            },
            JobSpec {
                dataset: tiny(DatasetKind::Squiggles),
                kind: JobKind::AllPairs { tau: 0.5 },
                use_tree: true,
                rmin: 16,
            },
            JobSpec {
                dataset: tiny(DatasetKind::Voronoi),
                kind: JobKind::Mst,
                use_tree: true,
                rmin: 16,
            },
            km(5, true),
        ];
        let ids: Vec<JobId> = specs
            .into_iter()
            .map(|s| coord.submit(s).unwrap())
            .collect();
        for id in ids {
            match coord.wait(id) {
                JobState::Done(_) => {}
                other => panic!("job {id} -> {other:?}"),
            }
        }
    }

    #[test]
    fn shutdown_reports_metrics() {
        let coord = Coordinator::new(2, 8);
        let id = coord.submit(km(3, true)).unwrap();
        coord.wait(id);
        let m = coord.shutdown();
        assert_eq!(m.submitted, 1);
        assert_eq!(m.completed, 1);
        assert!(m.total_dists > 0);
    }

    #[test]
    fn dataset_cache_shared_across_jobs() {
        let coord = Coordinator::new(2, 8);
        // Two tree jobs on the same dataset: the second must not pay the
        // tree build again, so its distance count is much lower.
        let a = coord.submit(km(3, true)).unwrap();
        let JobState::Done(ra) = coord.wait(a) else { panic!() };
        let b = coord.submit(km(3, true)).unwrap();
        let JobState::Done(rb) = coord.wait(b) else { panic!() };
        assert!(
            rb.dists <= ra.dists,
            "second job re-paid the build: {} vs {}",
            rb.dists,
            ra.dists
        );
    }
}
