//! The batch-analytics coordinator: the service layer that makes the
//! paper's algorithms consumable as *jobs* over named datasets.
//!
//! Clients submit [`JobSpec`]s — an [`engine::Query`] (any of the eight
//! algorithm families, naive or tree-accelerated) against a dataset. A
//! fixed worker pool executes them through the [`engine::Index`] facade.
//! Design points:
//!
//! * **Dataset cache** — generating a Table-1 dataset and building its
//!   metric tree is expensive; both are cached and shared (Arc) across
//!   jobs keyed by (dataset, rmin), then assembled into a per-job
//!   [`engine::Index`] via [`engine::Index::from_parts`].
//! * **Per-dataset serialization** — a dataset's distance counter is
//!   shared state; the coordinator runs at most one job per dataset at a
//!   time so each job's distance accounting is exact. Different datasets
//!   run fully in parallel.
//! * **Backpressure** — the queue is bounded; `submit` fails fast with
//!   [`SubmitError::QueueFull`] instead of buffering unboundedly.
//! * **No lost or duplicated jobs** — every accepted job reaches exactly
//!   one terminal state ([`JobState::Done`] / [`JobState::Failed`]);
//!   verified by property tests.
//! * **Worker-pool concurrency first** — jobs run serial internally by
//!   default (the pool is the parallelism); `PALLAS_THREADS` opts a
//!   deployment into intra-job parallelism via [`crate::parallel`],
//!   which changes wall-clock only, never results or distance counts.
//! * **Deadlines & cancellation** — [`JobSpec::deadline_ms`] arms a
//!   per-job deadline; [`Coordinator::cancel`] stops queued *and*
//!   running jobs. Both act through one mechanism: a
//!   [`crate::cancel::CancelSlot`] shared with the job's [`Space`],
//!   polled at traversal checkpoints (frontier pops and leaf-scan
//!   boundaries — never inside a distance kernel), so the happy path is
//!   observationally free and results stay bit-identical. An
//!   interrupted job ends in `Failed("cancelled")`/`Failed("deadline")`
//!   ([`JobFailure`]) with its *partial* [`QueryStats`] attached.
//! * **Graceful degradation** — a per-dataset circuit breaker
//!   quarantines a dataset after K consecutive job *panics*
//!   ([`CoordinatorConfig::breaker_k`]): further jobs fail fast with
//!   `"breaker_open"` instead of re-crashing workers, until a cooldown
//!   and a successful half-open probe close it. Cancelled/deadline
//!   failures neither trip nor reset the breaker.
//! * **Drain** — [`Coordinator::drain`] stops intake and waits (bounded)
//!   for in-flight work; [`Coordinator::shutdown`] and `Drop` use the
//!   same path and *detach* rather than hang on a wedged worker.
//! * **Fault drills** — every failure path above is exercised by the
//!   deterministic [`crate::faults`] injector (`PALLAS_FAULTS`, default
//!   off): forced job panics, queue-full storms, slow leaves.
//!
//! One `Coordinator` is one *shard*: a self-contained queue + worker
//! pool + dataset/tree cache. [`shard::ShardedCoordinator`] composes N
//! of them behind a consistent-hash router on the dataset cache key so
//! different datasets never contend on a lock, a queue, or a cache
//! mutex — see the [`shard`] module docs.

pub mod server;
pub mod shard;

pub use shard::ShardedCoordinator;

use crate::cancel::{CancelReason, CancelSlot, CancelUnwind};
use crate::dataset::DatasetSpec;
use crate::engine::{self, IndexBuilder, Query, QueryResult};
use crate::metrics::Space;
use crate::obs::{self, Histogram, HistogramSnapshot, QueryStats};
use crate::parallel::{Executor, Parallelism};
use crate::runtime::BatchDistanceEngine;
use crate::tree::middle_out::{self, MiddleOutConfig};
use crate::tree::MetricTree;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A complete job description: which dataset, which query, which leaf
/// threshold for the cached tree. What to run — including the
/// naive-vs-tree switch — lives inside the [`Query`].
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub dataset: DatasetSpec,
    pub query: Query,
    /// Leaf threshold for the cached tree.
    pub rmin: usize,
    /// Optional deadline, milliseconds from submit. When it expires the
    /// job is abandoned: removed from the queue if still queued, or
    /// cooperatively cancelled at its next traversal checkpoint if
    /// running. Either way it ends in `Failed("deadline")`.
    pub deadline_ms: Option<u64>,
}

impl JobSpec {
    /// The cache key this job routes on: `(dataset, rmin)`. The sharded
    /// router ([`shard::ShardedCoordinator`]) hashes exactly this
    /// string, so every job stream for one `(dataset, rmin)` pair lands
    /// on one shard, where it shares that shard's cached `Space` and
    /// tree and serializes on that shard's per-dataset run lock (exact
    /// per-job distance accounting). Jobs with different keys never
    /// contend across shards.
    ///
    /// Tradeoff (deliberate): because `rmin` is part of the key, one
    /// dataset queried at two `rmin` values may land on two shards,
    /// each generating and holding its own `Space` copy. That buys
    /// cross-`rmin` parallelism — the two streams stop serializing on
    /// one run lock — at the cost of duplicated generation time and
    /// resident memory per extra `rmin`. Deployments that pin one
    /// `rmin` per dataset (the common case; the CLI default is 30)
    /// never pay it. Dataset generation counts no distances, so the
    /// duplication never changes any job's distance accounting.
    pub fn route_key(&self) -> String {
        format!("{}#rmin={}", dataset_key(&self.dataset), self.rmin)
    }
}

/// Job identifier.
pub type JobId = u64;

/// Terminal result of a job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: JobId,
    pub output: QueryResult,
    /// Distance computations attributed to this job (tree build included
    /// on first use of a dataset/rmin pair).
    pub dists: u64,
    /// Deterministic traversal counters for exactly this job's query
    /// (nodes visited, prunes by rule, leaf rows, ...). Bit-identical
    /// across thread and shard counts — see `tests/obs_equivalence.rs`.
    pub stats: QueryStats,
    pub wall_ms: f64,
}

/// Why a job failed, beyond the error string: the coordinator's metric
/// and breaker decisions key on this, and the server maps it to wire
/// fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// Dispatch-level error (malformed query, ...).
    Error,
    /// The job's execution panicked (caught; trips the dataset breaker).
    Panic,
    /// Explicitly cancelled while running (or between claim and run).
    Cancelled,
    /// `deadline_ms` expired while the job was running.
    Deadline,
    /// Failed fast because the dataset's circuit breaker was open.
    BreakerOpen,
}

/// Terminal failure of a job: the error string (what the wire reports),
/// the [`FailureKind`], and — for jobs interrupted mid-traversal — the
/// partial deterministic [`QueryStats`] up to the stop point.
#[derive(Clone, Debug)]
pub struct JobFailure {
    pub error: String,
    pub kind: FailureKind,
    /// Partial traversal counters for jobs stopped mid-flight
    /// (deadline, running-cancel, panic after the traversal started).
    /// `None` for jobs that never started running.
    pub stats: Option<QueryStats>,
}

impl JobFailure {
    fn interrupted(reason: CancelReason, stats: Option<QueryStats>) -> JobFailure {
        JobFailure {
            error: reason.as_str().into(),
            kind: match reason {
                CancelReason::Cancelled => FailureKind::Cancelled,
                CancelReason::Deadline => FailureKind::Deadline,
            },
            stats,
        }
    }
}

impl std::fmt::Display for JobFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.error)
    }
}

impl From<String> for JobFailure {
    fn from(error: String) -> JobFailure {
        JobFailure { error, kind: FailureKind::Error, stats: None }
    }
}

impl From<&str> for JobFailure {
    fn from(error: &str) -> JobFailure {
        JobFailure::from(error.to_string())
    }
}

/// Compare against the bare error string (`"cancelled"`, `"deadline"`,
/// ...) — what tests and wire assertions key on.
impl PartialEq<&str> for JobFailure {
    fn eq(&self, other: &&str) -> bool {
        self.error == *other
    }
}

/// Lifecycle of a job.
#[derive(Clone, Debug)]
pub enum JobState {
    Queued,
    Running,
    Done(JobResult),
    Failed(JobFailure),
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done(_) | JobState::Failed(_))
    }
}

/// Submission failures.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
}

/// Aggregate counters (monotonic).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Jobs cancelled while still queued. Each is also counted under
    /// `failed` (its terminal state is `Failed("cancelled")`), so
    /// `completed + failed == submitted` keeps holding.
    pub cancelled: AtomicU64,
    /// Jobs cancelled after they started running (cooperative
    /// checkpoint cancellation). Also a subset of `failed`.
    pub cancelled_running: AtomicU64,
    /// Jobs that ended `Failed("deadline")` (queued or running). Also a
    /// subset of `failed`.
    pub deadline_exceeded: AtomicU64,
    /// Jobs failed fast because their dataset's breaker was open. Also
    /// a subset of `failed`.
    pub breaker_open: AtomicU64,
    pub total_dists: AtomicU64,
}

/// Point-in-time metric values.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    /// Subset of `failed`: jobs cancelled while still queued.
    pub cancelled: u64,
    /// Subset of `failed`: jobs cancelled after they started running.
    pub cancelled_running: u64,
    /// Subset of `failed`: jobs that hit their deadline.
    pub deadline_exceeded: u64,
    /// Subset of `failed`: jobs rejected by an open dataset breaker.
    pub breaker_open: u64,
    pub total_dists: u64,
}

impl MetricsSnapshot {
    /// Field-wise sum — the aggregate view over coordinator shards.
    pub fn merge(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted + other.submitted,
            rejected: self.rejected + other.rejected,
            completed: self.completed + other.completed,
            failed: self.failed + other.failed,
            cancelled: self.cancelled + other.cancelled,
            cancelled_running: self.cancelled_running + other.cancelled_running,
            deadline_exceeded: self.deadline_exceeded + other.deadline_exceeded,
            breaker_open: self.breaker_open + other.breaker_open,
            total_dists: self.total_dists + other.total_dists,
        }
    }
}

/// Robustness knobs, per coordinator shard.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Consecutive job *panics* on one dataset before its circuit
    /// breaker opens (fail-fast `"breaker_open"` until a cooldown and a
    /// successful half-open probe). `0` disables the breaker.
    pub breaker_k: u32,
    /// How long an open breaker rejects before allowing one probe job.
    pub breaker_cooldown: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig { breaker_k: 3, breaker_cooldown: Duration::from_millis(1000) }
    }
}

/// Serving-edge observability owned by one coordinator shard: latency
/// histograms (µs, √2 buckets) plus per-family lifetime [`QueryStats`]
/// aggregates. The coordinator and server are the only layers allowed
/// to read the clock (pallas-lint D2 keeps `std::time` out of the
/// algorithm/tree/metrics/engine dirs), so wall-time lives here while
/// the in-algorithm counters stay deterministic.
struct EdgeObs {
    /// Submit → claimed by a worker.
    queue_wait: Histogram,
    /// Index assembly (includes the cached tree's first build).
    build: Histogram,
    /// `Index::run_traced` alone, per query family.
    run: [Histogram; obs::FAMILIES.len()],
    /// Submit → terminal state, per query family.
    e2e: [Histogram; obs::FAMILIES.len()],
    /// Lifetime sum of per-job [`QueryStats`], per query family.
    stats: Mutex<Vec<QueryStats>>,
}

impl EdgeObs {
    fn new() -> EdgeObs {
        EdgeObs {
            queue_wait: Histogram::new(),
            build: Histogram::new(),
            run: std::array::from_fn(|_| Histogram::new()),
            e2e: std::array::from_fn(|_| Histogram::new()),
            stats: Mutex::new(vec![QueryStats::default(); obs::FAMILIES.len()]),
        }
    }

    fn snapshot(&self) -> ObsSnapshot {
        ObsSnapshot {
            queue_wait: self.queue_wait.snapshot(),
            build: self.build.snapshot(),
            run: self.run.iter().map(Histogram::snapshot).collect(),
            e2e: self.e2e.iter().map(Histogram::snapshot).collect(),
            stats: self.stats.lock().unwrap().clone(),
        }
    }
}

/// Point-in-time serving-edge observability values. Like
/// [`MetricsSnapshot`], snapshots merge field-wise across shards; the
/// merge is order-invariant (histogram buckets and counter sums are
/// commutative), so any fold order over shards yields the same
/// aggregate.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ObsSnapshot {
    pub queue_wait: HistogramSnapshot,
    pub build: HistogramSnapshot,
    /// Indexed by [`obs::FAMILIES`].
    pub run: Vec<HistogramSnapshot>,
    /// Indexed by [`obs::FAMILIES`].
    pub e2e: Vec<HistogramSnapshot>,
    /// Indexed by [`obs::FAMILIES`].
    pub stats: Vec<QueryStats>,
}

fn merge_hist_vec(a: &[HistogramSnapshot], b: &[HistogramSnapshot]) -> Vec<HistogramSnapshot> {
    let n = a.len().max(b.len());
    let zero = HistogramSnapshot::default();
    (0..n)
        .map(|i| a.get(i).unwrap_or(&zero).merge(b.get(i).unwrap_or(&zero)))
        .collect()
}

impl ObsSnapshot {
    /// Field-wise sum — the aggregate view over coordinator shards.
    pub fn merge(&self, other: &ObsSnapshot) -> ObsSnapshot {
        let n = self.stats.len().max(other.stats.len());
        let mut stats = vec![QueryStats::default(); n];
        for (i, s) in stats.iter_mut().enumerate() {
            if let Some(a) = self.stats.get(i) {
                s.accumulate(a);
            }
            if let Some(b) = other.stats.get(i) {
                s.accumulate(b);
            }
        }
        ObsSnapshot {
            queue_wait: self.queue_wait.merge(&other.queue_wait),
            build: self.build.merge(&other.build),
            run: merge_hist_vec(&self.run, &other.run),
            e2e: merge_hist_vec(&self.e2e, &other.e2e),
            stats,
        }
    }
}

/// Saturating `Duration` → whole microseconds for histogram recording.
fn micros(d: Duration) -> u64 {
    u64::try_from(d.as_micros()).unwrap_or(u64::MAX)
}

struct CachedDataset {
    space: Arc<Space>,
    /// Trees per rmin (built lazily under the dataset lock).
    trees: Mutex<HashMap<usize, Arc<MetricTree>>>,
    /// Serializes jobs touching this dataset (exact distance accounting).
    run_lock: Mutex<()>,
}

/// Claimed-job bookkeeping for cancellation. `slots` maps a registered
/// running job to the cancel slot its traversal polls; `pending` holds
/// cancel/deadline verdicts that arrived while the job was claimed but
/// not yet registered (e.g. mid dataset build) — applied at
/// registration, or at [`finish_job`] for verdicts that land after the
/// job unregistered. Every `cancel`/expiry that answers `true` goes
/// through one of those two consumption points, so an affirmative
/// cancel always ends in `Failed` — never a lie.
#[derive(Default)]
struct RunningMap {
    slots: HashMap<JobId, Arc<CancelSlot>>,
    pending: HashMap<JobId, CancelReason>,
}

/// Per-dataset circuit-breaker state.
#[derive(Clone, Copy, Debug, Default)]
struct BreakerState {
    /// Consecutive panics (reset by any success).
    consecutive: u32,
    /// While `Some`, the breaker is open until this instant.
    open_until: Option<Instant>,
    /// Half-open: one probe job is in flight.
    probing: bool,
}

struct Inner {
    /// Each entry carries its submit instant so the claiming worker can
    /// record queue-wait and end-to-end latency.
    queue: Mutex<VecDeque<(JobId, JobSpec, Instant)>>,
    queue_cv: Condvar,
    capacity: usize,
    states: Mutex<HashMap<JobId, JobState>>,
    state_cv: Condvar,
    datasets: Mutex<HashMap<String, Arc<CachedDataset>>>,
    /// Claimed-job cancellation bookkeeping. Lock order: `queue` →
    /// `running` → `states` (→ `breakers` is leaf-only); `deadlines` is
    /// only ever taken first.
    running: Mutex<RunningMap>,
    /// Pending job deadlines, earliest first, owned by the timer thread.
    deadlines: Mutex<BinaryHeap<Reverse<(Instant, JobId)>>>,
    deadline_cv: Condvar,
    /// Per-dataset circuit breakers (keyed by [`dataset_key`]).
    breakers: Mutex<HashMap<String, BreakerState>>,
    /// Workers still running their loop; [`Coordinator::drain`] waits on
    /// this instead of `join` so a wedged worker can't hang the caller.
    live_workers: Mutex<usize>,
    worker_cv: Condvar,
    metrics: Metrics,
    obs: EdgeObs,
    shutdown: AtomicBool,
    config: CoordinatorConfig,
    engine: Option<Arc<BatchDistanceEngine>>,
    /// Intra-job worker budget. The pool's own workers are the primary
    /// source of concurrency, so jobs default to serial execution —
    /// `PALLAS_THREADS` overrides for single-tenant deployments where
    /// one big job should use the whole machine. Results and distance
    /// accounting are identical either way.
    parallelism: Parallelism,
    next_id: AtomicU64,
}

/// The coordinator service.
pub struct Coordinator {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
    timer: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start `n_workers` workers with a queue bound of `capacity`.
    pub fn new(n_workers: usize, capacity: usize) -> Coordinator {
        Self::with_engine(n_workers, capacity, None)
    }

    /// Start with an optional XLA batch engine shared by all jobs.
    pub fn with_engine(
        n_workers: usize,
        capacity: usize,
        engine: Option<Arc<BatchDistanceEngine>>,
    ) -> Coordinator {
        Self::with_config(n_workers, capacity, engine, CoordinatorConfig::default())
    }

    /// Start with explicit robustness knobs (breaker threshold/cooldown).
    pub fn with_config(
        n_workers: usize,
        capacity: usize,
        engine: Option<Arc<BatchDistanceEngine>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        let parallelism = Parallelism::from_env().unwrap_or(Parallelism::Serial);
        let n_workers = n_workers.max(1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            capacity: capacity.max(1),
            states: Mutex::new(HashMap::new()),
            state_cv: Condvar::new(),
            datasets: Mutex::new(HashMap::new()),
            running: Mutex::new(RunningMap::default()),
            deadlines: Mutex::new(BinaryHeap::new()),
            deadline_cv: Condvar::new(),
            breakers: Mutex::new(HashMap::new()),
            live_workers: Mutex::new(n_workers),
            worker_cv: Condvar::new(),
            metrics: Metrics::default(),
            obs: EdgeObs::new(),
            shutdown: AtomicBool::new(false),
            config,
            engine,
            parallelism,
            next_id: AtomicU64::new(1),
        });
        let workers = (0..n_workers)
            .map(|wid| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("coord-worker-{wid}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn worker")
            })
            .collect();
        let timer = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("coord-deadline".into())
                .spawn(move || timer_loop(inner))
                .expect("spawn deadline timer")
        };
        Coordinator { inner, workers, timer: Some(timer) }
    }

    /// Submit a job; fails fast when the queue is at capacity.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        // Injected queue-full storm (drills only; `active` is the
        // always-off fast gate). Counted under `rejected` like a real
        // full queue — the client-visible contract is identical.
        if crate::faults::active() && crate::faults::should_reject_submit() {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull);
        }
        let deadline_ms = spec.deadline_ms;
        let mut queue = self.inner.queue.lock().unwrap();
        if queue.len() >= self.inner.capacity {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        queue.push_back((id, spec, Instant::now()));
        self.inner
            .states
            .lock()
            .unwrap()
            .insert(id, JobState::Queued);
        self.inner.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.inner.queue_cv.notify_one();
        drop(queue);
        if let Some(ms) = deadline_ms {
            let due = Instant::now() + Duration::from_millis(ms);
            self.inner
                .deadlines
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(Reverse((due, id)));
            self.inner.deadline_cv.notify_all();
        }
        Ok(id)
    }

    /// Snapshot a job's state (`None` for an id this coordinator never
    /// issued — the non-panicking sibling of [`Coordinator::wait`],
    /// safe for untrusted ids off the wire).
    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.states.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job reaches a terminal state.
    ///
    /// # Panics
    /// On an unknown job id; untrusted ids (e.g. off the wire) should
    /// go through [`Coordinator::wait_checked`] instead.
    pub fn wait(&self, id: JobId) -> JobState {
        self.wait_checked(id)
            .unwrap_or_else(|| panic!("unknown job id {id}"))
    }

    /// Non-panicking [`Coordinator::wait`]: `None` for an id this
    /// coordinator has never issued. Sound against check-then-wait
    /// races because job states are never evicted — an id seen once
    /// stays resolvable for the coordinator's lifetime.
    pub fn wait_checked(&self, id: JobId) -> Option<JobState> {
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                Some(s) if s.is_terminal() => return Some(s.clone()),
                Some(_) => {
                    states = self.inner.state_cv.wait(states).unwrap();
                }
                None => return None,
            }
        }
    }

    /// Current queue depth.
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let m = &self.inner.metrics;
        MetricsSnapshot {
            submitted: m.submitted.load(Ordering::Relaxed),
            rejected: m.rejected.load(Ordering::Relaxed),
            completed: m.completed.load(Ordering::Relaxed),
            failed: m.failed.load(Ordering::Relaxed),
            cancelled: m.cancelled.load(Ordering::Relaxed),
            cancelled_running: m.cancelled_running.load(Ordering::Relaxed),
            deadline_exceeded: m.deadline_exceeded.load(Ordering::Relaxed),
            breaker_open: m.breaker_open.load(Ordering::Relaxed),
            total_dists: m.total_dists.load(Ordering::Relaxed),
        }
    }

    /// Snapshot the serving-edge observability state: latency histograms
    /// and per-family lifetime query stats.
    pub fn obs(&self) -> ObsSnapshot {
        self.inner.obs.snapshot()
    }

    /// Cancel a job. Queued: removed from the queue and moved straight
    /// to `Failed("cancelled")`. Running (or claimed): its cancel slot
    /// is flagged — the traversal unwinds at its next checkpoint and the
    /// job ends `Failed("cancelled")` with partial stats. Returns
    /// `false` — and changes nothing — only for unknown or already
    /// terminal jobs. **An affirmative answer is a promise**: once
    /// `cancel` returns `true` the job's terminal state is `Failed`,
    /// even if its traversal happened to finish in the race window (the
    /// completed result is discarded).
    pub fn cancel(&self, id: JobId) -> bool {
        // Queued: holding the queue lock pins the race with worker pop —
        // a job found here cannot simultaneously be claimed.
        {
            let mut queue = self.inner.queue.lock().unwrap();
            if let Some(pos) = queue.iter().position(|(jid, _, _)| *jid == id) {
                queue.remove(pos);
                drop(queue);
                self.inner.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                self.inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                set_state(
                    &self.inner,
                    id,
                    JobState::Failed(JobFailure::interrupted(CancelReason::Cancelled, None)),
                );
                return true;
            }
        }
        // Running: flag the registered slot, or leave a pending marker
        // for a claimed-but-unregistered job (consumed at registration
        // or at finish — see [`RunningMap`]). All under the running
        // lock, which [`finish_job`] also holds while publishing the
        // terminal state: seeing a non-terminal state here guarantees
        // the marker is consumed.
        let mut running = self.inner.running.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(slot) = running.slots.get(&id) {
            slot.set(CancelReason::Cancelled);
            return true;
        }
        let live = matches!(
            self.inner.states.lock().unwrap().get(&id),
            Some(s) if !s.is_terminal()
        );
        if live {
            running.pending.insert(id, CancelReason::Cancelled);
            return true;
        }
        false
    }

    /// Stop accepting new jobs and wake every sleeper (workers drain the
    /// queue, the deadline timer exits). Does not wait; pair with
    /// [`Coordinator::drain`].
    pub fn request_shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        self.inner.deadline_cv.notify_all();
    }

    /// Stop intake and wait up to `timeout` for the workers to finish
    /// everything queued or in flight. Returns `true` when the shard
    /// fully drained, `false` if a straggler was still running at the
    /// bound (it keeps draining in the background; a later `drain` call
    /// can re-check).
    pub fn drain(&self, timeout: Duration) -> bool {
        self.request_shutdown();
        let deadline = Instant::now() + timeout;
        let mut live = self
            .inner
            .live_workers
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        while *live > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self
                .inner
                .worker_cv
                .wait_timeout(live, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            live = guard;
        }
        true
    }

    /// Drain the queue, stop accepting work, and join the workers
    /// (bounded — a wedged worker is detached, not waited on forever).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.finish(Duration::from_secs(60));
        self.metrics()
    }

    /// Bounded teardown: drain, then join (or detach, on timeout) the
    /// worker threads and join the deadline timer. Idempotent.
    fn finish(&mut self, timeout: Duration) {
        if self.workers.is_empty() && self.timer.is_none() {
            return;
        }
        let drained = self.drain(timeout);
        if drained {
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
        } else {
            // Wedged worker: detach instead of hanging the caller. The
            // thread keeps draining in the background and exits on its
            // own once its job trips a checkpoint or completes.
            self.workers.clear();
        }
        // The timer always exits promptly once the shutdown flag is up
        // (its waits are bounded), so this join is safe.
        if let Some(t) = self.timer.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.finish(Duration::from_secs(60));
    }
}

/// Decrements the live-worker count however the worker exits (normal
/// return or an unexpected panic escaping the per-job catch), keeping
/// [`Coordinator::drain`] accurate.
struct WorkerExit<'a>(&'a Inner);

impl Drop for WorkerExit<'_> {
    fn drop(&mut self) {
        let mut live = self
            .0
            .live_workers
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        *live = live.saturating_sub(1);
        self.0.worker_cv.notify_all();
    }
}

fn worker_loop(inner: Arc<Inner>) {
    let _exit = WorkerExit(&inner);
    // One executor (and persistent worker pool) per coordinator worker:
    // repeated jobs on this worker reuse its parked threads, while
    // concurrent jobs on other workers keep fully independent pools (a
    // single shared pool would serialize every job's parallel passes on
    // the broadcast channel). With the default serial budget this is
    // poolless and free.
    let exec = Executor::new(inner.parallelism);
    loop {
        let job = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if let Some(job) = queue.pop_front() {
                    break Some(job);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = inner.queue_cv.wait(queue).unwrap();
            }
        };
        let Some((id, spec, submitted_at)) = job else { return };
        inner.obs.queue_wait.record(micros(submitted_at.elapsed()));
        set_state(&inner, id, JobState::Running);
        let dataset = dataset_key(&spec.dataset);
        let outcome = if breaker_admit(&inner, &dataset) {
            // The outer catch covers the claim-to-register window
            // (dataset generation); everything after registration is
            // caught inside `run_job` so it can unregister first.
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_job(&inner, id, &spec, &exec)
            }))
            .unwrap_or_else(|payload| Err(failure_from_unwind(payload.as_ref(), None)))
        } else {
            inner.metrics.breaker_open.fetch_add(1, Ordering::Relaxed);
            Err(JobFailure {
                error: "breaker_open".into(),
                kind: FailureKind::BreakerOpen,
                stats: None,
            })
        };
        match finish_job(&inner, id, outcome) {
            None => breaker_record(&inner, &dataset, false),
            Some(FailureKind::Panic) => breaker_record(&inner, &dataset, true),
            // Cancelled/deadline/fail-fast are not evidence about the
            // dataset's health: they neither trip nor reset the breaker.
            Some(_) => {}
        }
        // Submit → terminal, recorded for successes and failures alike.
        if let Some(fi) = obs::family_index(spec.query.kind()) {
            inner.obs.e2e[fi].record(micros(submitted_at.elapsed()));
        }
    }
}

/// Publish a claimed job's terminal state, atomically (under the
/// running lock) resolving any cancel/deadline verdict that landed
/// after the job unregistered — the other half of the `cancel`-true
/// promise. Returns `None` for `Done`, the [`FailureKind`] otherwise.
fn finish_job(inner: &Inner, id: JobId, outcome: Result<JobResult, JobFailure>) -> Option<FailureKind> {
    let mut running = inner.running.lock().unwrap_or_else(|e| e.into_inner());
    let outcome = match (outcome, running.pending.remove(&id)) {
        // A cancel answered `true` in the window where the job had
        // finished but its state wasn't terminal yet: honor it, the
        // completed result is discarded (deliberately — see `cancel`).
        (Ok(r), Some(reason)) => Err(JobFailure::interrupted(reason, Some(r.stats))),
        (outcome, _) => outcome,
    };
    let kind = match outcome {
        Ok(result) => {
            inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
            inner
                .metrics
                .total_dists
                .fetch_add(result.dists, Ordering::Relaxed);
            set_state(inner, id, JobState::Done(result));
            None
        }
        Err(failure) => {
            inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
            match failure.kind {
                FailureKind::Cancelled => {
                    inner.metrics.cancelled_running.fetch_add(1, Ordering::Relaxed);
                }
                FailureKind::Deadline => {
                    inner.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                }
                _ => {}
            }
            let kind = failure.kind;
            set_state(inner, id, JobState::Failed(failure));
            Some(kind)
        }
    };
    drop(running);
    kind
}

/// Classify an unwind payload: a typed [`CancelUnwind`] (checkpoint
/// trip) vs. a real panic.
fn failure_from_unwind(
    payload: &(dyn std::any::Any + Send),
    stats: Option<QueryStats>,
) -> JobFailure {
    if let Some(cu) = payload.downcast_ref::<CancelUnwind>() {
        return JobFailure::interrupted(cu.reason, stats);
    }
    let error = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "job panicked".into());
    JobFailure { error, kind: FailureKind::Panic, stats }
}

/// Deadline timer: sleeps until the earliest pending deadline, fires
/// everything due, exits when the coordinator shuts down. Expiry of a
/// *queued* job fails it directly (like `cancel`); a running job gets
/// its slot flagged and unwinds at its next checkpoint.
fn timer_loop(inner: Arc<Inner>) {
    let mut heap = inner
        .deadlines
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    loop {
        let now = Instant::now();
        while let Some(&Reverse((due, id))) = heap.peek() {
            if due > now {
                break;
            }
            heap.pop();
            expire(&inner, id);
        }
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        heap = match heap.peek() {
            Some(&Reverse((due, _))) => {
                let wait = due.saturating_duration_since(Instant::now());
                inner
                    .deadline_cv
                    .wait_timeout(heap, wait)
                    .unwrap_or_else(|e| e.into_inner())
                    .0
            }
            None => inner
                .deadline_cv
                .wait(heap)
                .unwrap_or_else(|e| e.into_inner()),
        };
    }
}

/// Fire one job's deadline. Mirrors `cancel`'s three-way resolution
/// (queued / registered / claimed-but-unregistered); terminal jobs are
/// left untouched.
fn expire(inner: &Inner, id: JobId) {
    {
        let mut queue = inner.queue.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(pos) = queue.iter().position(|(jid, _, _)| *jid == id) {
            queue.remove(pos);
            drop(queue);
            inner.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
            set_state(
                inner,
                id,
                JobState::Failed(JobFailure::interrupted(CancelReason::Deadline, None)),
            );
            return;
        }
    }
    let mut running = inner.running.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(slot) = running.slots.get(&id) {
        slot.set(CancelReason::Deadline);
        return;
    }
    let live = matches!(
        inner.states.lock().unwrap().get(&id),
        Some(s) if !s.is_terminal()
    );
    if live {
        running.pending.insert(id, CancelReason::Deadline);
    }
}

/// Register a claimed job's cancel slot. Called under the dataset's run
/// lock (so one slot serves one job at a time) — arms the slot, then
/// applies any verdict that arrived before registration.
fn register_running(inner: &Inner, id: JobId, slot: &Arc<CancelSlot>) {
    let mut running = inner.running.lock().unwrap_or_else(|e| e.into_inner());
    slot.arm();
    if let Some(reason) = running.pending.remove(&id) {
        slot.set(reason);
    }
    running.slots.insert(id, Arc::clone(slot));
}

/// Unregister and read the slot's final verdict, atomically against
/// cancel/expiry (which only flag slots that are present in the map).
fn unregister_running(inner: &Inner, id: JobId, slot: &CancelSlot) -> Option<CancelReason> {
    let mut running = inner.running.lock().unwrap_or_else(|e| e.into_inner());
    running.slots.remove(&id);
    slot.get()
}

/// Should a job on this dataset run? `true` when the breaker is closed,
/// or half-open with no probe in flight (this job becomes the probe).
fn breaker_admit(inner: &Inner, key: &str) -> bool {
    let k = inner.config.breaker_k;
    if k == 0 {
        return true;
    }
    let mut map = inner.breakers.lock().unwrap_or_else(|e| e.into_inner());
    let b = map.entry(key.to_string()).or_default();
    if b.consecutive < k {
        return true;
    }
    match b.open_until {
        Some(until) if Instant::now() < until => false,
        _ => {
            if b.probing {
                false
            } else {
                b.probing = true;
                true
            }
        }
    }
}

/// Feed a job outcome to the dataset's breaker: any success closes it;
/// a panic bumps the consecutive count and (re)opens at the threshold.
fn breaker_record(inner: &Inner, key: &str, panicked: bool) {
    if inner.config.breaker_k == 0 {
        return;
    }
    let mut map = inner.breakers.lock().unwrap_or_else(|e| e.into_inner());
    let b = map.entry(key.to_string()).or_default();
    if panicked {
        b.consecutive += 1;
        b.probing = false;
        if b.consecutive >= inner.config.breaker_k {
            b.open_until = Some(Instant::now() + inner.config.breaker_cooldown);
        }
    } else {
        *b = BreakerState::default();
    }
}

fn set_state(inner: &Inner, id: JobId, state: JobState) {
    inner.states.lock().unwrap().insert(id, state);
    inner.state_cv.notify_all();
}

fn dataset_key(spec: &DatasetSpec) -> String {
    format!("{}@{}@{}", spec.kind.name(), spec.scale, spec.seed)
}

fn get_dataset(inner: &Inner, spec: &DatasetSpec) -> Arc<CachedDataset> {
    let key = dataset_key(spec);
    // Fast path. The map mutex recovers from poison: a panicking build
    // (caught by the worker) must not wedge every later job.
    if let Some(ds) = inner
        .datasets
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .get(&key)
    {
        return ds.clone();
    }
    // Build outside the map lock (generation can be slow), then insert —
    // first writer wins so concurrent builders converge on one copy.
    let built = Arc::new(CachedDataset {
        space: Arc::new(spec.build()),
        trees: Mutex::new(HashMap::new()),
        run_lock: Mutex::new(()),
    });
    let mut map = inner.datasets.lock().unwrap_or_else(|e| e.into_inner());
    map.entry(key).or_insert(built).clone()
}

fn get_tree(ds: &CachedDataset, rmin: usize, seed: u64, exec: &Executor) -> Arc<MetricTree> {
    // Poison-recovering for the same reason as the dataset map: a panic
    // mid-build leaves no partial entry behind (insert is post-build).
    let mut trees = ds.trees.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(t) = trees.get(&rmin) {
        return t.clone();
    }
    let cfg = MiddleOutConfig { rmin, seed, ..Default::default() };
    let tree = Arc::new(middle_out::build_ex(&ds.space, &cfg, exec));
    trees.insert(rmin, tree.clone());
    tree
}

/// Assemble the per-job [`engine::Index`] view over the cached parts.
/// Tree queries get the cached tree (built under the dataset lock on
/// first use); naive queries get a tree-less index so they never pay
/// for a build. Both reuse the calling worker's executor/pool.
fn get_index(
    inner: &Inner,
    ds: &CachedDataset,
    spec: &JobSpec,
    exec: &Executor,
) -> engine::Index {
    if spec.query.needs_tree() {
        let tree = get_tree(ds, spec.rmin, spec.dataset.seed, exec);
        engine::Index::from_parts(
            Arc::clone(&ds.space),
            tree,
            inner.engine.clone(),
            spec.dataset.seed,
            spec.rmin,
        )
        .with_executor(exec.clone())
    } else {
        // (No .parallelism() call: with_executor supersedes both the
        // budget and the executor, making `exec` the single source of
        // truth for this job's concurrency.)
        IndexBuilder::new(spec.dataset.clone())
            .rmin(spec.rmin)
            .batch_engine(inner.engine.clone())
            .build_on(Arc::clone(&ds.space))
            .with_executor(exec.clone())
    }
}

fn run_job(
    inner: &Inner,
    id: JobId,
    spec: &JobSpec,
    exec: &Executor,
) -> Result<JobResult, JobFailure> {
    let ds = get_dataset(inner, &spec.dataset);
    // Serialize jobs on this dataset: exact per-job distance accounting.
    // A panicking query unwinds while holding this guard and poisons the
    // mutex; the lock protects no invariant — only accounting
    // serialization — so recover rather than letting one bad request
    // permanently fail every later job on the dataset.
    let _guard = ds.run_lock.lock().unwrap_or_else(|e| e.into_inner());
    // Register for cooperative cancellation. The slot lives on the
    // dataset's `Space` (shared with every arena view of it); the run
    // lock guarantees it serves exactly this job until unregistered.
    let slot = ds.space.cancel_shared();
    register_running(inner, id, &slot);
    let start = Instant::now();
    let before = ds.space.dist_count();
    // Baseline for *partial* stats on the interrupted path. The happy
    // path keeps using `run_traced`'s own attribution, bit-identical to
    // a coordinator without cancellation support.
    let stats_before = ds.space.obs().snapshot();
    ds.space.obs().reset_frontier_peak();
    let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if crate::faults::active() && crate::faults::should_panic_job(id) {
            panic!("injected fault: job panic");
        }
        let index = get_index(inner, &ds, spec, exec);
        let build_us = micros(start.elapsed());
        let run_start = Instant::now();
        let (output, stats) = index.run_traced(&spec.query);
        (output, stats, build_us, micros(run_start.elapsed()))
    }));
    // Unregister while still holding the run lock (the slot must not be
    // re-armed by the dataset's next job before this one's verdict is
    // read), and read the final verdict under the running lock.
    let verdict = unregister_running(inner, id, &slot);
    let dists = ds.space.dist_count() - before;
    match attempt {
        Ok((output, stats, build_us, run_us)) => {
            if let Some(reason) = verdict {
                // Cancel/deadline landed after the last checkpoint but
                // before the job finished; the canceller was already
                // told `true`, so honor it and discard the result.
                return Err(JobFailure::interrupted(reason, Some(stats)));
            }
            inner.obs.build.record(build_us);
            if let Some(fi) = obs::family_index(spec.query.kind()) {
                inner.obs.run[fi].record(run_us);
                inner.obs.stats.lock().unwrap()[fi].accumulate(&stats);
            }
            let wall_ms = start.elapsed().as_secs_f64() * 1e3;
            if obs::trace::enabled() {
                use crate::json::Value;
                obs::trace::span(
                    "job",
                    &[
                        ("id", Value::Num(crate::ids::wire_from_u64(id))),
                        ("op", Value::Str(spec.query.kind().into())),
                        ("dataset", Value::Str(dataset_key(&spec.dataset))),
                        ("dists", Value::Num(crate::ids::wire_from_u64(dists))),
                        (
                            "nodes_visited",
                            Value::Num(crate::ids::wire_from_u64(stats.nodes_visited)),
                        ),
                        (
                            "pruned",
                            Value::Num(crate::ids::wire_from_u64(stats.total_pruned())),
                        ),
                        ("run_us", Value::Num(crate::ids::wire_from_u64(run_us))),
                        ("wall_ms", Value::Num(wall_ms)),
                    ],
                );
            }
            Ok(JobResult { id, output, stats, dists, wall_ms })
        }
        Err(payload) => {
            // Partial deterministic counters up to the unwind point
            // (attached for cancelled/deadline jobs and real panics
            // alike — the observability story for "what was it doing
            // when it stopped").
            let partial = ds.space.obs().snapshot().delta_from(&stats_before);
            Err(failure_from_unwind(payload.as_ref(), Some(partial)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::engine::{
        AllPairsQuery, AnomalyQuery, BallQuery, GaussianEmQuery, KmeansQuery, KnnQuery, KnnTarget,
        MstQuery, XmeansQuery,
    };
    use crate::faults::{FaultPlan, ScopedFaults};

    fn tiny(kind: DatasetKind) -> DatasetSpec {
        DatasetSpec::scaled(kind, 0.004) // a few hundred rows
    }

    fn km(k: usize, use_tree: bool) -> JobSpec {
        JobSpec {
            dataset: tiny(DatasetKind::Squiggles),
            query: Query::Kmeans(KmeansQuery { k, iters: 4, use_tree, ..Default::default() }),
            rmin: 16,
            deadline_ms: None,
        }
    }

    #[test]
    fn runs_one_job() {
        let coord = Coordinator::new(2, 16);
        let id = coord.submit(km(3, true)).unwrap();
        match coord.wait(id) {
            JobState::Done(r) => {
                assert!(r.dists > 0);
                assert!(matches!(r.output, QueryResult::Kmeans { .. }));
            }
            other => panic!("unexpected state {other:?}"),
        }
    }

    #[test]
    fn naive_and_tree_jobs_agree() {
        let coord = Coordinator::new(2, 16);
        let a = coord.submit(km(4, false)).unwrap();
        let b = coord.submit(km(4, true)).unwrap();
        let (ra, rb) = (coord.wait(a), coord.wait(b));
        let (JobState::Done(ra), JobState::Done(rb)) = (ra, rb) else {
            panic!("jobs failed");
        };
        let (
            QueryResult::Kmeans { distortion: da, .. },
            QueryResult::Kmeans { distortion: db, .. },
        ) = (&ra.output, &rb.output)
        else {
            panic!("wrong outputs");
        };
        assert!((da - db).abs() < 1e-6 * (1.0 + da), "{da} vs {db}");
        // And the tree job used fewer distances (cache shares the build).
        assert!(rb.dists < ra.dists * 2, "tree {} naive {}", rb.dists, ra.dists);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, capacity 2, and jobs slow enough to pile up.
        let coord = Coordinator::new(1, 2);
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for _ in 0..12 {
            match coord.submit(km(3, true)) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e:?}"),
            }
        }
        assert!(rejected > 0, "queue never filled");
        for id in accepted {
            assert!(coord.wait(id).is_terminal());
        }
        let m = coord.metrics();
        assert_eq!(m.rejected, rejected);
        assert_eq!(m.completed + m.failed, m.submitted);
    }

    #[test]
    fn all_query_families_execute() {
        let coord = Coordinator::new(3, 32);
        let squiggles = tiny(DatasetKind::Squiggles);
        let specs = vec![
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Anomaly(AnomalyQuery { threshold: 5, ..Default::default() }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: tiny(DatasetKind::Voronoi),
                query: Query::Mst(MstQuery { use_tree: true }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Xmeans(XmeansQuery { k_min: 1, k_max: 4 }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Ball(BallQuery {
                    center: vec![0.0, 0.0],
                    radius: 1.0,
                    use_tree: true,
                }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::GaussianEm(GaussianEmQuery {
                    k: 2,
                    steps: 2,
                    ..Default::default()
                }),
                rmin: 16,
                deadline_ms: None,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Knn(KnnQuery {
                    target: KnnTarget::Point(0),
                    k: 3,
                    use_tree: true,
                }),
                rmin: 16,
                deadline_ms: None,
            },
            km(5, true),
        ];
        let ids: Vec<JobId> = specs
            .into_iter()
            .map(|s| coord.submit(s).unwrap())
            .collect();
        for id in ids {
            match coord.wait(id) {
                JobState::Done(_) => {}
                other => panic!("job {id} -> {other:?}"),
            }
        }
    }

    #[test]
    fn failed_job_does_not_wedge_the_dataset() {
        // A query that panics in the dispatcher (wrong-dimension ball
        // center on 2-d squiggles) unwinds while holding the dataset's
        // run lock; later jobs on the same dataset must still succeed.
        let coord = Coordinator::new(1, 8);
        let bad = JobSpec {
            dataset: tiny(DatasetKind::Squiggles),
            query: Query::Ball(BallQuery {
                center: vec![0.0, 0.0, 0.0],
                radius: 1.0,
                use_tree: true,
            }),
            rmin: 16,
            deadline_ms: None,
        };
        let id = coord.submit(bad).unwrap();
        let JobState::Failed(f) = coord.wait(id) else { panic!("bad job succeeded") };
        assert_eq!(f.kind, FailureKind::Panic);
        let id = coord.submit(km(3, true)).unwrap();
        match coord.wait(id) {
            JobState::Done(_) => {}
            other => panic!("dataset wedged after failed job: {other:?}"),
        }
    }

    #[test]
    fn shutdown_reports_metrics() {
        let coord = Coordinator::new(2, 8);
        let id = coord.submit(km(3, true)).unwrap();
        coord.wait(id);
        let m = coord.shutdown();
        assert_eq!(m.submitted, 1);
        assert_eq!(m.completed, 1);
        assert!(m.total_dists > 0);
    }

    #[test]
    fn obs_snapshot_populates_after_jobs() {
        let coord = Coordinator::new(2, 16);
        let id = coord.submit(km(3, true)).unwrap();
        let JobState::Done(r) = coord.wait(id) else { panic!("job failed") };
        assert!(r.stats.nodes_visited > 0, "tree kmeans visited no nodes");
        let snap = coord.obs();
        assert_eq!(snap.run.len(), obs::FAMILIES.len());
        assert_eq!(snap.stats.len(), obs::FAMILIES.len());
        assert_eq!(snap.queue_wait.count, 1);
        assert_eq!(snap.build.count, 1);
        let fi = obs::family_index("kmeans").unwrap();
        assert_eq!(snap.run[fi].count, 1);
        assert_eq!(snap.e2e[fi].count, 1);
        assert_eq!(snap.stats[fi].nodes_visited, r.stats.nodes_visited);
        // Merging with an empty snapshot is the identity.
        assert_eq!(snap.merge(&ObsSnapshot::default()), snap);
    }

    #[test]
    fn dataset_cache_shared_across_jobs() {
        let coord = Coordinator::new(2, 8);
        // Two tree jobs on the same dataset: the second must not pay the
        // tree build again, so its distance count is much lower.
        let a = coord.submit(km(3, true)).unwrap();
        let JobState::Done(ra) = coord.wait(a) else { panic!() };
        let b = coord.submit(km(3, true)).unwrap();
        let JobState::Done(rb) = coord.wait(b) else { panic!() };
        assert!(
            rb.dists <= ra.dists,
            "second job re-paid the build: {} vs {}",
            rb.dists,
            ra.dists
        );
    }

    #[test]
    fn deadline_fails_a_running_job_with_partial_stats() {
        // Slow leaves make the traversal take seconds; a 10ms deadline
        // fires mid-flight and the checkpoint unwind carries partials.
        let _drill = ScopedFaults::install(FaultPlan {
            seed: 1,
            slow_leaf: Some(Duration::from_millis(5)),
            ..Default::default()
        });
        let coord = Coordinator::new(1, 8);
        let mut spec = km(3, true);
        spec.deadline_ms = Some(10);
        let id = coord.submit(spec).unwrap();
        let JobState::Failed(f) = coord.wait(id) else {
            panic!("deadline never fired")
        };
        assert_eq!(f, "deadline");
        assert_eq!(f.kind, FailureKind::Deadline);
        assert!(f.stats.is_some(), "running deadline must attach partial stats");
        let m = coord.metrics();
        assert_eq!(m.deadline_exceeded, 1);
        assert_eq!(m.completed + m.failed, m.submitted);
    }

    #[test]
    fn cancel_stops_a_running_job() {
        let _drill = ScopedFaults::install(FaultPlan {
            seed: 2,
            slow_leaf: Some(Duration::from_millis(5)),
            ..Default::default()
        });
        let coord = Coordinator::new(1, 8);
        let id = coord.submit(km(3, true)).unwrap();
        // Wait until the job is claimed, then cancel it mid-run.
        while !matches!(coord.state(id), Some(JobState::Running)) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(coord.cancel(id), "running job must be cancellable");
        let JobState::Failed(f) = coord.wait(id) else {
            panic!("cancelled job completed")
        };
        assert_eq!(f, "cancelled");
        assert_eq!(f.kind, FailureKind::Cancelled);
        let m = coord.metrics();
        assert_eq!(m.cancelled_running, 1);
        assert_eq!(m.cancelled, 0, "queued-cancel counter must not move");
        assert_eq!(m.completed + m.failed, m.submitted);
        // A terminal job is no longer cancellable.
        assert!(!coord.cancel(id));
    }

    #[test]
    fn breaker_quarantines_after_consecutive_panics() {
        // Every job panics under the drill; K=2 opens the breaker.
        let _drill = ScopedFaults::install(FaultPlan {
            seed: 3,
            panic_ppm: 1_000_000,
            ..Default::default()
        });
        let coord = Coordinator::with_config(
            1,
            16,
            None,
            CoordinatorConfig { breaker_k: 2, breaker_cooldown: Duration::from_millis(100) },
        );
        for expect_kind in [FailureKind::Panic, FailureKind::Panic, FailureKind::BreakerOpen] {
            let id = coord.submit(km(3, true)).unwrap();
            let JobState::Failed(f) = coord.wait(id) else { panic!("job succeeded") };
            assert_eq!(f.kind, expect_kind, "{}", f.error);
        }
        assert_eq!(coord.metrics().breaker_open, 1);
        // Faults off + cooldown elapsed: the half-open probe succeeds
        // and closes the breaker for good.
        crate::faults::install(None);
        std::thread::sleep(Duration::from_millis(150));
        for _ in 0..2 {
            let id = coord.submit(km(3, true)).unwrap();
            match coord.wait(id) {
                JobState::Done(_) => {}
                other => panic!("breaker failed to close: {other:?}"),
            }
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 2);
        assert_eq!(m.completed + m.failed, m.submitted);
    }

    #[test]
    fn drain_finishes_in_flight_work_and_rejects_new_submits() {
        let coord = Coordinator::new(2, 16);
        let ids: Vec<JobId> = (0..4).map(|_| coord.submit(km(3, true)).unwrap()).collect();
        assert!(coord.drain(Duration::from_secs(60)), "drain timed out");
        assert!(matches!(coord.submit(km(3, true)), Err(SubmitError::ShuttingDown)));
        for id in ids {
            assert!(matches!(coord.wait(id), JobState::Done(_)));
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 4);
        assert_eq!(m.completed + m.failed, m.submitted);
    }
}
