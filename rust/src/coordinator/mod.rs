//! The batch-analytics coordinator: the service layer that makes the
//! paper's algorithms consumable as *jobs* over named datasets.
//!
//! Clients submit [`JobSpec`]s — an [`engine::Query`] (any of the eight
//! algorithm families, naive or tree-accelerated) against a dataset. A
//! fixed worker pool executes them through the [`engine::Index`] facade.
//! Design points:
//!
//! * **Dataset cache** — generating a Table-1 dataset and building its
//!   metric tree is expensive; both are cached and shared (Arc) across
//!   jobs keyed by (dataset, rmin), then assembled into a per-job
//!   [`engine::Index`] via [`engine::Index::from_parts`].
//! * **Per-dataset serialization** — a dataset's distance counter is
//!   shared state; the coordinator runs at most one job per dataset at a
//!   time so each job's distance accounting is exact. Different datasets
//!   run fully in parallel.
//! * **Backpressure** — the queue is bounded; `submit` fails fast with
//!   [`SubmitError::QueueFull`] instead of buffering unboundedly.
//! * **No lost or duplicated jobs** — every accepted job reaches exactly
//!   one terminal state ([`JobState::Done`] / [`JobState::Failed`]);
//!   verified by property tests.
//! * **Worker-pool concurrency first** — jobs run serial internally by
//!   default (the pool is the parallelism); `PALLAS_THREADS` opts a
//!   deployment into intra-job parallelism via [`crate::parallel`],
//!   which changes wall-clock only, never results or distance counts.
//! * **Cancellation** — [`Coordinator::cancel`] abandons a job that is
//!   still queued (it moves to `Failed("cancelled")`); a job that has
//!   started running is never interrupted, so results stay exact.
//!
//! One `Coordinator` is one *shard*: a self-contained queue + worker
//! pool + dataset/tree cache. [`shard::ShardedCoordinator`] composes N
//! of them behind a consistent-hash router on the dataset cache key so
//! different datasets never contend on a lock, a queue, or a cache
//! mutex — see the [`shard`] module docs.

pub mod server;
pub mod shard;

pub use shard::ShardedCoordinator;

use crate::dataset::DatasetSpec;
use crate::engine::{self, IndexBuilder, Query, QueryResult};
use crate::metrics::Space;
use crate::obs::{self, Histogram, HistogramSnapshot, QueryStats};
use crate::parallel::{Executor, Parallelism};
use crate::runtime::BatchDistanceEngine;
use crate::tree::middle_out::{self, MiddleOutConfig};
use crate::tree::MetricTree;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A complete job description: which dataset, which query, which leaf
/// threshold for the cached tree. What to run — including the
/// naive-vs-tree switch — lives inside the [`Query`].
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub dataset: DatasetSpec,
    pub query: Query,
    /// Leaf threshold for the cached tree.
    pub rmin: usize,
}

impl JobSpec {
    /// The cache key this job routes on: `(dataset, rmin)`. The sharded
    /// router ([`shard::ShardedCoordinator`]) hashes exactly this
    /// string, so every job stream for one `(dataset, rmin)` pair lands
    /// on one shard, where it shares that shard's cached `Space` and
    /// tree and serializes on that shard's per-dataset run lock (exact
    /// per-job distance accounting). Jobs with different keys never
    /// contend across shards.
    ///
    /// Tradeoff (deliberate): because `rmin` is part of the key, one
    /// dataset queried at two `rmin` values may land on two shards,
    /// each generating and holding its own `Space` copy. That buys
    /// cross-`rmin` parallelism — the two streams stop serializing on
    /// one run lock — at the cost of duplicated generation time and
    /// resident memory per extra `rmin`. Deployments that pin one
    /// `rmin` per dataset (the common case; the CLI default is 30)
    /// never pay it. Dataset generation counts no distances, so the
    /// duplication never changes any job's distance accounting.
    pub fn route_key(&self) -> String {
        format!("{}#rmin={}", dataset_key(&self.dataset), self.rmin)
    }
}

/// Job identifier.
pub type JobId = u64;

/// Terminal result of a job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: JobId,
    pub output: QueryResult,
    /// Distance computations attributed to this job (tree build included
    /// on first use of a dataset/rmin pair).
    pub dists: u64,
    /// Deterministic traversal counters for exactly this job's query
    /// (nodes visited, prunes by rule, leaf rows, ...). Bit-identical
    /// across thread and shard counts — see `tests/obs_equivalence.rs`.
    pub stats: QueryStats,
    pub wall_ms: f64,
}

/// Lifecycle of a job.
#[derive(Clone, Debug)]
pub enum JobState {
    Queued,
    Running,
    Done(JobResult),
    Failed(String),
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done(_) | JobState::Failed(_))
    }
}

/// Submission failures.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
}

/// Aggregate counters (monotonic).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Jobs cancelled while still queued. Each is also counted under
    /// `failed` (its terminal state is `Failed("cancelled")`), so
    /// `completed + failed == submitted` keeps holding.
    pub cancelled: AtomicU64,
    pub total_dists: AtomicU64,
}

/// Point-in-time metric values.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    /// Subset of `failed`: jobs cancelled while still queued.
    pub cancelled: u64,
    pub total_dists: u64,
}

impl MetricsSnapshot {
    /// Field-wise sum — the aggregate view over coordinator shards.
    pub fn merge(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted + other.submitted,
            rejected: self.rejected + other.rejected,
            completed: self.completed + other.completed,
            failed: self.failed + other.failed,
            cancelled: self.cancelled + other.cancelled,
            total_dists: self.total_dists + other.total_dists,
        }
    }
}

/// Serving-edge observability owned by one coordinator shard: latency
/// histograms (µs, √2 buckets) plus per-family lifetime [`QueryStats`]
/// aggregates. The coordinator and server are the only layers allowed
/// to read the clock (pallas-lint D2 keeps `std::time` out of the
/// algorithm/tree/metrics/engine dirs), so wall-time lives here while
/// the in-algorithm counters stay deterministic.
struct EdgeObs {
    /// Submit → claimed by a worker.
    queue_wait: Histogram,
    /// Index assembly (includes the cached tree's first build).
    build: Histogram,
    /// `Index::run_traced` alone, per query family.
    run: [Histogram; obs::FAMILIES.len()],
    /// Submit → terminal state, per query family.
    e2e: [Histogram; obs::FAMILIES.len()],
    /// Lifetime sum of per-job [`QueryStats`], per query family.
    stats: Mutex<Vec<QueryStats>>,
}

impl EdgeObs {
    fn new() -> EdgeObs {
        EdgeObs {
            queue_wait: Histogram::new(),
            build: Histogram::new(),
            run: std::array::from_fn(|_| Histogram::new()),
            e2e: std::array::from_fn(|_| Histogram::new()),
            stats: Mutex::new(vec![QueryStats::default(); obs::FAMILIES.len()]),
        }
    }

    fn snapshot(&self) -> ObsSnapshot {
        ObsSnapshot {
            queue_wait: self.queue_wait.snapshot(),
            build: self.build.snapshot(),
            run: self.run.iter().map(Histogram::snapshot).collect(),
            e2e: self.e2e.iter().map(Histogram::snapshot).collect(),
            stats: self.stats.lock().unwrap().clone(),
        }
    }
}

/// Point-in-time serving-edge observability values. Like
/// [`MetricsSnapshot`], snapshots merge field-wise across shards; the
/// merge is order-invariant (histogram buckets and counter sums are
/// commutative), so any fold order over shards yields the same
/// aggregate.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ObsSnapshot {
    pub queue_wait: HistogramSnapshot,
    pub build: HistogramSnapshot,
    /// Indexed by [`obs::FAMILIES`].
    pub run: Vec<HistogramSnapshot>,
    /// Indexed by [`obs::FAMILIES`].
    pub e2e: Vec<HistogramSnapshot>,
    /// Indexed by [`obs::FAMILIES`].
    pub stats: Vec<QueryStats>,
}

fn merge_hist_vec(a: &[HistogramSnapshot], b: &[HistogramSnapshot]) -> Vec<HistogramSnapshot> {
    let n = a.len().max(b.len());
    let zero = HistogramSnapshot::default();
    (0..n)
        .map(|i| a.get(i).unwrap_or(&zero).merge(b.get(i).unwrap_or(&zero)))
        .collect()
}

impl ObsSnapshot {
    /// Field-wise sum — the aggregate view over coordinator shards.
    pub fn merge(&self, other: &ObsSnapshot) -> ObsSnapshot {
        let n = self.stats.len().max(other.stats.len());
        let mut stats = vec![QueryStats::default(); n];
        for (i, s) in stats.iter_mut().enumerate() {
            if let Some(a) = self.stats.get(i) {
                s.accumulate(a);
            }
            if let Some(b) = other.stats.get(i) {
                s.accumulate(b);
            }
        }
        ObsSnapshot {
            queue_wait: self.queue_wait.merge(&other.queue_wait),
            build: self.build.merge(&other.build),
            run: merge_hist_vec(&self.run, &other.run),
            e2e: merge_hist_vec(&self.e2e, &other.e2e),
            stats,
        }
    }
}

/// Saturating `Duration` → whole microseconds for histogram recording.
fn micros(d: Duration) -> u64 {
    u64::try_from(d.as_micros()).unwrap_or(u64::MAX)
}

struct CachedDataset {
    space: Arc<Space>,
    /// Trees per rmin (built lazily under the dataset lock).
    trees: Mutex<HashMap<usize, Arc<MetricTree>>>,
    /// Serializes jobs touching this dataset (exact distance accounting).
    run_lock: Mutex<()>,
}

struct Inner {
    /// Each entry carries its submit instant so the claiming worker can
    /// record queue-wait and end-to-end latency.
    queue: Mutex<VecDeque<(JobId, JobSpec, Instant)>>,
    queue_cv: Condvar,
    capacity: usize,
    states: Mutex<HashMap<JobId, JobState>>,
    state_cv: Condvar,
    datasets: Mutex<HashMap<String, Arc<CachedDataset>>>,
    metrics: Metrics,
    obs: EdgeObs,
    shutdown: AtomicBool,
    engine: Option<Arc<BatchDistanceEngine>>,
    /// Intra-job worker budget. The pool's own workers are the primary
    /// source of concurrency, so jobs default to serial execution —
    /// `PALLAS_THREADS` overrides for single-tenant deployments where
    /// one big job should use the whole machine. Results and distance
    /// accounting are identical either way.
    parallelism: Parallelism,
    next_id: AtomicU64,
}

/// The coordinator service.
pub struct Coordinator {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start `n_workers` workers with a queue bound of `capacity`.
    pub fn new(n_workers: usize, capacity: usize) -> Coordinator {
        Self::with_engine(n_workers, capacity, None)
    }

    /// Start with an optional XLA batch engine shared by all jobs.
    pub fn with_engine(
        n_workers: usize,
        capacity: usize,
        engine: Option<Arc<BatchDistanceEngine>>,
    ) -> Coordinator {
        let parallelism = Parallelism::from_env().unwrap_or(Parallelism::Serial);
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            capacity: capacity.max(1),
            states: Mutex::new(HashMap::new()),
            state_cv: Condvar::new(),
            datasets: Mutex::new(HashMap::new()),
            metrics: Metrics::default(),
            obs: EdgeObs::new(),
            shutdown: AtomicBool::new(false),
            engine,
            parallelism,
            next_id: AtomicU64::new(1),
        });
        let workers = (0..n_workers.max(1))
            .map(|wid| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("coord-worker-{wid}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn worker")
            })
            .collect();
        Coordinator { inner, workers }
    }

    /// Submit a job; fails fast when the queue is at capacity.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        let mut queue = self.inner.queue.lock().unwrap();
        if queue.len() >= self.inner.capacity {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        queue.push_back((id, spec, Instant::now()));
        self.inner
            .states
            .lock()
            .unwrap()
            .insert(id, JobState::Queued);
        self.inner.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.inner.queue_cv.notify_one();
        Ok(id)
    }

    /// Snapshot a job's state.
    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.states.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job reaches a terminal state.
    ///
    /// # Panics
    /// On an unknown job id; untrusted ids (e.g. off the wire) should
    /// go through [`Coordinator::wait_checked`] instead.
    pub fn wait(&self, id: JobId) -> JobState {
        self.wait_checked(id)
            .unwrap_or_else(|| panic!("unknown job id {id}"))
    }

    /// Non-panicking [`Coordinator::wait`]: `None` for an id this
    /// coordinator has never issued. Sound against check-then-wait
    /// races because job states are never evicted — an id seen once
    /// stays resolvable for the coordinator's lifetime.
    pub fn wait_checked(&self, id: JobId) -> Option<JobState> {
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                Some(s) if s.is_terminal() => return Some(s.clone()),
                Some(_) => {
                    states = self.inner.state_cv.wait(states).unwrap();
                }
                None => return None,
            }
        }
    }

    /// Current queue depth.
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let m = &self.inner.metrics;
        MetricsSnapshot {
            submitted: m.submitted.load(Ordering::Relaxed),
            rejected: m.rejected.load(Ordering::Relaxed),
            completed: m.completed.load(Ordering::Relaxed),
            failed: m.failed.load(Ordering::Relaxed),
            cancelled: m.cancelled.load(Ordering::Relaxed),
            total_dists: m.total_dists.load(Ordering::Relaxed),
        }
    }

    /// Snapshot the serving-edge observability state: latency histograms
    /// and per-family lifetime query stats.
    pub fn obs(&self) -> ObsSnapshot {
        self.inner.obs.snapshot()
    }

    /// Cancel a job that is still queued: it is removed from the queue
    /// and moves to [`JobState::Failed`] with message `"cancelled"`
    /// (waiters are woken). Returns `false` — and changes nothing — if
    /// the job has already started running, already finished, or is
    /// unknown: a running job is never interrupted, so its distance
    /// accounting and result stay exact.
    pub fn cancel(&self, id: JobId) -> bool {
        // Holding the queue lock pins the race with worker pop: a job
        // found in the queue here cannot simultaneously be claimed.
        let mut queue = self.inner.queue.lock().unwrap();
        let Some(pos) = queue.iter().position(|(jid, _, _)| *jid == id) else {
            return false;
        };
        queue.remove(pos);
        drop(queue);
        self.inner.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
        set_state(&self.inner, id, JobState::Failed("cancelled".into()));
        true
    }

    /// Drain the queue, stop accepting work, and join the workers.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    // One executor (and persistent worker pool) per coordinator worker:
    // repeated jobs on this worker reuse its parked threads, while
    // concurrent jobs on other workers keep fully independent pools (a
    // single shared pool would serialize every job's parallel passes on
    // the broadcast channel). With the default serial budget this is
    // poolless and free.
    let exec = Executor::new(inner.parallelism);
    loop {
        let job = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if let Some(job) = queue.pop_front() {
                    break Some(job);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = inner.queue_cv.wait(queue).unwrap();
            }
        };
        let Some((id, spec, submitted_at)) = job else { return };
        inner.obs.queue_wait.record(micros(submitted_at.elapsed()));
        set_state(&inner, id, JobState::Running);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(&inner, id, &spec, &exec)
        }));
        match outcome {
            Ok(Ok(result)) => {
                inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
                inner
                    .metrics
                    .total_dists
                    .fetch_add(result.dists, Ordering::Relaxed);
                set_state(&inner, id, JobState::Done(result));
            }
            Ok(Err(msg)) => {
                inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                set_state(&inner, id, JobState::Failed(msg));
            }
            Err(panic) => {
                inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                let msg = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "job panicked".into());
                set_state(&inner, id, JobState::Failed(msg));
            }
        }
        // Submit → terminal, recorded for successes and failures alike.
        if let Some(fi) = obs::family_index(spec.query.kind()) {
            inner.obs.e2e[fi].record(micros(submitted_at.elapsed()));
        }
    }
}

fn set_state(inner: &Inner, id: JobId, state: JobState) {
    inner.states.lock().unwrap().insert(id, state);
    inner.state_cv.notify_all();
}

fn dataset_key(spec: &DatasetSpec) -> String {
    format!("{}@{}@{}", spec.kind.name(), spec.scale, spec.seed)
}

fn get_dataset(inner: &Inner, spec: &DatasetSpec) -> Arc<CachedDataset> {
    let key = dataset_key(spec);
    // Fast path.
    if let Some(ds) = inner.datasets.lock().unwrap().get(&key) {
        return ds.clone();
    }
    // Build outside the map lock (generation can be slow), then insert —
    // first writer wins so concurrent builders converge on one copy.
    let built = Arc::new(CachedDataset {
        space: Arc::new(spec.build()),
        trees: Mutex::new(HashMap::new()),
        run_lock: Mutex::new(()),
    });
    let mut map = inner.datasets.lock().unwrap();
    map.entry(key).or_insert(built).clone()
}

fn get_tree(ds: &CachedDataset, rmin: usize, seed: u64, exec: &Executor) -> Arc<MetricTree> {
    let mut trees = ds.trees.lock().unwrap();
    if let Some(t) = trees.get(&rmin) {
        return t.clone();
    }
    let cfg = MiddleOutConfig { rmin, seed, ..Default::default() };
    let tree = Arc::new(middle_out::build_ex(&ds.space, &cfg, exec));
    trees.insert(rmin, tree.clone());
    tree
}

/// Assemble the per-job [`engine::Index`] view over the cached parts.
/// Tree queries get the cached tree (built under the dataset lock on
/// first use); naive queries get a tree-less index so they never pay
/// for a build. Both reuse the calling worker's executor/pool.
fn get_index(
    inner: &Inner,
    ds: &CachedDataset,
    spec: &JobSpec,
    exec: &Executor,
) -> engine::Index {
    if spec.query.needs_tree() {
        let tree = get_tree(ds, spec.rmin, spec.dataset.seed, exec);
        engine::Index::from_parts(
            Arc::clone(&ds.space),
            tree,
            inner.engine.clone(),
            spec.dataset.seed,
            spec.rmin,
        )
        .with_executor(exec.clone())
    } else {
        // (No .parallelism() call: with_executor supersedes both the
        // budget and the executor, making `exec` the single source of
        // truth for this job's concurrency.)
        IndexBuilder::new(spec.dataset.clone())
            .rmin(spec.rmin)
            .batch_engine(inner.engine.clone())
            .build_on(Arc::clone(&ds.space))
            .with_executor(exec.clone())
    }
}

fn run_job(inner: &Inner, id: JobId, spec: &JobSpec, exec: &Executor) -> Result<JobResult, String> {
    let ds = get_dataset(inner, &spec.dataset);
    // Serialize jobs on this dataset: exact per-job distance accounting.
    // A panicking query (worker catches it below) unwinds while holding
    // this guard and poisons the mutex; the lock protects no invariant —
    // only accounting serialization — so recover rather than letting one
    // bad request permanently fail every later job on the dataset.
    let _guard = ds.run_lock.lock().unwrap_or_else(|e| e.into_inner());
    let start = Instant::now();
    let before = ds.space.dist_count();
    let index = get_index(inner, &ds, spec, exec);
    inner.obs.build.record(micros(start.elapsed()));
    let run_start = Instant::now();
    let (output, stats) = index.run_traced(&spec.query);
    let run_us = micros(run_start.elapsed());
    if let Some(fi) = obs::family_index(spec.query.kind()) {
        inner.obs.run[fi].record(run_us);
        inner.obs.stats.lock().unwrap()[fi].accumulate(&stats);
    }
    let dists = ds.space.dist_count() - before;
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    if obs::trace::enabled() {
        use crate::json::Value;
        obs::trace::span(
            "job",
            &[
                ("id", Value::Num(crate::ids::wire_from_u64(id))),
                ("op", Value::Str(spec.query.kind().into())),
                ("dataset", Value::Str(dataset_key(&spec.dataset))),
                ("dists", Value::Num(crate::ids::wire_from_u64(dists))),
                (
                    "nodes_visited",
                    Value::Num(crate::ids::wire_from_u64(stats.nodes_visited)),
                ),
                (
                    "pruned",
                    Value::Num(crate::ids::wire_from_u64(stats.total_pruned())),
                ),
                ("run_us", Value::Num(crate::ids::wire_from_u64(run_us))),
                ("wall_ms", Value::Num(wall_ms)),
            ],
        );
    }
    Ok(JobResult { id, output, stats, dists, wall_ms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::engine::{
        AllPairsQuery, AnomalyQuery, BallQuery, GaussianEmQuery, KmeansQuery, KnnQuery, KnnTarget,
        MstQuery, XmeansQuery,
    };

    fn tiny(kind: DatasetKind) -> DatasetSpec {
        DatasetSpec::scaled(kind, 0.004) // a few hundred rows
    }

    fn km(k: usize, use_tree: bool) -> JobSpec {
        JobSpec {
            dataset: tiny(DatasetKind::Squiggles),
            query: Query::Kmeans(KmeansQuery { k, iters: 4, use_tree, ..Default::default() }),
            rmin: 16,
        }
    }

    #[test]
    fn runs_one_job() {
        let coord = Coordinator::new(2, 16);
        let id = coord.submit(km(3, true)).unwrap();
        match coord.wait(id) {
            JobState::Done(r) => {
                assert!(r.dists > 0);
                assert!(matches!(r.output, QueryResult::Kmeans { .. }));
            }
            other => panic!("unexpected state {other:?}"),
        }
    }

    #[test]
    fn naive_and_tree_jobs_agree() {
        let coord = Coordinator::new(2, 16);
        let a = coord.submit(km(4, false)).unwrap();
        let b = coord.submit(km(4, true)).unwrap();
        let (ra, rb) = (coord.wait(a), coord.wait(b));
        let (JobState::Done(ra), JobState::Done(rb)) = (ra, rb) else {
            panic!("jobs failed");
        };
        let (
            QueryResult::Kmeans { distortion: da, .. },
            QueryResult::Kmeans { distortion: db, .. },
        ) = (&ra.output, &rb.output)
        else {
            panic!("wrong outputs");
        };
        assert!((da - db).abs() < 1e-6 * (1.0 + da), "{da} vs {db}");
        // And the tree job used fewer distances (cache shares the build).
        assert!(rb.dists < ra.dists * 2, "tree {} naive {}", rb.dists, ra.dists);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, capacity 2, and jobs slow enough to pile up.
        let coord = Coordinator::new(1, 2);
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for _ in 0..12 {
            match coord.submit(km(3, true)) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("{e:?}"),
            }
        }
        assert!(rejected > 0, "queue never filled");
        for id in accepted {
            assert!(coord.wait(id).is_terminal());
        }
        let m = coord.metrics();
        assert_eq!(m.rejected, rejected);
        assert_eq!(m.completed + m.failed, m.submitted);
    }

    #[test]
    fn all_query_families_execute() {
        let coord = Coordinator::new(3, 32);
        let squiggles = tiny(DatasetKind::Squiggles);
        let specs = vec![
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Anomaly(AnomalyQuery { threshold: 5, ..Default::default() }),
                rmin: 16,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
                rmin: 16,
            },
            JobSpec {
                dataset: tiny(DatasetKind::Voronoi),
                query: Query::Mst(MstQuery { use_tree: true }),
                rmin: 16,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Xmeans(XmeansQuery { k_min: 1, k_max: 4 }),
                rmin: 16,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Ball(BallQuery {
                    center: vec![0.0, 0.0],
                    radius: 1.0,
                    use_tree: true,
                }),
                rmin: 16,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::GaussianEm(GaussianEmQuery {
                    k: 2,
                    steps: 2,
                    ..Default::default()
                }),
                rmin: 16,
            },
            JobSpec {
                dataset: squiggles.clone(),
                query: Query::Knn(KnnQuery {
                    target: KnnTarget::Point(0),
                    k: 3,
                    use_tree: true,
                }),
                rmin: 16,
            },
            km(5, true),
        ];
        let ids: Vec<JobId> = specs
            .into_iter()
            .map(|s| coord.submit(s).unwrap())
            .collect();
        for id in ids {
            match coord.wait(id) {
                JobState::Done(_) => {}
                other => panic!("job {id} -> {other:?}"),
            }
        }
    }

    #[test]
    fn failed_job_does_not_wedge_the_dataset() {
        // A query that panics in the dispatcher (wrong-dimension ball
        // center on 2-d squiggles) unwinds while holding the dataset's
        // run lock; later jobs on the same dataset must still succeed.
        let coord = Coordinator::new(1, 8);
        let bad = JobSpec {
            dataset: tiny(DatasetKind::Squiggles),
            query: Query::Ball(BallQuery {
                center: vec![0.0, 0.0, 0.0],
                radius: 1.0,
                use_tree: true,
            }),
            rmin: 16,
        };
        let id = coord.submit(bad).unwrap();
        assert!(matches!(coord.wait(id), JobState::Failed(_)));
        let id = coord.submit(km(3, true)).unwrap();
        match coord.wait(id) {
            JobState::Done(_) => {}
            other => panic!("dataset wedged after failed job: {other:?}"),
        }
    }

    #[test]
    fn shutdown_reports_metrics() {
        let coord = Coordinator::new(2, 8);
        let id = coord.submit(km(3, true)).unwrap();
        coord.wait(id);
        let m = coord.shutdown();
        assert_eq!(m.submitted, 1);
        assert_eq!(m.completed, 1);
        assert!(m.total_dists > 0);
    }

    #[test]
    fn obs_snapshot_populates_after_jobs() {
        let coord = Coordinator::new(2, 16);
        let id = coord.submit(km(3, true)).unwrap();
        let JobState::Done(r) = coord.wait(id) else { panic!("job failed") };
        assert!(r.stats.nodes_visited > 0, "tree kmeans visited no nodes");
        let snap = coord.obs();
        assert_eq!(snap.run.len(), obs::FAMILIES.len());
        assert_eq!(snap.stats.len(), obs::FAMILIES.len());
        assert_eq!(snap.queue_wait.count, 1);
        assert_eq!(snap.build.count, 1);
        let fi = obs::family_index("kmeans").unwrap();
        assert_eq!(snap.run[fi].count, 1);
        assert_eq!(snap.e2e[fi].count, 1);
        assert_eq!(snap.stats[fi].nodes_visited, r.stats.nodes_visited);
        // Merging with an empty snapshot is the identity.
        assert_eq!(snap.merge(&ObsSnapshot::default()), snap);
    }

    #[test]
    fn dataset_cache_shared_across_jobs() {
        let coord = Coordinator::new(2, 8);
        // Two tree jobs on the same dataset: the second must not pay the
        // tree build again, so its distance count is much lower.
        let a = coord.submit(km(3, true)).unwrap();
        let JobState::Done(ra) = coord.wait(a) else { panic!() };
        let b = coord.submit(km(3, true)).unwrap();
        let JobState::Done(rb) = coord.wait(b) else { panic!() };
        assert!(
            rb.dists <= ra.dists,
            "second job re-paid the build: {} vs {}",
            rb.dists,
            ra.dists
        );
    }
}
