//! Line-protocol TCP front-end for the [`ShardedCoordinator`] — the
//! deployable "launcher" surface of the system (vLLM-router-style: a
//! thin, fast network layer over the batch scheduler). With one shard
//! (the default) this is exactly the classic single-coordinator server;
//! with N shards every request routes by the job id's shard tag.
//!
//! Protocol: newline-delimited JSON over TCP. The query portion of a
//! `submit` request is exactly the [`engine::wire`] form of an
//! [`crate::engine::Query`] (flat `"op"` + options), so the protocol
//! maps 1:1 onto the typed engine API — every algorithm family the
//! engine serves is reachable over the wire.
//!
//! ```text
//! → {"cmd":"submit","dataset":"cell","scale":0.01,"op":"kmeans","k":10,
//!    "iters":5,"tree":true}
//! ← {"ok":true,"id":3}
//! → {"cmd":"wait","id":3}
//! ← {"ok":true,"id":3,"state":"done","dists":12345,
//!    "output":{"kind":"kmeans","distortion":1.23e4,"iterations":5,...}}
//! → {"cmd":"metrics"}            → {"cmd":"ping"}
//! → {"cmd":"cancel","id":4}      → {"cmd":"shards"}
//! ```
//!
//! Ops beyond `ping`/`submit`/`state`/`wait`:
//!
//! * **`cancel`** — `{"cmd":"cancel","id":N}` abandons a job that is
//!   still queued: `{"ok":true,"id":N,"cancelled":true}`, and the job's
//!   terminal state becomes `failed` with error `"cancelled"`. Once the
//!   job is running (or finished, or unknown) the request is a no-op
//!   and the response is `{"ok":false,...}` — a started job always runs
//!   to completion so its accounting stays exact.
//! * **`metrics`** — aggregate counters plus queue depth: `queue_len`
//!   is the total across shards and `shard_queue_lens` the per-shard
//!   depths (index = shard).
//! * **`shards`** — introspection: `{"ok":true,"shards":N,"per_shard":
//!   [{"shard":0,"queue_len":..,"submitted":..,"completed":..,
//!   "failed":..,"rejected":..,"cancelled":..,"total_dists":..},...]}`.
//! * **`stats`** — the serving-edge observability snapshot, merged
//!   across shards: queue-wait/build latency histogram summaries, and
//!   per-family run/e2e latency plus lifetime traversal counters
//!   (`{"families":{"kmeans":{"run":...,"e2e":...,"stats":...},...}}`).
//!   The `"text"` field carries the same data as a Prometheus text
//!   exposition (`pallas_queue_wait_us_bucket{le=...}` ...), ready to
//!   proxy to a scraper.
//!
//! One thread per connection (std-only environment; connections are few
//! and long-lived — the heavy concurrency lives in the coordinator's
//! worker pool, not here).
//!
//! Note: `wait`/`state` responses carry the *full* result payload
//! (pairs, edges, centroids, ...) so the wire maps losslessly onto
//! [`crate::engine::QueryResult`]. An allpairs query with a generous
//! tau on a big dataset can make that line large; clients wanting
//! summaries only should read the derived `n_*` fields and ignore the
//! payload arrays.

use super::{JobSpec, JobState, MetricsSnapshot, ObsSnapshot, ShardedCoordinator};
use crate::dataset::{DatasetKind, DatasetSpec};
use crate::engine::wire;
use crate::ids;
use crate::json::{self, Value};
use crate::obs::{
    self,
    hist::{prometheus_counter, prometheus_histogram},
    HistogramSnapshot,
};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running server handle; dropping it stops accepting new connections.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind on `addr` ("127.0.0.1:0" for an ephemeral test port) and serve
    /// `coordinator` until the handle is dropped.
    pub fn start(addr: &str, coordinator: Arc<ShardedCoordinator>) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("coord-server-accept".into())
            .spawn(move || {
                // Nonblocking accept loop so `stop` is honored promptly.
                // Without nonblocking mode `stop` cannot be polled; give
                // up on serving rather than take the process down.
                if listener.set_nonblocking(true).is_err() {
                    return;
                }
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let coord = Arc::clone(&coordinator);
                            std::thread::spawn(move || {
                                let _ = handle_connection(stream, coord);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(stream: TcpStream, coord: Arc<ShardedCoordinator>) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_request(&line, &coord) {
            Ok(v) => v,
            Err(msg) => err_obj(&msg),
        };
        writer.write_all(json::write(&response).as_bytes())?;
        writer.write_all(b"\n")?;
    }
    let _ = peer;
    Ok(())
}

fn err_obj(msg: &str) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Value::Bool(false));
    m.insert("error".into(), Value::Str(msg.into()));
    Value::Obj(m)
}

fn ok_obj(fields: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Value::Bool(true));
    for (k, v) in fields {
        m.insert(k.into(), v);
    }
    Value::Obj(m)
}

fn handle_request(line: &str, coord: &ShardedCoordinator) -> Result<Value, String> {
    let req = json::parse(line).map_err(|e| format!("bad JSON: {e}"))?;
    let cmd = req
        .get("cmd")
        .and_then(Value::as_str)
        .ok_or("missing \"cmd\"")?;
    match cmd {
        "ping" => Ok(ok_obj(vec![("pong", Value::Bool(true))])),
        "metrics" => {
            let m = coord.metrics();
            // One queue-lock pass: the reported total is the sum of the
            // reported per-shard depths, so a monitoring client can
            // cross-check them within a single response.
            let lens = coord.shard_queue_lens();
            let total: usize = lens.iter().sum();
            let per_shard: Vec<Value> = lens
                .into_iter()
                .map(|q| Value::Num(ids::wire_from_usize(q)))
                .collect();
            Ok(ok_obj(vec![
                ("submitted", Value::Num(ids::wire_from_u64(m.submitted))),
                ("completed", Value::Num(ids::wire_from_u64(m.completed))),
                ("failed", Value::Num(ids::wire_from_u64(m.failed))),
                ("rejected", Value::Num(ids::wire_from_u64(m.rejected))),
                ("cancelled", Value::Num(ids::wire_from_u64(m.cancelled))),
                ("total_dists", Value::Num(ids::wire_from_u64(m.total_dists))),
                ("queue_len", Value::Num(ids::wire_from_usize(total))),
                ("shard_queue_lens", Value::Arr(per_shard)),
            ]))
        }
        "shards" => {
            let lens = coord.shard_queue_lens();
            let per_shard: Vec<Value> = coord
                .shard_metrics()
                .into_iter()
                .zip(lens)
                .enumerate()
                .map(|(shard, (m, queue_len))| shard_obj(shard, &m, queue_len))
                .collect();
            Ok(ok_obj(vec![
                ("shards", Value::Num(ids::wire_from_usize(coord.n_shards()))),
                ("per_shard", Value::Arr(per_shard)),
            ]))
        }
        "stats" => {
            let o = coord.obs();
            let m = coord.metrics();
            let mut families = BTreeMap::new();
            for (i, name) in obs::FAMILIES.iter().enumerate() {
                let mut fm = BTreeMap::new();
                fm.insert("run".into(), hist_obj(&o.run[i]));
                fm.insert("e2e".into(), hist_obj(&o.e2e[i]));
                fm.insert("stats".into(), wire::stats_to_json(&o.stats[i]));
                families.insert((*name).to_string(), Value::Obj(fm));
            }
            Ok(ok_obj(vec![
                ("queue_wait", hist_obj(&o.queue_wait)),
                ("build", hist_obj(&o.build)),
                ("families", Value::Obj(families)),
                ("text", Value::Str(prometheus_text(&m, &o))),
            ]))
        }
        "submit" => {
            let spec = parse_spec(&req)?;
            match coord.submit(spec) {
                Ok(id) => Ok(ok_obj(vec![("id", Value::Num(ids::wire_from_u64(id)))])),
                Err(e) => Err(format!("{e:?}")),
            }
        }
        "cancel" => {
            // Checked id parse: a raw `as u64` would turn garbage like
            // -1.5 into 0 and silently alias a real job.
            let raw = req
                .get("id")
                .and_then(Value::as_f64)
                .ok_or("missing \"id\"")?;
            let id = ids::wire_u64(raw, "id")?;
            if coord.cancel(id) {
                Ok(ok_obj(vec![
                    ("id", Value::Num(ids::wire_from_u64(id))),
                    ("cancelled", Value::Bool(true)),
                ]))
            } else {
                Err(format!(
                    "job {id} is not queued (already running, finished, or unknown)"
                ))
            }
        }
        "state" | "wait" => {
            let raw = req
                .get("id")
                .and_then(Value::as_f64)
                .ok_or("missing \"id\"")?;
            let id = ids::wire_u64(raw, "id")?;
            let state = if cmd == "wait" {
                coord.wait_checked(id)
            } else {
                coord.state(id)
            };
            let state = state.ok_or(format!("unknown job {id}"))?;
            Ok(state_obj(id, &state))
        }
        other => Err(format!("unknown cmd {other:?}")),
    }
}

/// Summary view of a latency histogram for the JSON side of `stats`
/// (count/sum/mean plus p50/p99 upper bounds); the full bucket series
/// lives in the Prometheus text exposition.
fn hist_obj(h: &HistogramSnapshot) -> Value {
    let quantile = |q: f64| match h.quantile_upper_bound(q) {
        Some(b) => Value::Num(ids::wire_from_u64(b)),
        None => Value::Null,
    };
    let mut m = BTreeMap::new();
    m.insert("count".into(), Value::Num(ids::wire_from_u64(h.count)));
    m.insert("sum_micros".into(), Value::Num(ids::wire_from_u64(h.sum_micros)));
    m.insert("mean_us".into(), Value::Num(h.mean_micros()));
    m.insert("p50_us".into(), quantile(0.5));
    m.insert("p99_us".into(), quantile(0.99));
    Value::Obj(m)
}

/// Prometheus text exposition of the merged snapshot: job counters,
/// edge latency histograms, and per-family traversal counters.
/// Families with no recorded jobs are omitted to keep the page small.
fn prometheus_text(m: &MetricsSnapshot, o: &ObsSnapshot) -> String {
    let mut out = String::new();
    prometheus_counter(&mut out, "pallas_jobs_submitted_total", "", m.submitted);
    prometheus_counter(&mut out, "pallas_jobs_completed_total", "", m.completed);
    prometheus_counter(&mut out, "pallas_jobs_failed_total", "", m.failed);
    prometheus_counter(&mut out, "pallas_jobs_rejected_total", "", m.rejected);
    prometheus_counter(&mut out, "pallas_jobs_cancelled_total", "", m.cancelled);
    prometheus_counter(&mut out, "pallas_dists_total", "", m.total_dists);
    prometheus_histogram(&mut out, "pallas_queue_wait_us", "", &o.queue_wait);
    prometheus_histogram(&mut out, "pallas_build_us", "", &o.build);
    for (i, name) in obs::FAMILIES.iter().enumerate() {
        if o.run[i].count == 0 && o.e2e[i].count == 0 {
            continue;
        }
        let label = format!("family=\"{name}\"");
        prometheus_histogram(&mut out, "pallas_run_us", &label, &o.run[i]);
        prometheus_histogram(&mut out, "pallas_e2e_us", &label, &o.e2e[i]);
        let s = &o.stats[i];
        prometheus_counter(&mut out, "pallas_nodes_visited_total", &label, s.nodes_visited);
        prometheus_counter(&mut out, "pallas_leaf_rows_total", &label, s.leaf_rows);
        for rule in obs::PruneRule::ALL {
            let pruned = s.pruned_by(rule);
            if pruned > 0 {
                let rule_label = format!("family=\"{name}\",rule=\"{}\"", rule.name());
                prometheus_counter(&mut out, "pallas_pruned_total", &rule_label, pruned);
            }
        }
    }
    out
}

fn shard_obj(shard: usize, m: &MetricsSnapshot, queue_len: usize) -> Value {
    let mut obj = BTreeMap::new();
    obj.insert("shard".into(), Value::Num(ids::wire_from_usize(shard)));
    obj.insert("queue_len".into(), Value::Num(ids::wire_from_usize(queue_len)));
    obj.insert("submitted".into(), Value::Num(ids::wire_from_u64(m.submitted)));
    obj.insert("completed".into(), Value::Num(ids::wire_from_u64(m.completed)));
    obj.insert("failed".into(), Value::Num(ids::wire_from_u64(m.failed)));
    obj.insert("rejected".into(), Value::Num(ids::wire_from_u64(m.rejected)));
    obj.insert("cancelled".into(), Value::Num(ids::wire_from_u64(m.cancelled)));
    obj.insert("total_dists".into(), Value::Num(ids::wire_from_u64(m.total_dists)));
    Value::Obj(obj)
}

fn parse_spec(req: &Value) -> Result<JobSpec, String> {
    let dataset_name = req
        .get("dataset")
        .and_then(Value::as_str)
        .ok_or("missing \"dataset\"")?;
    let kind = DatasetKind::parse(dataset_name)
        .ok_or(format!("unknown dataset {dataset_name:?}"))?;
    let scale = req.get("scale").and_then(Value::as_f64).unwrap_or(0.01);
    let seed = match req.get("seed").and_then(Value::as_f64) {
        Some(raw) => ids::wire_u64(raw, "seed")?,
        None => 20130,
    };
    let dataset = DatasetSpec { kind, scale, seed };
    // The rest of the request *is* the wire form of an engine query.
    let query = wire::query_from_json(req)?;
    let rmin = match req.get("rmin").and_then(Value::as_f64) {
        Some(raw) => ids::wire_usize(raw, "rmin")?,
        None => 30,
    };
    Ok(JobSpec { dataset, query, rmin })
}

fn state_obj(id: u64, state: &JobState) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![("id", Value::Num(ids::wire_from_u64(id)))];
    match state {
        JobState::Queued => fields.push(("state", Value::Str("queued".into()))),
        JobState::Running => fields.push(("state", Value::Str("running".into()))),
        JobState::Failed(e) => {
            fields.push(("state", Value::Str("failed".into())));
            fields.push(("error", Value::Str(e.clone())));
        }
        JobState::Done(r) => {
            fields.push(("state", Value::Str("done".into())));
            fields.push(("dists", Value::Num(ids::wire_from_u64(r.dists))));
            fields.push(("wall_ms", Value::Num(r.wall_ms)));
            fields.push(("stats", wire::stats_to_json(&r.stats)));
            fields.push(("output", wire::result_to_json(&r.output)));
        }
    }
    ok_obj(fields)
}

/// Minimal blocking client (used by tests and the CLI's `client` mode).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON request line and read one JSON response line.
    pub fn call(&mut self, request: &Value) -> Result<Value, String> {
        self.writer
            .write_all(json::write(request).as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .map_err(|e| format!("send: {e}"))?;
        let mut line = String::new();
        self.reader
            .read_line(&mut line)
            .map_err(|e| format!("recv: {e}"))?;
        json::parse(&line).map_err(|e| format!("bad response: {e}"))
    }

    /// Convenience: build a request object from key/value pairs.
    pub fn request(fields: Vec<(&str, Value)>) -> Value {
        let mut m = BTreeMap::new();
        for (k, v) in fields {
            m.insert(k.to_string(), v);
        }
        Value::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard;

    /// `PALLAS_SHARDS`-aware server (1 shard by default), so the CI
    /// `PALLAS_SHARDS=4` pass drives this whole suite sharded.
    fn start() -> (Server, Arc<ShardedCoordinator>) {
        let coord = Arc::new(ShardedCoordinator::new(shard::default_shards().unwrap(), 2, 16));
        let server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        (server, coord)
    }

    #[test]
    fn ping_pong() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![("cmd", Value::Str("ping".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(resp.get("pong"), Some(&Value::Bool(true)));
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.003)),
                ("op", Value::Str("kmeans".into())),
                ("k", Value::Num(3.0)),
                ("iters", Value::Num(2.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let id = resp.get("id").unwrap().as_f64().unwrap();
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        assert_eq!(done.get("state").unwrap().as_str(), Some("done"));
        let output = done.get("output").unwrap();
        assert_eq!(output.get("kind").unwrap().as_str(), Some("kmeans"));
        assert!(output.get("distortion").unwrap().as_f64().unwrap() > 0.0);
        assert!(done.get("dists").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn metrics_reflect_work() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let submit = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("voronoi".into())),
                ("scale", Value::Num(0.002)),
                ("op", Value::Str("mst".into())),
            ]))
            .unwrap();
        let id = submit.get("id").unwrap().as_f64().unwrap();
        client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        let m = client
            .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
            .unwrap();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn stats_op_reports_traversal_and_latency() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let submit = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.003)),
                ("op", Value::Str("kmeans".into())),
                ("k", Value::Num(3.0)),
                ("iters", Value::Num(2.0)),
            ]))
            .unwrap();
        let id = submit.get("id").unwrap().as_f64().unwrap();
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        // Done responses carry the per-job traversal counters.
        let job_stats = done.get("stats").expect("done response has stats");
        assert!(job_stats.get("nodes_visited").unwrap().as_f64().unwrap() > 0.0);

        let stats = client
            .call(&Client::request(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.get("ok"), Some(&Value::Bool(true)), "{stats:?}");
        assert!(stats.get("queue_wait").unwrap().get("count").unwrap().as_f64().unwrap() >= 1.0);
        assert!(stats.get("build").unwrap().get("count").unwrap().as_f64().unwrap() >= 1.0);
        let km = stats.get("families").unwrap().get("kmeans").unwrap();
        assert_eq!(km.get("run").unwrap().get("count").unwrap().as_f64(), Some(1.0));
        assert_eq!(km.get("e2e").unwrap().get("count").unwrap().as_f64(), Some(1.0));
        assert!(km.get("stats").unwrap().get("nodes_visited").unwrap().as_f64().unwrap() > 0.0);
        // Prometheus exposition names the edge histograms and the family.
        let text = stats.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("pallas_queue_wait_us_bucket"), "{text}");
        assert!(text.contains("pallas_run_us_count{family=\"kmeans\"}"), "{text}");
        assert!(text.contains("pallas_nodes_visited_total{family=\"kmeans\"}"), "{text}");
    }

    #[test]
    fn bad_requests_get_errors_not_disconnects() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        for bad in [
            "not json at all",
            r#"{"cmd":"nope"}"#,
            r#"{"cmd":"submit","dataset":"unknown-ds","op":"kmeans"}"#,
            r#"{"cmd":"submit","dataset":"cell"}"#,
            r#"{"cmd":"wait"}"#,
            // Garbage numerics: each of these would alias a real id (or
            // truncate silently) under a raw `as` cast. They must come
            // back as errors, never panics or bogus lookups.
            r#"{"cmd":"wait","id":-1.5}"#,
            r#"{"cmd":"wait","id":0.25}"#,
            r#"{"cmd":"wait","id":1e300}"#,
            r#"{"cmd":"cancel","id":-1}"#,
            r#"{"cmd":"cancel","id":1e300}"#,
            r#"{"cmd":"state","id":9.5}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","seed":-3}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","seed":0.5}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","rmin":-30}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","rmin":1e300}"#,
        ] {
            self_call(&mut client, bad);
        }
        // Connection still alive.
        let resp = client
            .call(&Client::request(vec![("cmd", Value::Str("ping".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
    }

    fn self_call(client: &mut Client, raw: &str) {
        client.writer.write_all(raw.as_bytes()).unwrap();
        client.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        client.reader.read_line(&mut line).unwrap();
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{raw} → {line}");
    }

    #[test]
    fn metrics_surface_queue_depths() {
        let (server, coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let m = client
            .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
            .unwrap();
        assert_eq!(m.get("queue_len").and_then(Value::as_f64), Some(0.0));
        let lens = m.get("shard_queue_lens").and_then(Value::as_arr).unwrap();
        assert_eq!(lens.len(), coord.n_shards());
        assert_eq!(m.get("cancelled").and_then(Value::as_f64), Some(0.0));
    }

    #[test]
    fn shards_op_reports_per_shard_state() {
        let (server, coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![("cmd", Value::Str("shards".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(
            resp.get("shards").and_then(Value::as_f64),
            Some(coord.n_shards() as f64)
        );
        let per = resp.get("per_shard").and_then(Value::as_arr).unwrap();
        assert_eq!(per.len(), coord.n_shards());
        for (i, shard) in per.iter().enumerate() {
            assert_eq!(shard.get("shard").and_then(Value::as_f64), Some(i as f64));
            assert_eq!(shard.get("queue_len").and_then(Value::as_f64), Some(0.0));
            assert!(shard.get("submitted").is_some());
        }
    }

    #[test]
    fn cancel_op_rejects_non_queued_jobs() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        // Unknown job: ok:false, connection stays usable.
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("cancel".into())),
                ("id", Value::Num(999_999.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        // A finished job is not cancellable either.
        let submit = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.002)),
                ("op", Value::Str("mst".into())),
            ]))
            .unwrap();
        let id = submit.get("id").unwrap().as_f64().unwrap();
        client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("cancel".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{resp:?}");
    }

    #[test]
    fn cancel_op_abandons_queued_jobs() {
        // A dedicated 1-worker, 1-shard coordinator: the worker is held
        // busy by an expensive first job, so the second job is reliably
        // still queued when the cancel lands.
        let coord = Arc::new(ShardedCoordinator::new(1, 1, 16));
        let server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let submit = |client: &mut Client, op: &str, scale: f64| -> f64 {
            let resp = client
                .call(&Client::request(vec![
                    ("cmd", Value::Str("submit".into())),
                    ("dataset", Value::Str("cell".into())),
                    ("scale", Value::Num(scale)),
                    ("op", Value::Str(op.into())),
                ]))
                .unwrap();
            resp.get("id").unwrap().as_f64().unwrap()
        };
        let busy = submit(&mut client, "mst", 0.01);
        let doomed = submit(&mut client, "mst", 0.005);
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("cancel".into())),
                ("id", Value::Num(doomed)),
            ]))
            .unwrap();
        // In the (unlikely) event the first job finished before the
        // cancel arrived, the second may already be running — then the
        // cancel correctly reports ok:false. Otherwise the job must
        // land in failed("cancelled").
        if resp.get("ok") == Some(&Value::Bool(true)) {
            assert_eq!(resp.get("cancelled"), Some(&Value::Bool(true)));
            let state = client
                .call(&Client::request(vec![
                    ("cmd", Value::Str("wait".into())),
                    ("id", Value::Num(doomed)),
                ]))
                .unwrap();
            assert_eq!(state.get("state").and_then(Value::as_str), Some("failed"));
            assert_eq!(state.get("error").and_then(Value::as_str), Some("cancelled"));
            let m = client
                .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
                .unwrap();
            assert_eq!(m.get("cancelled").and_then(Value::as_f64), Some(1.0));
        }
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(busy)),
            ]))
            .unwrap();
        assert_eq!(done.get("state").and_then(Value::as_str), Some("done"));
    }

    #[test]
    fn wait_on_unknown_id_is_an_error_not_a_hang() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(123_456.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
    }

    #[test]
    fn concurrent_clients() {
        let (server, _coord) = start();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let resp = c
                        .call(&Client::request(vec![
                            ("cmd", Value::Str("submit".into())),
                            ("dataset", Value::Str("squiggles".into())),
                            ("scale", Value::Num(0.002)),
                            ("seed", Value::Num(i as f64)),
                            ("op", Value::Str("anomaly".into())),
                        ]))
                        .unwrap();
                    let id = resp.get("id").unwrap().as_f64().unwrap();
                    let done = c
                        .call(&Client::request(vec![
                            ("cmd", Value::Str("wait".into())),
                            ("id", Value::Num(id)),
                        ]))
                        .unwrap();
                    assert_eq!(done.get("state").unwrap().as_str(), Some("done"));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
