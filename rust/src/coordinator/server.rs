//! Line-protocol TCP front-end for the [`ShardedCoordinator`] — the
//! deployable "launcher" surface of the system (vLLM-router-style: a
//! thin, fast network layer over the batch scheduler). With one shard
//! (the default) this is exactly the classic single-coordinator server;
//! with N shards every request routes by the job id's shard tag.
//!
//! Protocol: newline-delimited JSON over TCP. The query portion of a
//! `submit` request is exactly the [`engine::wire`] form of an
//! [`crate::engine::Query`] (flat `"op"` + options), so the protocol
//! maps 1:1 onto the typed engine API — every algorithm family the
//! engine serves is reachable over the wire.
//!
//! ```text
//! → {"cmd":"submit","dataset":"cell","scale":0.01,"op":"kmeans","k":10,
//!    "iters":5,"tree":true}
//! ← {"ok":true,"id":3}
//! → {"cmd":"wait","id":3}
//! ← {"ok":true,"id":3,"state":"done","dists":12345,
//!    "output":{"kind":"kmeans","distortion":1.23e4,"iterations":5,...}}
//! → {"cmd":"metrics"}            → {"cmd":"ping"}
//! → {"cmd":"cancel","id":4}      → {"cmd":"shards"}
//! ```
//!
//! Ops beyond `ping`/`submit`/`state`/`wait`:
//!
//! * **`cancel`** — `{"cmd":"cancel","id":N}` abandons a queued *or
//!   running* job: `{"ok":true,"id":N,"cancelled":true}`, and the job's
//!   terminal state becomes `failed` with error `"cancelled"` (a
//!   running job stops at its next traversal checkpoint and its
//!   response carries the partial `stats`). Only a finished or unknown
//!   job answers `{"ok":false,...}` — an affirmative answer is a
//!   promise that the job ends `failed`.
//! * **`drain`** — `{"cmd":"drain"}` stops intake on every shard,
//!   blocks until in-flight and queued work finishes (bounded by
//!   `timeout_ms`, default 60000), and reports
//!   `{"ok":true,"drained":bool,"stragglers":[shard,...]}`. After a
//!   drain, submits fail with `ShuttingDown`; status/metrics ops keep
//!   working so clients can collect results. The serve loop (see
//!   `main.rs`) polls [`Server::draining`] and exits cleanly.
//! * **`metrics`** — aggregate counters plus queue depth: `queue_len`
//!   is the total across shards and `shard_queue_lens` the per-shard
//!   depths (index = shard).
//! * **`shards`** — introspection: `{"ok":true,"shards":N,"per_shard":
//!   [{"shard":0,"queue_len":..,"submitted":..,"completed":..,
//!   "failed":..,"rejected":..,"cancelled":..,"total_dists":..},...]}`.
//! * **`stats`** — the serving-edge observability snapshot, merged
//!   across shards: queue-wait/build latency histogram summaries, and
//!   per-family run/e2e latency plus lifetime traversal counters
//!   (`{"families":{"kmeans":{"run":...,"e2e":...,"stats":...},...}}`).
//!   The `"text"` field carries the same data as a Prometheus text
//!   exposition (`pallas_queue_wait_us_bucket{le=...}` ...), ready to
//!   proxy to a scraper.
//!
//! One thread per connection (std-only environment; connections are few
//! and long-lived — the heavy concurrency lives in the coordinator's
//! worker pool, not here). The edge still defends itself
//! ([`ServerOptions`]): a connection cap (excess accepts get one
//! `{"ok":false,...}` line and are closed, counted in
//! `conns_rejected`), and per-socket read/write timeouts so a leaked or
//! wedged client is reaped instead of pinning a thread forever. The
//! [`Client`] pairs with that via [`Client::call_retry`] — bounded
//! reconnect-and-resend with deterministic backoff, annotating resent
//! requests with `"retry":N` so the server's `retries` counter sees
//! them. Retried requests are resent verbatim, so only use it for
//! idempotent ops or connection-time failures (the
//! [`crate::faults`] drop injector only drops at accept, before any
//! request is read).
//!
//! Note: `wait`/`state` responses carry the *full* result payload
//! (pairs, edges, centroids, ...) so the wire maps losslessly onto
//! [`crate::engine::QueryResult`]. An allpairs query with a generous
//! tau on a big dataset can make that line large; clients wanting
//! summaries only should read the derived `n_*` fields and ignore the
//! payload arrays.

use super::{JobSpec, JobState, MetricsSnapshot, ObsSnapshot, ShardedCoordinator};
use crate::dataset::{DatasetKind, DatasetSpec};
use crate::engine::wire;
use crate::ids;
use crate::json::{self, Value};
use crate::obs::{
    self,
    hist::{prometheus_counter, prometheus_histogram},
    HistogramSnapshot,
};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Edge-protection knobs for [`Server::start_with`].
#[derive(Clone, Copy, Debug)]
pub struct ServerOptions {
    /// Concurrent connection cap; excess accepts get one error line and
    /// are closed (counted in the `conns_rejected` metric).
    pub max_conns: usize,
    /// Per-socket read timeout: an idle connection is reaped after this
    /// long instead of pinning its thread forever. `None` disables.
    pub read_timeout: Option<Duration>,
    /// Per-socket write timeout (a client that stops reading while a
    /// huge result line is in flight cannot wedge the writer).
    pub write_timeout: Option<Duration>,
    /// Default `deadline_ms` applied to submits that carry none (the
    /// `serve --deadline-ms` flag). `None` = no default.
    pub default_deadline_ms: Option<u64>,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            max_conns: 256,
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(30)),
            default_deadline_ms: None,
        }
    }
}

/// State shared between the accept loop, the per-connection handlers,
/// and the [`Server`] handle.
struct Shared {
    coord: Arc<ShardedCoordinator>,
    /// Set by the `drain` op; the serve loop polls it to exit.
    draining: AtomicBool,
    /// Connections turned away at the cap.
    conns_rejected: AtomicU64,
    /// Requests that arrived with a `"retry":N` annotation (client-side
    /// reconnects).
    retries: AtomicU64,
    active_conns: AtomicUsize,
    /// See [`ServerOptions::default_deadline_ms`].
    default_deadline_ms: Option<u64>,
}

/// A running server handle; dropping it stops accepting new connections.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    shared: Arc<Shared>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

/// Decrements the active-connection count however the handler exits.
struct ConnGuard(Arc<Shared>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.active_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Server {
    /// Bind on `addr` ("127.0.0.1:0" for an ephemeral test port) and serve
    /// `coordinator` until the handle is dropped.
    pub fn start(addr: &str, coordinator: Arc<ShardedCoordinator>) -> std::io::Result<Server> {
        Self::start_with(addr, coordinator, ServerOptions::default())
    }

    /// As [`Server::start`], with explicit edge-protection knobs.
    pub fn start_with(
        addr: &str,
        coordinator: Arc<ShardedCoordinator>,
        opts: ServerOptions,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let shared = Arc::new(Shared {
            coord: coordinator,
            draining: AtomicBool::new(false),
            conns_rejected: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
            default_deadline_ms: opts.default_deadline_ms,
        });
        let shared2 = Arc::clone(&shared);
        let accept_thread = std::thread::Builder::new()
            .name("coord-server-accept".into())
            .spawn(move || {
                // Nonblocking accept loop so `stop` is honored promptly.
                // Without nonblocking mode `stop` cannot be polled; give
                // up on serving rather than take the process down.
                if listener.set_nonblocking(true).is_err() {
                    return;
                }
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            // Injected connection drop (drills only):
                            // the client sees a clean close before any
                            // response — exactly what `call_retry`
                            // recovers from.
                            if crate::faults::active() && crate::faults::should_drop_socket() {
                                drop(stream);
                                continue;
                            }
                            let prev = shared2.active_conns.fetch_add(1, Ordering::SeqCst);
                            if prev >= opts.max_conns {
                                shared2.active_conns.fetch_sub(1, Ordering::SeqCst);
                                shared2.conns_rejected.fetch_add(1, Ordering::Relaxed);
                                let _ = stream.write_all(
                                    b"{\"error\":\"server at connection capacity\",\"ok\":false}\n",
                                );
                                continue;
                            }
                            let guard = ConnGuard(Arc::clone(&shared2));
                            let _ = stream.set_read_timeout(opts.read_timeout);
                            let _ = stream.set_write_timeout(opts.write_timeout);
                            let shared = Arc::clone(&shared2);
                            std::thread::spawn(move || {
                                let _guard = guard;
                                let _ = handle_connection(stream, &shared);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, shared, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// `true` once a `drain` op has run: intake is stopped and the
    /// serve loop should finish up and exit.
    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Connections turned away at the connection cap so far.
    pub fn conns_rejected(&self) -> u64 {
        self.shared.conns_rejected.load(Ordering::Relaxed)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_request(&line, shared) {
            Ok(v) => v,
            Err(msg) => err_obj(&msg),
        };
        writer.write_all(json::write(&response).as_bytes())?;
        writer.write_all(b"\n")?;
    }
    let _ = peer;
    Ok(())
}

fn err_obj(msg: &str) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Value::Bool(false));
    m.insert("error".into(), Value::Str(msg.into()));
    Value::Obj(m)
}

fn ok_obj(fields: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Value::Bool(true));
    for (k, v) in fields {
        m.insert(k.into(), v);
    }
    Value::Obj(m)
}

fn handle_request(line: &str, shared: &Shared) -> Result<Value, String> {
    let coord: &ShardedCoordinator = &shared.coord;
    let req = json::parse(line).map_err(|e| format!("bad JSON: {e}"))?;
    // Client-side reconnect annotation (see `Client::call_retry`).
    if req.get("retry").and_then(Value::as_f64).is_some() {
        shared.retries.fetch_add(1, Ordering::Relaxed);
    }
    let cmd = req
        .get("cmd")
        .and_then(Value::as_str)
        .ok_or("missing \"cmd\"")?;
    match cmd {
        "ping" => Ok(ok_obj(vec![("pong", Value::Bool(true))])),
        "metrics" => {
            let m = coord.metrics();
            // One queue-lock pass: the reported total is the sum of the
            // reported per-shard depths, so a monitoring client can
            // cross-check them within a single response.
            let lens = coord.shard_queue_lens();
            let total: usize = lens.iter().sum();
            let per_shard: Vec<Value> = lens
                .into_iter()
                .map(|q| Value::Num(ids::wire_from_usize(q)))
                .collect();
            Ok(ok_obj(vec![
                ("submitted", Value::Num(ids::wire_from_u64(m.submitted))),
                ("completed", Value::Num(ids::wire_from_u64(m.completed))),
                ("failed", Value::Num(ids::wire_from_u64(m.failed))),
                ("rejected", Value::Num(ids::wire_from_u64(m.rejected))),
                ("cancelled", Value::Num(ids::wire_from_u64(m.cancelled))),
                (
                    "cancelled_running",
                    Value::Num(ids::wire_from_u64(m.cancelled_running)),
                ),
                (
                    "deadline_exceeded",
                    Value::Num(ids::wire_from_u64(m.deadline_exceeded)),
                ),
                ("breaker_open", Value::Num(ids::wire_from_u64(m.breaker_open))),
                ("total_dists", Value::Num(ids::wire_from_u64(m.total_dists))),
                ("queue_len", Value::Num(ids::wire_from_usize(total))),
                ("shard_queue_lens", Value::Arr(per_shard)),
                (
                    "conns_rejected",
                    Value::Num(ids::wire_from_u64(shared.conns_rejected.load(Ordering::Relaxed))),
                ),
                (
                    "retries",
                    Value::Num(ids::wire_from_u64(shared.retries.load(Ordering::Relaxed))),
                ),
                ("draining", Value::Bool(shared.draining.load(Ordering::SeqCst))),
            ]))
        }
        "shards" => {
            let lens = coord.shard_queue_lens();
            let per_shard: Vec<Value> = coord
                .shard_metrics()
                .into_iter()
                .zip(lens)
                .enumerate()
                .map(|(shard, (m, queue_len))| shard_obj(shard, &m, queue_len))
                .collect();
            Ok(ok_obj(vec![
                ("shards", Value::Num(ids::wire_from_usize(coord.n_shards()))),
                ("per_shard", Value::Arr(per_shard)),
            ]))
        }
        "stats" => {
            let o = coord.obs();
            let m = coord.metrics();
            let mut families = BTreeMap::new();
            for (i, name) in obs::FAMILIES.iter().enumerate() {
                let mut fm = BTreeMap::new();
                fm.insert("run".into(), hist_obj(&o.run[i]));
                fm.insert("e2e".into(), hist_obj(&o.e2e[i]));
                fm.insert("stats".into(), wire::stats_to_json(&o.stats[i]));
                families.insert((*name).to_string(), Value::Obj(fm));
            }
            Ok(ok_obj(vec![
                ("queue_wait", hist_obj(&o.queue_wait)),
                ("build", hist_obj(&o.build)),
                ("families", Value::Obj(families)),
                ("text", Value::Str(prometheus_text(&m, &o, shared))),
            ]))
        }
        "drain" => {
            // Stop intake everywhere, then block this request until the
            // in-flight and queued work finishes (bounded). Status and
            // metrics ops on other connections keep answering while the
            // drain runs, so clients can watch it progress.
            shared.draining.store(true, Ordering::SeqCst);
            let timeout_ms = match req.get("timeout_ms").and_then(Value::as_f64) {
                Some(raw) => ids::wire_u64(raw, "timeout_ms")?,
                None => 60_000,
            };
            let report = coord.drain(Duration::from_millis(timeout_ms));
            let stragglers: Vec<Value> = report
                .stragglers
                .iter()
                .map(|&s| Value::Num(ids::wire_from_usize(s)))
                .collect();
            Ok(ok_obj(vec![
                ("drained", Value::Bool(report.drained)),
                ("stragglers", Value::Arr(stragglers)),
                (
                    "completed",
                    Value::Num(ids::wire_from_u64(report.metrics.completed)),
                ),
                ("failed", Value::Num(ids::wire_from_u64(report.metrics.failed))),
            ]))
        }
        "submit" => {
            let mut spec = parse_spec(&req)?;
            if spec.deadline_ms.is_none() {
                spec.deadline_ms = shared.default_deadline_ms;
            }
            match coord.submit(spec) {
                Ok(id) => Ok(ok_obj(vec![("id", Value::Num(ids::wire_from_u64(id)))])),
                Err(e) => Err(format!("{e:?}")),
            }
        }
        "cancel" => {
            // Checked id parse: a raw `as u64` would turn garbage like
            // -1.5 into 0 and silently alias a real job.
            let raw = req
                .get("id")
                .and_then(Value::as_f64)
                .ok_or("missing \"id\"")?;
            let id = ids::wire_u64(raw, "id")?;
            if coord.cancel(id) {
                Ok(ok_obj(vec![
                    ("id", Value::Num(ids::wire_from_u64(id))),
                    ("cancelled", Value::Bool(true)),
                ]))
            } else {
                Err(format!("job {id} is not cancellable (finished or unknown)"))
            }
        }
        "state" | "wait" => {
            let raw = req
                .get("id")
                .and_then(Value::as_f64)
                .ok_or("missing \"id\"")?;
            let id = ids::wire_u64(raw, "id")?;
            let state = if cmd == "wait" {
                coord.wait_checked(id)
            } else {
                coord.state(id)
            };
            let state = state.ok_or(format!("unknown job {id}"))?;
            Ok(state_obj(id, &state))
        }
        other => Err(format!("unknown cmd {other:?}")),
    }
}

/// Summary view of a latency histogram for the JSON side of `stats`
/// (count/sum/mean plus p50/p99 upper bounds); the full bucket series
/// lives in the Prometheus text exposition.
fn hist_obj(h: &HistogramSnapshot) -> Value {
    let quantile = |q: f64| match h.quantile_upper_bound(q) {
        Some(b) => Value::Num(ids::wire_from_u64(b)),
        None => Value::Null,
    };
    let mut m = BTreeMap::new();
    m.insert("count".into(), Value::Num(ids::wire_from_u64(h.count)));
    m.insert("sum_micros".into(), Value::Num(ids::wire_from_u64(h.sum_micros)));
    m.insert("mean_us".into(), Value::Num(h.mean_micros()));
    m.insert("p50_us".into(), quantile(0.5));
    m.insert("p99_us".into(), quantile(0.99));
    Value::Obj(m)
}

/// Prometheus text exposition of the merged snapshot: job counters,
/// edge latency histograms, and per-family traversal counters.
/// Families with no recorded jobs are omitted to keep the page small.
fn prometheus_text(m: &MetricsSnapshot, o: &ObsSnapshot, shared: &Shared) -> String {
    let mut out = String::new();
    prometheus_counter(&mut out, "pallas_jobs_submitted_total", "", m.submitted);
    prometheus_counter(&mut out, "pallas_jobs_completed_total", "", m.completed);
    prometheus_counter(&mut out, "pallas_jobs_failed_total", "", m.failed);
    prometheus_counter(&mut out, "pallas_jobs_rejected_total", "", m.rejected);
    prometheus_counter(&mut out, "pallas_jobs_cancelled_total", "", m.cancelled);
    prometheus_counter(
        &mut out,
        "pallas_jobs_cancelled_running_total",
        "",
        m.cancelled_running,
    );
    prometheus_counter(
        &mut out,
        "pallas_jobs_deadline_exceeded_total",
        "",
        m.deadline_exceeded,
    );
    prometheus_counter(&mut out, "pallas_jobs_breaker_open_total", "", m.breaker_open);
    prometheus_counter(
        &mut out,
        "pallas_conns_rejected_total",
        "",
        shared.conns_rejected.load(Ordering::Relaxed),
    );
    prometheus_counter(
        &mut out,
        "pallas_retries_total",
        "",
        shared.retries.load(Ordering::Relaxed),
    );
    prometheus_counter(&mut out, "pallas_dists_total", "", m.total_dists);
    prometheus_histogram(&mut out, "pallas_queue_wait_us", "", &o.queue_wait);
    prometheus_histogram(&mut out, "pallas_build_us", "", &o.build);
    for (i, name) in obs::FAMILIES.iter().enumerate() {
        if o.run[i].count == 0 && o.e2e[i].count == 0 {
            continue;
        }
        let label = format!("family=\"{name}\"");
        prometheus_histogram(&mut out, "pallas_run_us", &label, &o.run[i]);
        prometheus_histogram(&mut out, "pallas_e2e_us", &label, &o.e2e[i]);
        let s = &o.stats[i];
        prometheus_counter(&mut out, "pallas_nodes_visited_total", &label, s.nodes_visited);
        prometheus_counter(&mut out, "pallas_leaf_rows_total", &label, s.leaf_rows);
        for rule in obs::PruneRule::ALL {
            let pruned = s.pruned_by(rule);
            if pruned > 0 {
                let rule_label = format!("family=\"{name}\",rule=\"{}\"", rule.name());
                prometheus_counter(&mut out, "pallas_pruned_total", &rule_label, pruned);
            }
        }
    }
    out
}

fn shard_obj(shard: usize, m: &MetricsSnapshot, queue_len: usize) -> Value {
    let mut obj = BTreeMap::new();
    obj.insert("shard".into(), Value::Num(ids::wire_from_usize(shard)));
    obj.insert("queue_len".into(), Value::Num(ids::wire_from_usize(queue_len)));
    obj.insert("submitted".into(), Value::Num(ids::wire_from_u64(m.submitted)));
    obj.insert("completed".into(), Value::Num(ids::wire_from_u64(m.completed)));
    obj.insert("failed".into(), Value::Num(ids::wire_from_u64(m.failed)));
    obj.insert("rejected".into(), Value::Num(ids::wire_from_u64(m.rejected)));
    obj.insert("cancelled".into(), Value::Num(ids::wire_from_u64(m.cancelled)));
    obj.insert(
        "cancelled_running".into(),
        Value::Num(ids::wire_from_u64(m.cancelled_running)),
    );
    obj.insert(
        "deadline_exceeded".into(),
        Value::Num(ids::wire_from_u64(m.deadline_exceeded)),
    );
    obj.insert(
        "breaker_open".into(),
        Value::Num(ids::wire_from_u64(m.breaker_open)),
    );
    obj.insert("total_dists".into(), Value::Num(ids::wire_from_u64(m.total_dists)));
    Value::Obj(obj)
}

fn parse_spec(req: &Value) -> Result<JobSpec, String> {
    let dataset_name = req
        .get("dataset")
        .and_then(Value::as_str)
        .ok_or("missing \"dataset\"")?;
    let kind = DatasetKind::parse(dataset_name)
        .ok_or(format!("unknown dataset {dataset_name:?}"))?;
    let scale = req.get("scale").and_then(Value::as_f64).unwrap_or(0.01);
    let seed = match req.get("seed").and_then(Value::as_f64) {
        Some(raw) => ids::wire_u64(raw, "seed")?,
        None => 20130,
    };
    let dataset = DatasetSpec { kind, scale, seed };
    // The rest of the request *is* the wire form of an engine query.
    let query = wire::query_from_json(req)?;
    let rmin = match req.get("rmin").and_then(Value::as_f64) {
        Some(raw) => ids::wire_usize(raw, "rmin")?,
        None => 30,
    };
    let deadline_ms = match req.get("deadline_ms").and_then(Value::as_f64) {
        Some(raw) => Some(ids::wire_u64(raw, "deadline_ms")?),
        None => None,
    };
    Ok(JobSpec { dataset, query, rmin, deadline_ms })
}

fn state_obj(id: u64, state: &JobState) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![("id", Value::Num(ids::wire_from_u64(id)))];
    match state {
        JobState::Queued => fields.push(("state", Value::Str("queued".into()))),
        JobState::Running => fields.push(("state", Value::Str("running".into()))),
        JobState::Failed(f) => {
            fields.push(("state", Value::Str("failed".into())));
            fields.push(("error", Value::Str(f.error.clone())));
            // Interrupted jobs (deadline/cancel/panic mid-traversal)
            // carry their partial deterministic counters.
            if let Some(stats) = &f.stats {
                fields.push(("stats", wire::stats_to_json(stats)));
            }
        }
        JobState::Done(r) => {
            fields.push(("state", Value::Str("done".into())));
            fields.push(("dists", Value::Num(ids::wire_from_u64(r.dists))));
            fields.push(("wall_ms", Value::Num(r.wall_ms)));
            fields.push(("stats", wire::stats_to_json(&r.stats)));
            fields.push(("output", wire::result_to_json(&r.output)));
        }
    }
    ok_obj(fields)
}

/// Minimal blocking client (used by tests and the CLI's `client` mode).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    addr: std::net::SocketAddr,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        let addr = stream.peer_addr()?;
        Ok(Client { reader: BufReader::new(stream), writer, addr })
    }

    /// Send one JSON request line and read one JSON response line.
    pub fn call(&mut self, request: &Value) -> Result<Value, String> {
        self.writer
            .write_all(json::write(request).as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .map_err(|e| format!("send: {e}"))?;
        let mut line = String::new();
        self.reader
            .read_line(&mut line)
            .map_err(|e| format!("recv: {e}"))?;
        json::parse(&line).map_err(|e| format!("bad response: {e}"))
    }

    /// [`Client::call`] with bounded reconnect-and-resend: on a
    /// transport failure (dropped connection, reaped idle socket) the
    /// client reconnects after a deterministic backoff (10ms · 2ᵃ,
    /// capped at 500ms — no jitter, this repo replays byte-for-byte)
    /// and resends the request annotated with `"retry":attempt` so the
    /// server's `retries` counter records it. Protocol-level errors
    /// (`ok:false` responses) are *returned*, not retried — the
    /// transport worked. The request is resent verbatim, so use this
    /// for idempotent ops or connection-time failures only.
    pub fn call_retry(&mut self, request: &Value, max_attempts: u32) -> Result<Value, String> {
        let mut last = String::new();
        for attempt in 0..max_attempts.max(1) {
            if attempt > 0 {
                let backoff = Duration::from_millis(
                    10u64.saturating_mul(1 << attempt.min(10)).min(500),
                );
                std::thread::sleep(backoff);
                match Client::connect(self.addr) {
                    Ok(fresh) => *self = fresh,
                    Err(e) => {
                        last = format!("reconnect: {e}");
                        continue;
                    }
                }
            }
            let req = if attempt == 0 {
                request.clone()
            } else if let Value::Obj(m) = request {
                let mut m = m.clone();
                m.insert("retry".into(), Value::Num(f64::from(attempt)));
                Value::Obj(m)
            } else {
                request.clone()
            };
            match self.call(&req) {
                Ok(v) => return Ok(v),
                Err(e) => last = e,
            }
        }
        Err(format!("gave up after {max_attempts} attempts: {last}"))
    }

    /// Convenience: build a request object from key/value pairs.
    pub fn request(fields: Vec<(&str, Value)>) -> Value {
        let mut m = BTreeMap::new();
        for (k, v) in fields {
            m.insert(k.to_string(), v);
        }
        Value::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard;

    /// `PALLAS_SHARDS`-aware server (1 shard by default), so the CI
    /// `PALLAS_SHARDS=4` pass drives this whole suite sharded.
    fn start() -> (Server, Arc<ShardedCoordinator>) {
        let coord = Arc::new(ShardedCoordinator::new(shard::default_shards().unwrap(), 2, 16));
        let server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        (server, coord)
    }

    #[test]
    fn ping_pong() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![("cmd", Value::Str("ping".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(resp.get("pong"), Some(&Value::Bool(true)));
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.003)),
                ("op", Value::Str("kmeans".into())),
                ("k", Value::Num(3.0)),
                ("iters", Value::Num(2.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let id = resp.get("id").unwrap().as_f64().unwrap();
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        assert_eq!(done.get("state").unwrap().as_str(), Some("done"));
        let output = done.get("output").unwrap();
        assert_eq!(output.get("kind").unwrap().as_str(), Some("kmeans"));
        assert!(output.get("distortion").unwrap().as_f64().unwrap() > 0.0);
        assert!(done.get("dists").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn metrics_reflect_work() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let submit = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("voronoi".into())),
                ("scale", Value::Num(0.002)),
                ("op", Value::Str("mst".into())),
            ]))
            .unwrap();
        let id = submit.get("id").unwrap().as_f64().unwrap();
        client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        let m = client
            .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
            .unwrap();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn stats_op_reports_traversal_and_latency() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let submit = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.003)),
                ("op", Value::Str("kmeans".into())),
                ("k", Value::Num(3.0)),
                ("iters", Value::Num(2.0)),
            ]))
            .unwrap();
        let id = submit.get("id").unwrap().as_f64().unwrap();
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        // Done responses carry the per-job traversal counters.
        let job_stats = done.get("stats").expect("done response has stats");
        assert!(job_stats.get("nodes_visited").unwrap().as_f64().unwrap() > 0.0);

        let stats = client
            .call(&Client::request(vec![("cmd", Value::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.get("ok"), Some(&Value::Bool(true)), "{stats:?}");
        assert!(stats.get("queue_wait").unwrap().get("count").unwrap().as_f64().unwrap() >= 1.0);
        assert!(stats.get("build").unwrap().get("count").unwrap().as_f64().unwrap() >= 1.0);
        let km = stats.get("families").unwrap().get("kmeans").unwrap();
        assert_eq!(km.get("run").unwrap().get("count").unwrap().as_f64(), Some(1.0));
        assert_eq!(km.get("e2e").unwrap().get("count").unwrap().as_f64(), Some(1.0));
        assert!(km.get("stats").unwrap().get("nodes_visited").unwrap().as_f64().unwrap() > 0.0);
        // Prometheus exposition names the edge histograms and the family.
        let text = stats.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("pallas_queue_wait_us_bucket"), "{text}");
        assert!(text.contains("pallas_run_us_count{family=\"kmeans\"}"), "{text}");
        assert!(text.contains("pallas_nodes_visited_total{family=\"kmeans\"}"), "{text}");
    }

    #[test]
    fn bad_requests_get_errors_not_disconnects() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        for bad in [
            "not json at all",
            r#"{"cmd":"nope"}"#,
            r#"{"cmd":"submit","dataset":"unknown-ds","op":"kmeans"}"#,
            r#"{"cmd":"submit","dataset":"cell"}"#,
            r#"{"cmd":"wait"}"#,
            // Garbage numerics: each of these would alias a real id (or
            // truncate silently) under a raw `as` cast. They must come
            // back as errors, never panics or bogus lookups.
            r#"{"cmd":"wait","id":-1.5}"#,
            r#"{"cmd":"wait","id":0.25}"#,
            r#"{"cmd":"wait","id":1e300}"#,
            r#"{"cmd":"cancel","id":-1}"#,
            r#"{"cmd":"cancel","id":1e300}"#,
            r#"{"cmd":"state","id":9.5}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","seed":-3}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","seed":0.5}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","rmin":-30}"#,
            r#"{"cmd":"submit","dataset":"cell","op":"mst","rmin":1e300}"#,
        ] {
            self_call(&mut client, bad);
        }
        // Connection still alive.
        let resp = client
            .call(&Client::request(vec![("cmd", Value::Str("ping".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
    }

    fn self_call(client: &mut Client, raw: &str) {
        client.writer.write_all(raw.as_bytes()).unwrap();
        client.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        client.reader.read_line(&mut line).unwrap();
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{raw} → {line}");
    }

    #[test]
    fn metrics_surface_queue_depths() {
        let (server, coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let m = client
            .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
            .unwrap();
        assert_eq!(m.get("queue_len").and_then(Value::as_f64), Some(0.0));
        let lens = m.get("shard_queue_lens").and_then(Value::as_arr).unwrap();
        assert_eq!(lens.len(), coord.n_shards());
        assert_eq!(m.get("cancelled").and_then(Value::as_f64), Some(0.0));
        // The robustness counters ride along from day one.
        assert_eq!(m.get("cancelled_running").and_then(Value::as_f64), Some(0.0));
        assert_eq!(m.get("deadline_exceeded").and_then(Value::as_f64), Some(0.0));
        assert_eq!(m.get("breaker_open").and_then(Value::as_f64), Some(0.0));
        assert_eq!(m.get("conns_rejected").and_then(Value::as_f64), Some(0.0));
        assert_eq!(m.get("retries").and_then(Value::as_f64), Some(0.0));
        assert_eq!(m.get("draining"), Some(&Value::Bool(false)));
    }

    #[test]
    fn shards_op_reports_per_shard_state() {
        let (server, coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![("cmd", Value::Str("shards".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(
            resp.get("shards").and_then(Value::as_f64),
            Some(coord.n_shards() as f64)
        );
        let per = resp.get("per_shard").and_then(Value::as_arr).unwrap();
        assert_eq!(per.len(), coord.n_shards());
        for (i, shard) in per.iter().enumerate() {
            assert_eq!(shard.get("shard").and_then(Value::as_f64), Some(i as f64));
            assert_eq!(shard.get("queue_len").and_then(Value::as_f64), Some(0.0));
            assert!(shard.get("submitted").is_some());
        }
    }

    #[test]
    fn cancel_op_rejects_non_queued_jobs() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        // Unknown job: ok:false, connection stays usable.
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("cancel".into())),
                ("id", Value::Num(999_999.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        // A finished job is not cancellable either.
        let submit = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.002)),
                ("op", Value::Str("mst".into())),
            ]))
            .unwrap();
        let id = submit.get("id").unwrap().as_f64().unwrap();
        client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("cancel".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{resp:?}");
    }

    #[test]
    fn cancel_op_abandons_queued_jobs() {
        // A dedicated 1-worker, 1-shard coordinator: the worker is held
        // busy by an expensive first job, so the second job is reliably
        // still queued when the cancel lands.
        let coord = Arc::new(ShardedCoordinator::new(1, 1, 16));
        let server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let submit = |client: &mut Client, op: &str, scale: f64| -> f64 {
            let resp = client
                .call(&Client::request(vec![
                    ("cmd", Value::Str("submit".into())),
                    ("dataset", Value::Str("cell".into())),
                    ("scale", Value::Num(scale)),
                    ("op", Value::Str(op.into())),
                ]))
                .unwrap();
            resp.get("id").unwrap().as_f64().unwrap()
        };
        let busy = submit(&mut client, "mst", 0.01);
        let doomed = submit(&mut client, "mst", 0.005);
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("cancel".into())),
                ("id", Value::Num(doomed)),
            ]))
            .unwrap();
        // The doomed job is still queued (or — if the busy job finished
        // implausibly fast — running); either way cancel now succeeds
        // and the job lands in failed("cancelled").
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("cancelled"), Some(&Value::Bool(true)));
        let state = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(doomed)),
            ]))
            .unwrap();
        assert_eq!(state.get("state").and_then(Value::as_str), Some("failed"));
        assert_eq!(state.get("error").and_then(Value::as_str), Some("cancelled"));
        let m = client
            .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
            .unwrap();
        let queued = m.get("cancelled").and_then(Value::as_f64).unwrap();
        let running = m.get("cancelled_running").and_then(Value::as_f64).unwrap();
        assert_eq!(queued + running, 1.0, "{m:?}");
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(busy)),
            ]))
            .unwrap();
        assert_eq!(done.get("state").and_then(Value::as_str), Some("done"));
    }

    #[test]
    fn wait_on_unknown_id_is_an_error_not_a_hang() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(123_456.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
    }

    #[test]
    fn deadline_over_the_wire() {
        // 1 worker held busy by an expensive job: the second job's 1ms
        // deadline fires while it is still queued, long before the
        // worker could claim it.
        let coord = Arc::new(ShardedCoordinator::new(1, 1, 16));
        let server = Server::start("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let busy = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("cell".into())),
                ("scale", Value::Num(0.01)),
                ("op", Value::Str("mst".into())),
            ]))
            .unwrap();
        let busy_id = busy.get("id").unwrap().as_f64().unwrap();
        let doomed = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("cell".into())),
                ("scale", Value::Num(0.005)),
                ("op", Value::Str("mst".into())),
                ("deadline_ms", Value::Num(1.0)),
            ]))
            .unwrap();
        let doomed_id = doomed.get("id").unwrap().as_f64().unwrap();
        let state = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(doomed_id)),
            ]))
            .unwrap();
        assert_eq!(state.get("state").and_then(Value::as_str), Some("failed"));
        assert_eq!(state.get("error").and_then(Value::as_str), Some("deadline"));
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(busy_id)),
            ]))
            .unwrap();
        assert_eq!(done.get("state").and_then(Value::as_str), Some("done"));
        let m = client
            .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
            .unwrap();
        assert_eq!(m.get("deadline_exceeded").and_then(Value::as_f64), Some(1.0));
    }

    #[test]
    fn drain_op_finishes_in_flight_and_stops_intake() {
        let (server, _coord) = start();
        let mut client = Client::connect(server.addr()).unwrap();
        let submit = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.003)),
                ("op", Value::Str("kmeans".into())),
                ("k", Value::Num(3.0)),
                ("iters", Value::Num(2.0)),
            ]))
            .unwrap();
        let id = submit.get("id").unwrap().as_f64().unwrap();
        assert!(!server.draining());
        let drained = client
            .call(&Client::request(vec![("cmd", Value::Str("drain".into()))]))
            .unwrap();
        assert_eq!(drained.get("ok"), Some(&Value::Bool(true)), "{drained:?}");
        assert_eq!(drained.get("drained"), Some(&Value::Bool(true)));
        assert_eq!(
            drained.get("stragglers").and_then(Value::as_arr).map(Vec::len),
            Some(0)
        );
        assert!(server.draining());
        // The in-flight job finished and its result is still readable.
        let done = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("wait".into())),
                ("id", Value::Num(id)),
            ]))
            .unwrap();
        assert_eq!(done.get("state").and_then(Value::as_str), Some("done"));
        // New submits are refused, but the connection stays usable.
        let refused = client
            .call(&Client::request(vec![
                ("cmd", Value::Str("submit".into())),
                ("dataset", Value::Str("squiggles".into())),
                ("scale", Value::Num(0.002)),
                ("op", Value::Str("mst".into())),
            ]))
            .unwrap();
        assert_eq!(refused.get("ok"), Some(&Value::Bool(false)), "{refused:?}");
    }

    #[test]
    fn connection_cap_rejects_excess_connections() {
        let coord = Arc::new(ShardedCoordinator::new(1, 1, 16));
        let opts = ServerOptions { max_conns: 1, ..Default::default() };
        let server = Server::start_with("127.0.0.1:0", Arc::clone(&coord), opts).unwrap();
        let mut first = Client::connect(server.addr()).unwrap();
        let resp = first
            .call(&Client::request(vec![("cmd", Value::Str("ping".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        // Second connection: over the cap — it gets one error line.
        let mut second = Client::connect(server.addr()).unwrap();
        let resp = second.call(&Client::request(vec![("cmd", Value::Str("ping".into()))]));
        match resp {
            Ok(v) => {
                assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{v:?}");
                assert!(
                    v.get("error")
                        .and_then(Value::as_str)
                        .is_some_and(|e| e.contains("capacity")),
                    "{v:?}"
                );
            }
            // The server may close before our request is written; the
            // transport error is an equally valid rejection.
            Err(_) => {}
        }
        assert_eq!(server.conns_rejected(), 1);
        let m = first
            .call(&Client::request(vec![("cmd", Value::Str("metrics".into()))]))
            .unwrap();
        assert_eq!(m.get("conns_rejected").and_then(Value::as_f64), Some(1.0));
    }

    #[test]
    fn client_retry_survives_a_reaped_connection() {
        // Tiny read timeout: the server reaps our idle connection, so
        // the next plain call fails at the transport — and call_retry
        // reconnects, resends with a "retry" annotation, and succeeds.
        let coord = Arc::new(ShardedCoordinator::new(1, 1, 16));
        let opts = ServerOptions {
            read_timeout: Some(std::time::Duration::from_millis(50)),
            ..Default::default()
        };
        let server = Server::start_with("127.0.0.1:0", Arc::clone(&coord), opts).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let ping = Client::request(vec![("cmd", Value::Str("ping".into()))]);
        assert_eq!(client.call(&ping).unwrap().get("ok"), Some(&Value::Bool(true)));
        std::thread::sleep(std::time::Duration::from_millis(250));
        let resp = client.call_retry(&ping, 4).unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let m = client
            .call_retry(
                &Client::request(vec![("cmd", Value::Str("metrics".into()))]),
                4,
            )
            .unwrap();
        assert!(
            m.get("retries").and_then(Value::as_f64).unwrap() >= 1.0,
            "{m:?}"
        );
    }

    #[test]
    fn concurrent_clients() {
        let (server, _coord) = start();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let resp = c
                        .call(&Client::request(vec![
                            ("cmd", Value::Str("submit".into())),
                            ("dataset", Value::Str("squiggles".into())),
                            ("scale", Value::Num(0.002)),
                            ("seed", Value::Num(i as f64)),
                            ("op", Value::Str("anomaly".into())),
                        ]))
                        .unwrap();
                    let id = resp.get("id").unwrap().as_f64().unwrap();
                    let done = c
                        .call(&Client::request(vec![
                            ("cmd", Value::Str("wait".into())),
                            ("id", Value::Num(id)),
                        ]))
                        .unwrap();
                    assert_eq!(done.get("state").unwrap().as_str(), Some("done"));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
