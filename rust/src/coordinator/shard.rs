//! Sharded coordinator: consistent-hash dataset routing over N
//! independent [`Coordinator`] shards.
//!
//! A single [`Coordinator`] scales until many datasets' jobs contend on
//! its shared queue mutex, its dataset-cache mutex, and (per dataset)
//! its run lock. [`ShardedCoordinator`] removes that ceiling the same
//! way the anchors hierarchy treats points: localize work, then exploit
//! the locality. Each shard is a complete, self-contained coordinator —
//! its own bounded queue, worker pool, dataset/tree cache, and (per
//! worker) [`crate::parallel::Executor`] — and a thin router hashes the
//! job's route key `(dataset, rmin)` ([`JobSpec::route_key`]) onto a
//! consistent-hash ring to pick the shard. Jobs for one `(dataset,
//! rmin)` pair therefore always land on the same shard (its caches stay
//! hot and its distance accounting exact), while jobs for different
//! datasets never touch a common lock.
//!
//! ## JobId encoding
//!
//! Returned [`JobId`]s are globally unique: the shard index lives in
//! the [`SHARD_BITS`] bits above the shard-local sequential id
//! ([`encode_job_id`] / [`decode_job_id`]). `state` / `wait` /
//! `cancel` decode the shard from the id and route directly — no
//! broadcast. Shard 0's tag is zero, so with one shard every id equals
//! the local id and `ShardedCoordinator::new(1, ..)` behaves exactly
//! like today's `Coordinator`, byte for byte.
//!
//! The tag sits at bit [`SHARD_SHIFT`] = 44 — not 56 — so every
//! encoded id stays below 2⁵² and survives the JSON wire protocol's
//! `f64` number representation exactly (integers are exact in an f64
//! only up to 2⁵³). 2⁴⁴ local jobs per shard is ~17 trillion — far
//! beyond any process lifetime this side of a restart.
//!
//! ## Determinism contract
//!
//! The shard count is a pure throughput knob. For any job stream,
//! results — and, because the route key pins each `(dataset, rmin)`
//! stream to one shard and one cache, per-job distance counts — are
//! identical at every shard count (`tests/coordinator_props.rs`
//! pins shards {1, 2, 4}). The ring itself is deterministic: same
//! shard count ⇒ same ring ⇒ same routing, on every machine.
//!
//! ## Why a consistent-hash ring (and not `hash % N`)
//!
//! The ring ([`VNODES`] virtual points per shard, FNV-1a + splitmix64
//! finalizer) keeps the assignment stable under resharding: growing N
//! shards to N+1 remaps only ~1/(N+1) of the key space instead of
//! almost all of it,
//! which is what makes this the stepping stone to multi-process /
//! multi-host serving where shards and their warm caches move between
//! processes.

use super::{
    Coordinator, CoordinatorConfig, JobId, JobSpec, JobState, MetricsSnapshot, ObsSnapshot,
    SubmitError,
};
use crate::ids;
use crate::runtime::BatchDistanceEngine;
use std::sync::Arc;
use std::time::Duration;

/// Bits of a [`JobId`] reserved for the shard index.
pub const SHARD_BITS: u32 = 8;
/// Maximum shard count representable in the [`JobId`] tag.
pub const MAX_SHARDS: usize = 1 << SHARD_BITS;
/// Virtual points per shard on the consistent-hash ring.
pub const VNODES: usize = 256;
/// Bit position of the shard tag. Low enough that every encoded id is
/// ≤ 2⁵² and therefore exact as a JSON `f64` (see the module docs).
pub const SHARD_SHIFT: u32 = 44;

const LOCAL_MASK: u64 = (1 << SHARD_SHIFT) - 1;

/// Tag a shard-local job id with its shard index (the [`SHARD_BITS`]
/// bits at [`SHARD_SHIFT`]). Shard 0 is the identity:
/// `encode_job_id(0, id) == id`.
pub fn encode_job_id(shard: usize, local: JobId) -> JobId {
    debug_assert!(shard < MAX_SHARDS, "shard {shard} out of range");
    debug_assert!(local <= LOCAL_MASK, "local id {local} overflows the tag");
    (ids::u64_from_usize(shard) << SHARD_SHIFT) | local
}

/// Split a global [`JobId`] into `(shard, local)`.
pub fn decode_job_id(id: JobId) -> (usize, JobId) {
    // The tag is at most `SHARD_BITS` + the bits above it — far below
    // `u32::MAX` — so the usize conversion is lossless on every target.
    (ids::usize_from_u64(id >> SHARD_SHIFT), id & LOCAL_MASK)
}

/// Default shard count: `PALLAS_SHARDS` when set, otherwise 1 —
/// today's single-coordinator behavior. This is the *single* owner of
/// the variable's semantics — the CLI (`--shards` fallback), the
/// servers, and the test suites all go through here, so the behavior
/// cannot diverge between consumers.
///
/// A variable that is *set but unparseable* is a loud `Err`, never a
/// silent fallback: the CI `PALLAS_SHARDS=4` pass exists to exercise
/// the sharded path, and quietly degrading to one shard would turn
/// that coverage green while testing nothing. The value is returned
/// unclamped — [`ShardedCoordinator::with_engine`] is the single
/// clamp point, for flag and env values alike.
pub fn default_shards() -> Result<usize, String> {
    match std::env::var("PALLAS_SHARDS") {
        Err(_) => Ok(1),
        Ok(raw) => raw
            .trim()
            .parse::<usize>()
            .map_err(|e| format!("$PALLAS_SHARDS: cannot parse {raw:?}: {e}")),
    }
}

/// Ring hash: FNV-1a folded through a splitmix64 finalizer.
/// Deterministic, allocation-free, std-only. FNV-1a alone has weak
/// avalanche on the short, structured strings we hash (vnode labels,
/// route keys) — its raw output clumps badly on the ring (measured:
/// one of 4 shards owning ~7% of the key space); the finalizer's
/// multiply-xorshift cascade restores balance to within a few percent.
fn ring_hash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// A consistent-hash ring: each shard contributes [`VNODES`] points;
/// a key routes to the shard owning the first point clockwise of the
/// key's hash.
struct Ring {
    /// Sorted `(point, shard)` pairs. The shard is stored as `usize`
    /// outright — `(u64, u32)` pads to the same 16 bytes, so narrowing
    /// would buy nothing and cost a cast on every route.
    points: Vec<(u64, usize)>,
}

impl Ring {
    fn new(n_shards: usize) -> Ring {
        let mut points = Vec::with_capacity(n_shards * VNODES);
        for shard in 0..n_shards {
            for vnode in 0..VNODES {
                let point = ring_hash(format!("shard-{shard}#vnode-{vnode}").as_bytes());
                points.push((point, shard));
            }
        }
        points.sort_unstable();
        Ring { points }
    }

    fn route(&self, key: &str) -> usize {
        let h = ring_hash(key.as_bytes());
        let i = self.points.partition_point(|&(p, _)| p < h);
        // Wrap past the last point back to the ring's first.
        let (_, shard) = self.points[if i == self.points.len() { 0 } else { i }];
        shard
    }
}

/// N independent [`Coordinator`] shards behind a consistent-hash
/// router. Drop-in for a single `Coordinator` — same `submit` / `state`
/// / `wait` / `cancel` / `queue_len` / `metrics` / `shutdown` surface —
/// plus per-shard introspection ([`ShardedCoordinator::shard_metrics`],
/// [`ShardedCoordinator::shard_queue_lens`]).
pub struct ShardedCoordinator {
    shards: Vec<Coordinator>,
    ring: Ring,
}

impl ShardedCoordinator {
    /// `n_shards` shards (clamped to `1..=`[`MAX_SHARDS`]), each with
    /// its own pool of `workers_per_shard` workers and a queue bounded
    /// at `capacity_per_shard`.
    pub fn new(n_shards: usize, workers_per_shard: usize, capacity_per_shard: usize) -> Self {
        Self::with_engine(n_shards, workers_per_shard, capacity_per_shard, None)
    }

    /// As [`ShardedCoordinator::new`], with an optional XLA batch
    /// engine shared by all shards (it is internally synchronized and
    /// stateless across calls, so sharing it does not re-introduce a
    /// cross-shard serialization point for the scalar path).
    pub fn with_engine(
        n_shards: usize,
        workers_per_shard: usize,
        capacity_per_shard: usize,
        engine: Option<Arc<BatchDistanceEngine>>,
    ) -> Self {
        Self::with_config(
            n_shards,
            workers_per_shard,
            capacity_per_shard,
            engine,
            CoordinatorConfig::default(),
        )
    }

    /// As [`ShardedCoordinator::with_engine`], with explicit robustness
    /// knobs applied to every shard (breakers stay per-dataset, and a
    /// dataset lives on exactly one shard, so per-shard breaker state is
    /// also globally consistent).
    pub fn with_config(
        n_shards: usize,
        workers_per_shard: usize,
        capacity_per_shard: usize,
        engine: Option<Arc<BatchDistanceEngine>>,
        config: CoordinatorConfig,
    ) -> Self {
        let n = n_shards.clamp(1, MAX_SHARDS);
        let shards = (0..n)
            .map(|_| {
                Coordinator::with_config(
                    workers_per_shard,
                    capacity_per_shard,
                    engine.clone(),
                    config,
                )
            })
            .collect();
        ShardedCoordinator { shards, ring: Ring::new(n) }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard `spec` routes to — a pure function of
    /// [`JobSpec::route_key`] and the shard count.
    pub fn shard_of(&self, spec: &JobSpec) -> usize {
        self.ring.route(&spec.route_key())
    }

    /// Route and submit; the returned id is globally unique and carries
    /// its shard tag, so every other call routes without a broadcast.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        let shard = self.shard_of(&spec);
        self.shards[shard]
            .submit(spec)
            .map(|local| encode_job_id(shard, local))
    }

    /// Snapshot a job's state (`None` for ids no shard has seen).
    pub fn state(&self, id: JobId) -> Option<JobState> {
        let (shard, local) = decode_job_id(id);
        self.shards.get(shard)?.state(local)
    }

    /// Block until the job reaches a terminal state.
    ///
    /// # Panics
    /// Like [`Coordinator::wait`], panics on an unknown job id;
    /// untrusted ids (e.g. off the wire) should go through
    /// [`ShardedCoordinator::wait_checked`] instead.
    pub fn wait(&self, id: JobId) -> JobState {
        // pallas-lint: allow(panic-wire, documented trusted-caller API; the wire path resolves untrusted ids via wait_checked)
        self.wait_checked(id).unwrap_or_else(|| panic!("unknown job id {id}"))
    }

    /// Non-panicking [`ShardedCoordinator::wait`]: `None` when the id's
    /// shard tag names no shard or its shard never issued the local id.
    pub fn wait_checked(&self, id: JobId) -> Option<JobState> {
        let (shard, local) = decode_job_id(id);
        self.shards.get(shard)?.wait_checked(local)
    }

    /// Cancel a queued *or running* job on whichever shard owns it; see
    /// [`Coordinator::cancel`] for the exact semantics (an affirmative
    /// answer is a promise that the job ends `Failed`).
    pub fn cancel(&self, id: JobId) -> bool {
        let (shard, local) = decode_job_id(id);
        self.shards.get(shard).is_some_and(|coord| coord.cancel(local))
    }

    /// Total queue depth across shards.
    pub fn queue_len(&self) -> usize {
        self.shards.iter().map(Coordinator::queue_len).sum()
    }

    /// Per-shard queue depths, indexed by shard.
    pub fn shard_queue_lens(&self) -> Vec<usize> {
        self.shards.iter().map(Coordinator::queue_len).collect()
    }

    /// Aggregate metrics across shards (field-wise sums).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shards
            .iter()
            .map(Coordinator::metrics)
            .fold(MetricsSnapshot::default(), |acc, m| acc.merge(&m))
    }

    /// Per-shard metric snapshots, indexed by shard.
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(Coordinator::metrics).collect()
    }

    /// Aggregate serving-edge observability across shards (field-wise
    /// histogram and counter sums; the merge is order-invariant).
    pub fn obs(&self) -> ObsSnapshot {
        self.shards
            .iter()
            .map(Coordinator::obs)
            .fold(ObsSnapshot::default(), |acc, o| acc.merge(&o))
    }

    /// Per-shard serving-edge observability, indexed by shard.
    pub fn shard_obs(&self) -> Vec<ObsSnapshot> {
        self.shards.iter().map(Coordinator::obs).collect()
    }

    /// Stop intake on every shard at once (does not wait; pair with
    /// [`ShardedCoordinator::drain`] or [`ShardedCoordinator::shutdown`]).
    pub fn request_shutdown(&self) {
        for shard in &self.shards {
            shard.request_shutdown();
        }
    }

    /// Stop intake everywhere, then wait — bounded per shard — for
    /// in-flight and queued work to finish. Intake stops on *all*
    /// shards before any waiting starts, so the shards drain
    /// concurrently and a wedged shard never delays the others' drains;
    /// it is reported as a straggler instead of hanging the caller.
    pub fn drain(&self, per_shard_timeout: Duration) -> DrainReport {
        self.request_shutdown();
        let mut stragglers = Vec::new();
        for (i, shard) in self.shards.iter().enumerate() {
            if !shard.drain(per_shard_timeout) {
                stragglers.push(i);
            }
        }
        DrainReport {
            drained: stragglers.is_empty(),
            stragglers,
            metrics: self.metrics(),
        }
    }

    /// Drain and join every shard, then return the aggregate metrics.
    /// Intake stops on all shards up front (concurrent drain, as in
    /// [`ShardedCoordinator::drain`]); each shard's join is bounded, so
    /// one wedged worker detaches instead of wedging the whole
    /// teardown.
    pub fn shutdown(self) -> MetricsSnapshot {
        self.request_shutdown();
        self.shards
            .into_iter()
            .map(Coordinator::shutdown)
            .fold(MetricsSnapshot::default(), |acc, m| acc.merge(&m))
    }
}

/// Outcome of [`ShardedCoordinator::drain`].
#[derive(Clone, Debug)]
pub struct DrainReport {
    /// Every shard finished all queued and in-flight work in time.
    pub drained: bool,
    /// Shards still running a job when their wait bound expired (they
    /// keep draining in the background).
    pub stragglers: Vec<usize>,
    /// Aggregate metrics at the moment the drain ended.
    pub metrics: MetricsSnapshot,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, DatasetSpec};
    use crate::engine::{KmeansQuery, Query, QueryResult};

    fn km_spec(seed: u64, rmin: usize) -> JobSpec {
        let query = KmeansQuery { k: 3, iters: 2, use_tree: true, ..Default::default() };
        JobSpec {
            dataset: DatasetSpec { kind: DatasetKind::Squiggles, scale: 0.003, seed },
            query: Query::Kmeans(query),
            rmin,
            deadline_ms: None,
        }
    }

    #[test]
    fn job_id_roundtrip() {
        for shard in [0usize, 1, 7, MAX_SHARDS - 1] {
            for local in [1u64, 42, LOCAL_MASK] {
                let id = encode_job_id(shard, local);
                assert_eq!(decode_job_id(id), (shard, local));
            }
        }
        // Shard 0 is the identity: single-shard ids match today's.
        assert_eq!(encode_job_id(0, 17), 17);
        // Every encodable id survives the wire's f64 number type
        // exactly — the reason the tag sits at bit 44, not 56.
        let max = encode_job_id(MAX_SHARDS - 1, LOCAL_MASK);
        assert!(max < (1 << 53));
        assert_eq!(max as f64 as u64, max);
        let small_on_last_shard = encode_job_id(MAX_SHARDS - 1, 1);
        assert_eq!(small_on_last_shard as f64 as u64, small_on_last_shard);
    }

    #[test]
    fn ring_routing_is_deterministic_and_in_range() {
        for n in [1usize, 2, 4, 8] {
            let a = Ring::new(n);
            let b = Ring::new(n);
            for seed in 0..64u64 {
                let key = km_spec(seed, 16).route_key();
                let shard = a.route(&key);
                assert!(shard < n);
                assert_eq!(shard, b.route(&key), "ring not deterministic");
            }
        }
    }

    #[test]
    fn ring_spreads_keys_over_shards() {
        let n = 4;
        let ring = Ring::new(n);
        let mut hits = vec![0usize; n];
        for seed in 0..256u64 {
            for rmin in [8usize, 16, 30] {
                hits[ring.route(&km_spec(seed, rmin).route_key())] += 1;
            }
        }
        // Not a balance proof, just a sanity floor: every shard owns a
        // real fraction of a 768-key universe.
        for (shard, &h) in hits.iter().enumerate() {
            assert!(h > 768 / (n * 8), "shard {shard} nearly empty: {hits:?}");
        }
    }

    #[test]
    fn resharding_moves_few_keys() {
        // The consistent-hash property: going 4 → 5 shards remaps only
        // a minority of keys (hash % N would remap ~80% of them).
        let before = Ring::new(4);
        let after = Ring::new(5);
        let total = 512usize;
        let moved = (0..total as u64)
            .filter(|&seed| {
                let key = km_spec(seed, 16).route_key();
                before.route(&key) != after.route(&key)
            })
            .count();
        assert!(moved < total / 2, "resharding moved {moved}/{total} keys");
    }

    #[test]
    fn submit_wait_across_shards() {
        let coord = ShardedCoordinator::new(4, 1, 32);
        let ids: Vec<JobId> = (0..8)
            .map(|seed| coord.submit(km_spec(seed, 16)).unwrap())
            .collect();
        // Ids are globally unique even though shards count locally.
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len(), "duplicate global ids");
        for id in &ids {
            let JobState::Done(r) = coord.wait(*id) else {
                panic!("job {id} did not complete");
            };
            assert!(matches!(r.output, QueryResult::Kmeans { .. }));
            assert!(r.dists > 0);
        }
        let m = coord.shutdown();
        assert_eq!(m.submitted, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn state_and_cancel_route_by_id_tag() {
        let coord = ShardedCoordinator::new(4, 1, 32);
        let id = coord.submit(km_spec(1, 16)).unwrap();
        assert!(coord.state(id).is_some());
        // An id tagged for a shard that does not exist is unknown, not
        // a panic (state) and not a cancel.
        let bogus = encode_job_id(MAX_SHARDS - 1, 1);
        assert!(coord.state(bogus).is_none());
        assert!(!coord.cancel(bogus));
        assert!(coord.wait_checked(bogus).is_none());
        // An unknown local id on an existing shard: None, not a hang.
        assert!(coord.wait_checked(encode_job_id(0, 999)).is_none());
        assert!(coord.wait(id).is_terminal());
        // Terminal jobs are not cancellable.
        assert!(!coord.cancel(id));
        coord.shutdown();
    }

    #[test]
    fn drain_finishes_every_shard_and_stops_intake() {
        let coord = ShardedCoordinator::new(4, 1, 32);
        let ids: Vec<JobId> = (0..6)
            .map(|seed| coord.submit(km_spec(seed, 16)).unwrap())
            .collect();
        let report = coord.drain(Duration::from_secs(60));
        assert!(report.drained, "stragglers: {:?}", report.stragglers);
        assert_eq!(report.metrics.completed, 6);
        assert!(matches!(
            coord.submit(km_spec(9, 16)),
            Err(SubmitError::ShuttingDown)
        ));
        for id in ids {
            assert!(matches!(coord.wait(id), JobState::Done(_)));
        }
        coord.shutdown();
    }

    #[test]
    fn single_shard_matches_plain_coordinator_ids() {
        let sharded = ShardedCoordinator::new(1, 2, 16);
        let plain = Coordinator::new(2, 16);
        for seed in 0..3u64 {
            let a = sharded.submit(km_spec(seed, 16)).unwrap();
            let b = plain.submit(km_spec(seed, 16)).unwrap();
            assert_eq!(a, b, "N=1 ids must match the plain coordinator's");
        }
    }

    #[test]
    fn per_shard_introspection_sums_to_aggregate() {
        let coord = ShardedCoordinator::new(4, 1, 32);
        let ids: Vec<JobId> = (0..6)
            .map(|seed| coord.submit(km_spec(seed, 16)).unwrap())
            .collect();
        for id in ids {
            coord.wait(id);
        }
        let agg = coord.metrics();
        let per = coord.shard_metrics();
        assert_eq!(per.len(), 4);
        let summed = per
            .iter()
            .fold(MetricsSnapshot::default(), |acc, m| acc.merge(m));
        assert_eq!(summed, agg);
        assert_eq!(agg.submitted, 6);
        assert_eq!(
            coord.shard_queue_lens().iter().sum::<usize>(),
            coord.queue_len()
        );
        coord.shutdown();
    }
}
