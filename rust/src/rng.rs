//! Deterministic pseudo-random number generation (no external crates).
//!
//! Everything in this repo that samples — dataset generators, K-means
//! initialization, property tests — goes through [`Rng`], a xoshiro256++
//! generator seeded via SplitMix64. Same seed ⇒ same dataset ⇒ same
//! distance counts, which is what makes the paper-table reproductions
//! (docs/EXPERIMENTS.md) stable across runs and machines.

/// SplitMix64 step — used to expand a single `u64` seed into the four
/// xoshiro words (the construction recommended by the xoshiro authors).
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush; more than
/// adequate for synthetic-data generation and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child generator (for per-worker / per-cluster
    /// streams without correlated output).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    ///
    /// A plain `f64 as f32` would violate the half-open contract: any
    /// draw above `1 − 2⁻²⁵` (e.g. the largest `f64()` output,
    /// `1 − 2⁻⁵³`) rounds to exactly `1.0f32`. Clamp those draws to the
    /// largest f32 below 1.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        const BELOW_ONE: f32 = 1.0 - f32::EPSILON / 2.0; // 0x3F7FFFFF
        (self.f64() as f32).min(BELOW_ONE)
    }

    /// Uniform integer in [0, n) (n > 0), bias-free via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        // Lemire-style rejection sampling.
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi) .
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // Rejection for sparse samples.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Sample from a Zipf(s) distribution over ranks 1..=n, returned as a
    /// 0-based index. Uses the cached-CDF inversion (callers wanting many
    /// samples should use [`ZipfTable`]).
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        table.sample(self)
    }

    /// Categorical sample from (unnormalized) non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero total weight");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed CDF for Zipf-distributed vocabulary sampling (used by the
/// Reuters bag-of-words surrogate).
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_never_reaches_one_even_at_the_rounding_boundary() {
        // Any f64 in (1 − 2⁻²⁵, 1) rounds to 1.0f32 under `as f32`, so
        // the clamp is what upholds the documented [0, 1) contract.
        // Check the exact worst case the raw u64 stream can produce
        // (all-ones → f64() = 1 − 2⁻⁵³) plus the nearest-even boundary.
        let worst = (u64::MAX >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        assert!(worst < 1.0 && worst as f32 == 1.0, "premise of the clamp");
        let clamped = (worst as f32).min(1.0 - f32::EPSILON / 2.0);
        assert_eq!(clamped.to_bits(), 0x3F7F_FFFF, "largest f32 below 1");
        // And the generator itself stays in range over a long stream.
        let mut r = Rng::new(41);
        for _ in 0..100_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x), "f32() produced {x}");
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_is_head_heavy() {
        let mut r = Rng::new(23);
        let t = ZipfTable::new(1000, 1.1);
        let mut head = 0;
        let n = 20_000;
        for _ in 0..n {
            if t.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // Top-10 ranks of a 1000-word Zipf(1.1) carry ~35-45% of the mass.
        assert!(head > n / 5, "head mass too small: {head}/{n}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(29);
        let mut c = [0usize; 3];
        for _ in 0..30_000 {
            c[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(c[2] > c[1] && c[1] > c[0]);
        assert!((c[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(31);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
