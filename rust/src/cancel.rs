//! Cooperative cancellation: the one-word flag a running query polls at
//! its traversal checkpoints.
//!
//! A [`CancelSlot`] is a single atomic owned by a [`crate::metrics::Space`]
//! and shared (like the distance counter and the obs sink) with every
//! arena derived from it via `select_rows`. The coordinator *arms* the
//! slot before a job's traversal starts and *sets* it from another
//! thread — [`crate::coordinator::Coordinator::cancel`] for an explicit
//! cancel, the deadline timer for an expired `deadline_ms`. The running
//! query observes the flag only at explicit checkpoints
//! ([`crate::metrics::Space::checkpoint`]): frontier pops and leaf-scan
//! chunk boundaries, never inside a distance kernel — so on the
//! non-cancelled path the checkpoint is observationally free (one
//! relaxed load) and the determinism/accounting contract is untouched.
//!
//! A tripped checkpoint unwinds with [`std::panic::panic_any`] carrying
//! a typed [`CancelUnwind`] payload. The coordinator's per-job
//! `catch_unwind` downcasts it back and classifies the job as
//! `Failed("cancelled")` / `Failed("deadline")` with the partial
//! traversal counters attached — distinguishable from a real panic,
//! which trips the per-dataset circuit breaker instead.

use std::sync::atomic::{AtomicU8, Ordering};

/// Why a running job was asked to stop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// Explicit `cancel` request for a running job.
    Cancelled,
    /// The job's `deadline_ms` expired.
    Deadline,
}

impl CancelReason {
    /// The wire/state error string for this reason (`"cancelled"` /
    /// `"deadline"`).
    pub fn as_str(self) -> &'static str {
        match self {
            CancelReason::Cancelled => "cancelled",
            CancelReason::Deadline => "deadline",
        }
    }
}

const LIVE: u8 = 0;
const CANCELLED: u8 = 1;
const DEADLINE: u8 = 2;

/// One job's cancellation flag: armed (cleared) by the worker at job
/// start, set at most once by a canceller, polled at checkpoints.
#[derive(Debug, Default)]
pub struct CancelSlot {
    state: AtomicU8,
}

impl CancelSlot {
    pub fn new() -> CancelSlot {
        CancelSlot { state: AtomicU8::new(LIVE) }
    }

    /// Clear the slot for a fresh job. Only the owning worker calls
    /// this, under the dataset's run lock, before the job's traversal
    /// starts — so a stale flag from a previous job on the same space
    /// can never leak into the next one.
    pub fn arm(&self) {
        self.state.store(LIVE, Ordering::Release);
    }

    /// Request a stop. First reason wins; later calls are no-ops, so an
    /// explicit cancel racing a deadline yields one stable reason.
    pub fn set(&self, reason: CancelReason) {
        let v = match reason {
            CancelReason::Cancelled => CANCELLED,
            CancelReason::Deadline => DEADLINE,
        };
        let _ = self
            .state
            .compare_exchange(LIVE, v, Ordering::AcqRel, Ordering::Acquire);
    }

    /// The reason set on this slot, if any.
    pub fn get(&self) -> Option<CancelReason> {
        match self.state.load(Ordering::Acquire) {
            CANCELLED => Some(CancelReason::Cancelled),
            DEADLINE => Some(CancelReason::Deadline),
            _ => None,
        }
    }

    /// Checkpoint: unwind with a typed [`CancelUnwind`] payload when the
    /// slot has been set. The happy path is one relaxed load.
    #[inline]
    pub fn check(&self) {
        if self.state.load(Ordering::Relaxed) != LIVE {
            self.trip();
        }
    }

    #[cold]
    fn trip(&self) {
        let reason = self.get().unwrap_or(CancelReason::Cancelled);
        std::panic::panic_any(CancelUnwind { reason });
    }
}

/// The typed unwind payload a tripped checkpoint carries. Caught (and
/// downcast) by the coordinator's per-job `catch_unwind`; never printed
/// by the default panic hook path because the coordinator always
/// catches it before it reaches a thread boundary it doesn't own.
#[derive(Clone, Copy, Debug)]
pub struct CancelUnwind {
    pub reason: CancelReason,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_reason_wins_and_arm_resets() {
        let slot = CancelSlot::new();
        assert_eq!(slot.get(), None);
        slot.set(CancelReason::Deadline);
        slot.set(CancelReason::Cancelled); // late, ignored
        assert_eq!(slot.get(), Some(CancelReason::Deadline));
        slot.arm();
        assert_eq!(slot.get(), None);
        slot.set(CancelReason::Cancelled);
        assert_eq!(slot.get(), Some(CancelReason::Cancelled));
    }

    #[test]
    fn check_unwinds_with_typed_payload() {
        let slot = CancelSlot::new();
        slot.check(); // live: no unwind
        slot.set(CancelReason::Deadline);
        let err = std::panic::catch_unwind(|| slot.check()).unwrap_err();
        let cu = err.downcast_ref::<CancelUnwind>().expect("typed payload");
        assert_eq!(cu.reason, CancelReason::Deadline);
        assert_eq!(cu.reason.as_str(), "deadline");
    }
}
