//! Minimal JSON reader/writer (the environment is offline; serde is not
//! available). Only what this crate needs: parsing `artifacts/manifest.json`
//! and emitting structured experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Parse a JSON document. Returns a descriptive error with byte offset on
/// malformed input.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        // The scanned bytes are all ASCII digits/signs, but fail soft
        // anyway: this is wire-facing code.
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| format!("invalid number bytes at {start}"))?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("bad number {s:?} at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape \\{}", esc as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    // `peek()` returned `Some`, so `rest` is non-empty.
                    let ch = rest.chars().next().ok_or("unterminated string")?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }
}

/// Serialize a [`Value`] to compact JSON text.
pub fn write(v: &Value) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Value::Str(k.clone()), out);
                out.push(':');
                write_into(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"tile_n":256,"variants":[{"d":8,"file":"x.hlo.txt","program":"pairwise_d2"}]}"#;
        let v = parse(src).unwrap();
        assert_eq!(write(&v), src);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
  "tile_n": 256,
  "tile_k": 128,
  "variants": [
    {"program": "pairwise_d2", "n": 256, "k": 128, "d": 8,
     "file": "pairwise_d2_n256_k128_d8.hlo.txt",
     "outputs": ["d2[n,k]f32"]}
  ]
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("tile_n").unwrap().as_usize(), Some(256));
        let vars = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(vars[0].get("d").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Value::Str("A".into()));
    }
}
