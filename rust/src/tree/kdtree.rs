//! A plain kd-tree — the baseline the paper argues *against* in high
//! dimensions (§2.1, Figure 1).
//!
//! Used by the `figure1` experiment to demonstrate that on the two-class
//! 1000-dimensional binary dataset a kd-tree needs many levels to separate
//! the classes while a metric tree's very first split does it.

use crate::data::DenseMatrix;

#[derive(Debug)]
pub struct KdNode {
    /// Splitting dimension (interior nodes).
    pub split_dim: usize,
    /// Splitting value.
    pub split_val: f32,
    pub count: usize,
    pub children: Option<(u32, u32)>,
    /// Leaf point ids.
    pub points: Vec<u32>,
}

pub struct KdTree {
    pub nodes: Vec<KdNode>,
    pub root: u32,
    pub rmin: usize,
}

impl KdTree {
    pub fn node(&self, id: u32) -> &KdNode {
        &self.nodes[id as usize]
    }

    /// Build with the classic "split widest dimension at the median" rule.
    pub fn build(data: &DenseMatrix, rmin: usize) -> KdTree {
        let points: Vec<u32> = (0..data.n as u32).collect();
        let mut nodes = Vec::new();
        let root = split(data, points, rmin.max(1), &mut nodes, 0);
        KdTree { nodes, root, rmin }
    }

    /// Node ids at a given depth (root = depth 0).
    pub fn nodes_at_depth(&self, depth: usize) -> Vec<u32> {
        let mut frontier = vec![self.root];
        for _ in 0..depth {
            let mut next = Vec::new();
            for id in frontier {
                match self.node(id).children {
                    Some((a, b)) => {
                        next.push(a);
                        next.push(b);
                    }
                    None => next.push(id), // leaves stay in the frontier
                }
            }
            frontier = next;
        }
        frontier
    }

    /// All points under a node.
    pub fn points_under(&self, id: u32) -> Vec<u32> {
        let mut out = Vec::new();
        let mut stack = vec![id];
        while let Some(nid) = stack.pop() {
            let n = self.node(nid);
            match n.children {
                None => out.extend_from_slice(&n.points),
                Some((a, b)) => {
                    stack.push(a);
                    stack.push(b);
                }
            }
        }
        out
    }
}

fn split(
    data: &DenseMatrix,
    points: Vec<u32>,
    rmin: usize,
    nodes: &mut Vec<KdNode>,
    depth: usize,
) -> u32 {
    let count = points.len();
    // Depth cap keeps degenerate data (all duplicates) from recursing
    // forever; 64 levels is far beyond any real split need.
    if count <= rmin || depth > 64 {
        nodes.push(KdNode {
            split_dim: 0,
            split_val: 0.0,
            count,
            children: None,
            points,
        });
        return (nodes.len() - 1) as u32;
    }
    // Widest dimension.
    let d = data.d;
    let mut best_dim = 0;
    let mut best_spread = -1.0f32;
    for dim in 0..d {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &p in &points {
            // pallas-lint: allow(uncounted-dist, coordinate access for the kd split; no distance computed)
            let v = data.row(p as usize)[dim];
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi - lo > best_spread {
            best_spread = hi - lo;
            best_dim = dim;
        }
    }
    if best_spread <= 0.0 {
        nodes.push(KdNode {
            split_dim: 0,
            split_val: 0.0,
            count,
            children: None,
            points,
        });
        return (nodes.len() - 1) as u32;
    }
    // Median split on the widest dimension.
    let mut vals: Vec<f32> = points
        .iter()
        // pallas-lint: allow(uncounted-dist, coordinate access for the kd split; no distance computed)
        .map(|&p| data.row(p as usize)[best_dim])
        .collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let split_val = vals[vals.len() / 2];
    let (mut left, mut right) = (Vec::new(), Vec::new());
    for &p in &points {
        // pallas-lint: allow(uncounted-dist, coordinate access for the kd split; no distance computed)
        if data.row(p as usize)[best_dim] < split_val {
            left.push(p);
        } else {
            right.push(p);
        }
    }
    if left.is_empty() || right.is_empty() {
        // All values equal to the median: split evenly.
        let mut all = points;
        let mid = all.len() / 2;
        right = all.split_off(mid);
        left = all;
    }
    let l = split(data, left, rmin, nodes, depth + 1);
    let r = split(data, right, rmin, nodes, depth + 1);
    nodes.push(KdNode {
        split_dim: best_dim,
        split_val,
        count,
        children: Some((l, r)),
        points: Vec::new(),
    });
    (nodes.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_dense(n: usize, d: usize, seed: u64) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        DenseMatrix::new(n, d, vals)
    }

    #[test]
    fn partitions_all_points() {
        let data = random_dense(200, 3, 1);
        let tree = KdTree::build(&data, 10);
        let mut pts = tree.points_under(tree.root);
        pts.sort();
        assert_eq!(pts, (0..200).collect::<Vec<u32>>());
    }

    #[test]
    fn leaves_respect_rmin() {
        let data = random_dense(500, 2, 2);
        let tree = KdTree::build(&data, 20);
        let mut stack = vec![tree.root];
        while let Some(id) = stack.pop() {
            let n = tree.node(id);
            match n.children {
                None => assert!(n.count <= 20),
                Some((a, b)) => {
                    stack.push(a);
                    stack.push(b);
                }
            }
        }
    }

    #[test]
    fn split_respects_dimension_rule() {
        // Interior node: left child strictly below split value.
        let data = random_dense(100, 2, 3);
        let tree = KdTree::build(&data, 10);
        let root = tree.node(tree.root);
        if let Some((l, _)) = root.children {
            for p in tree.points_under(l) {
                assert!(data.row(p as usize)[root.split_dim] < root.split_val);
            }
        }
    }

    #[test]
    fn duplicates_terminate() {
        let data = DenseMatrix::new(64, 2, vec![1.0; 128]);
        let tree = KdTree::build(&data, 4);
        assert_eq!(tree.points_under(tree.root).len(), 64);
    }

    #[test]
    fn nodes_at_depth_cover_everything() {
        let data = random_dense(300, 2, 4);
        let tree = KdTree::build(&data, 10);
        for depth in [0, 1, 3, 6] {
            let total: usize = tree
                .nodes_at_depth(depth)
                .iter()
                .map(|&id| tree.points_under(id).len())
                .sum();
            assert_eq!(total, 300, "depth {depth}");
        }
    }
}
