//! Middle-out metric-tree construction via the Anchors Hierarchy
//! (paper §3.1).
//!
//! For a point set of size R: build √R anchors (cheap, thanks to the
//! triangle-inequality cutoff), recursively build a subtree inside each
//! anchor's owned set, then agglomerate the √R subtree roots bottom-up —
//! at each step merging the pair of nodes whose smallest enclosing ball is
//! smallest ("most compatible", §3.1). The recursion bottoms out at
//! `rmin`-sized leaves.
//!
//! ## Parallel builds
//!
//! Once an anchor frontier is fixed, its subtrees share nothing: the
//! top-level √R anchor subtrees build concurrently on
//! [`MiddleOutConfig::parallelism`] workers, each into a private arena
//! that is spliced into the shared arena in anchor order (so the layout —
//! and every node — is byte-identical to the sequential schedule). The
//! anchor passes themselves fan out over point chunks inside
//! [`build_anchors_ex`]. Each subtree derives its RNG by forking the
//! parent stream per anchor index *before* any sibling builds, which is
//! what decouples sibling builds from each other; determinism across
//! thread counts is asserted by `tests/parallel_equivalence.rs`.
//!
//! The agglomeration phase is parallel too on wide frontiers: the roots
//! partition into ⌈√F⌉ spatial buckets that merge independently on the
//! executor before a small cross-bucket heap finishes the job (see
//! `agglomerate`) — removing both the serial O(F²) heap init and the
//! last serial fraction of the build at high thread counts.

use super::{
    enclosing_radius, make_leaf, make_parent, splice_arena, splice_offset_arena, MetricTree,
    Node, NodeId,
};
use crate::anchors::build_anchors_ex;
use crate::metrics::Space;
use crate::parallel::{Executor, Parallelism};
use crate::rng::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Tunables for the middle-out builder.
#[derive(Clone, Debug)]
pub struct MiddleOutConfig {
    /// Leaf threshold R_min.
    pub rmin: usize,
    /// RNG seed (first-anchor choice).
    pub seed: u64,
    /// When true, agglomerated interior nodes get exact radii (an extra
    /// counted pass over their points) instead of the triangle-inequality
    /// upper bound. Tighter balls prune better downstream but make the
    /// build cost ~O(R log R) more distances. Benchmarked in the
    /// `tree_build` ablation.
    pub exact_radii: bool,
    /// Worker budget for the build. The produced tree is bit-identical
    /// for every setting; this knob trades wall-clock for cores only.
    pub parallelism: Parallelism,
}

impl Default for MiddleOutConfig {
    fn default() -> Self {
        MiddleOutConfig {
            rmin: 30,
            seed: 0xA11C0,
            exact_radii: false,
            parallelism: Parallelism::default(),
        }
    }
}

/// Build a middle-out tree over all points of `space`.
pub fn build(space: &Space, cfg: &MiddleOutConfig) -> MetricTree {
    build_ex(space, cfg, &Executor::new(cfg.parallelism))
}

/// [`build`] on an explicit executor, so repeated builds (the engine's
/// lazy tree, the coordinator's per-rmin cache) share one persistent
/// worker pool instead of resolving [`MiddleOutConfig::parallelism`]
/// each time.
pub fn build_ex(space: &Space, cfg: &MiddleOutConfig, exec: &Executor) -> MetricTree {
    let points: Vec<u32> = (0..space.n() as u32).collect();
    build_subset_ex(space, points, cfg, exec)
}

/// Build over an explicit point subset.
pub fn build_subset(space: &Space, points: Vec<u32>, cfg: &MiddleOutConfig) -> MetricTree {
    build_subset_ex(space, points, cfg, &Executor::new(cfg.parallelism))
}

/// [`build_subset`] on an explicit executor.
pub fn build_subset_ex(
    space: &Space,
    points: Vec<u32>,
    cfg: &MiddleOutConfig,
    exec: &Executor,
) -> MetricTree {
    assert!(!points.is_empty(), "empty tree");
    let rmin = cfg.rmin.max(1);
    let before = space.dist_count();
    let mut nodes: Vec<Node> = Vec::new();
    let mut rng = Rng::new(cfg.seed);
    let root = recurse(space, points, rmin, cfg, &mut rng, &mut nodes, exec, true);
    // Permute the dataset into tree order (uncounted copy work; the
    // layout is a pure function of the schedule-independent node arena,
    // so builds stay byte-identical at every thread count).
    let (layout, arena) = super::finalize_layout(space, &mut nodes, root);
    MetricTree {
        nodes,
        root,
        rmin,
        build_dists: space.dist_count() - before,
        layout,
        arena: Some(arena),
    }
}

#[allow(clippy::too_many_arguments)]
fn recurse(
    space: &Space,
    points: Vec<u32>,
    rmin: usize,
    cfg: &MiddleOutConfig,
    rng: &mut Rng,
    nodes: &mut Vec<Node>,
    exec: &Executor,
    fan_out: bool,
) -> NodeId {
    if points.len() <= rmin {
        nodes.push(make_leaf(space, points));
        return (nodes.len() - 1) as NodeId;
    }
    // √R anchors (at least 2, else we cannot make progress).
    let k = ((points.len() as f64).sqrt().ceil() as usize).max(2);
    let anchor_set = build_anchors_ex(space, &points, k, rng, exec);
    if anchor_set.k() < 2 {
        // All duplicates: one leaf holds them all.
        nodes.push(make_leaf(space, points));
        return (nodes.len() - 1) as NodeId;
    }

    // One RNG per subtree, forked in anchor order *before* any subtree
    // builds: each child's stream is a function of this node's state and
    // its anchor index alone — never of a sibling's build — so siblings
    // may build in any order (or concurrently) with identical results.
    let mut child_rngs: Vec<Rng> = (0..anchor_set.k()).map(|i| rng.fork(i as u64)).collect();

    // Recursively build a subtree inside each anchor's owned set
    // (paper Figure 10), then agglomerate the subtree roots
    // (Figures 8–9). With workers available, the top-level subtrees
    // build concurrently into private arenas spliced back in anchor
    // order — exactly the layout the sequential loop produces.
    let child_roots: Vec<NodeId> = if fan_out && exec.threads() > 1 {
        let serial = Executor::serial();
        let subtrees: Vec<(Vec<Node>, NodeId)> = exec.map_tasks(anchor_set.k(), |i| {
            let mut local: Vec<Node> = Vec::new();
            let mut local_rng = child_rngs[i].clone();
            let local_root = recurse(
                space,
                anchor_set.anchors[i].point_ids(),
                rmin,
                cfg,
                &mut local_rng,
                &mut local,
                &serial,
                false,
            );
            (local, local_root)
        });
        subtrees
            .into_iter()
            .map(|(local, local_root)| splice_arena(nodes, local, local_root))
            .collect()
    } else {
        anchor_set
            .anchors
            .iter()
            .zip(child_rngs.iter_mut())
            .map(|(a, crng)| {
                recurse(space, a.point_ids(), rmin, cfg, crng, nodes, exec, false)
            })
            .collect()
    };
    agglomerate(space, child_roots, cfg, nodes, exec)
}

/// Frontiers at least this wide agglomerate through the partitioned
/// scheme; narrower ones use one serial heap (the O(F²) init is cheap
/// there and the merge quality is the reference). A constant — never a
/// function of thread count — so the decomposition, and therefore every
/// result bit and distance count, is identical on any schedule.
const PARTITION_MIN_ROOTS: usize = 64;

/// Bottom-up agglomeration: repeatedly merge the most compatible pair.
/// Compatibility = radius of the smallest ball containing both (§3.1).
///
/// Wide frontiers (≥ [`PARTITION_MIN_ROOTS`], i.e. √R ≥ 64 subtree
/// roots) do not pay the serial all-pairs heap init. Instead the roots
/// are partitioned into ⌈√F⌉ spatial buckets around evenly-strided
/// leader pivots, each bucket agglomerates independently — fanned out on
/// the executor, into private offset-encoded arenas spliced back in
/// bucket order — and a small cross-bucket heap merges the ⌈√F⌉
/// survivors. Besides removing the residual serial fraction from the
/// build (ROADMAP), the partition drops the heap-init distance cost from
/// F²/2 to ≈ F·√F·3/2, which is what Pestov's lower bounds say matters
/// most in high dimensions where per-query pruning cannot win back
/// build-time waste.
fn agglomerate(
    space: &Space,
    roots: Vec<NodeId>,
    cfg: &MiddleOutConfig,
    nodes: &mut Vec<Node>,
    exec: &Executor,
) -> NodeId {
    debug_assert!(!roots.is_empty());
    if roots.len() == 1 {
        return roots[0];
    }
    if roots.len() < PARTITION_MIN_ROOTS {
        let base = nodes.len() as NodeId;
        let mut local: Vec<Node> = Vec::new();
        let root = agglomerate_into(space, &roots, cfg, nodes, base, &mut local);
        return splice_offset_arena(nodes, local, root, base);
    }

    let f = roots.len();
    let b = (f as f64).sqrt().ceil() as usize;
    // Leaders: evenly strided over the frontier (deterministic; anchor
    // order already spreads pivots across the point set).
    let leaders: Vec<NodeId> = (0..b).map(|i| roots[i * f / b]).collect();
    // Assign every root to its nearest leader pivot: F·B counted
    // pivot-pivot distances, the same set at every thread count. Ties
    // break to the earliest leader.
    let mut buckets: Vec<Vec<NodeId>> = vec![Vec::new(); b];
    for &r in &roots {
        let rn = &nodes[r as usize];
        let mut best = f64::INFINITY;
        let mut best_b = 0usize;
        for (bi, &l) in leaders.iter().enumerate() {
            let d = space.dist_vv(&rn.pivot, &nodes[l as usize].pivot);
            if d < best {
                best = d;
                best_b = bi;
            }
        }
        buckets[best_b].push(r);
    }
    buckets.retain(|bucket| !bucket.is_empty());

    // Per-bucket merges fan out on the executor. Each task reads the
    // shared arena snapshot and appends parents to a private arena with
    // ids offset-encoded from `base`; splicing in bucket order makes the
    // layout a function of the partition alone.
    let base = nodes.len() as NodeId;
    let built: Vec<(Vec<Node>, NodeId)> = {
        let shared: &[Node] = nodes;
        exec.map_tasks(buckets.len(), |bi| {
            let mut local: Vec<Node> = Vec::new();
            let root = agglomerate_into(space, &buckets[bi], cfg, shared, base, &mut local);
            (local, root)
        })
    };
    let bucket_roots: Vec<NodeId> = built
        .into_iter()
        .map(|(local, root)| splice_offset_arena(nodes, local, root, base))
        .collect();

    // Cross-bucket phase: one small heap over the ⌈√F⌉ survivors.
    let base = nodes.len() as NodeId;
    let mut local: Vec<Node> = Vec::new();
    let root = agglomerate_into(space, &bucket_roots, cfg, nodes, base, &mut local);
    splice_offset_arena(nodes, local, root, base)
}

/// Resolve a node id against the shared-arena snapshot + local arena
/// split used by the agglomeration tasks (`id >= base` is local).
#[inline]
fn node_at<'a>(shared: &'a [Node], base: NodeId, local: &'a [Node], id: NodeId) -> &'a Node {
    if id < base {
        &shared[id as usize]
    } else {
        &local[(id - base) as usize]
    }
}

/// The serial most-compatible-pair heap over one set of roots, appending
/// parents to `local` with ids offset-encoded from `base`. Returns the
/// surviving root (offset-encoded if it is a new parent). This is the
/// building block of both the per-bucket and the cross-bucket phases;
/// the single-heap path calls it with an empty partition of one.
fn agglomerate_into(
    space: &Space,
    roots: &[NodeId],
    cfg: &MiddleOutConfig,
    shared: &[Node],
    base: NodeId,
    local: &mut Vec<Node>,
) -> NodeId {
    debug_assert!(!roots.is_empty());
    if roots.len() == 1 {
        return roots[0];
    }
    // Active cluster list; lazy-deletion heap of candidate merges keyed by
    // enclosing-ball radius. f64 keys wrapped in a total order.
    let mut active: Vec<NodeId> = roots.to_vec();
    let mut alive: Vec<bool> = vec![true; active.len()];
    let mut heap: BinaryHeap<Reverse<(OrdF64, usize, usize)>> = BinaryHeap::new();

    let score = |local: &[Node], a: NodeId, b: NodeId| -> f64 {
        let (na, nb) = (node_at(shared, base, local, a), node_at(shared, base, local, b));
        let d = space.dist_vv(&na.pivot, &nb.pivot);
        enclosing_radius(d, na.radius, nb.radius)
    };

    for i in 0..active.len() {
        for j in (i + 1)..active.len() {
            let s = score(local, active[i], active[j]);
            heap.push(Reverse((OrdF64(s), i, j)));
        }
    }

    let mut remaining = active.len();
    while remaining > 1 {
        let Reverse((_, i, j)) = heap.pop().expect("heap exhausted with clusters remaining");
        if !alive[i] || !alive[j] {
            continue; // stale entry
        }
        alive[i] = false;
        alive[j] = false;
        let (ia, ib) = (active[i], active[j]);
        let mut parent = make_parent(
            space,
            node_at(shared, base, local, ia),
            node_at(shared, base, local, ib),
        );
        if cfg.exact_radii {
            tighten_radius(space, &mut parent, shared, base, local, ia, ib);
        }
        parent.children = Some((ia, ib));
        local.push(parent);
        let pid = base + (local.len() - 1) as NodeId;
        let slot = active.len();
        active.push(pid);
        alive.push(true);
        remaining -= 1;
        // Score the new cluster against all alive ones.
        for (idx, &nid) in active.iter().enumerate() {
            if idx != slot && alive[idx] {
                let s = score(local, nid, pid);
                heap.push(Reverse((OrdF64(s), idx.min(slot), idx.max(slot))));
            }
        }
    }
    *active
        .iter()
        .zip(&alive)
        .find(|(_, &a)| a)
        .expect("one cluster must survive")
        .0
}

/// Replace the parent's bounded radius with the exact maximum distance
/// over its points (counted — this is the `exact_radii` ablation).
fn tighten_radius(
    space: &Space,
    parent: &mut Node,
    shared: &[Node],
    base: NodeId,
    local: &[Node],
    a: NodeId,
    b: NodeId,
) {
    let mut radius = 0.0f64;
    let mut stack = vec![a, b];
    while let Some(id) = stack.pop() {
        let n = node_at(shared, base, local, id);
        match n.children {
            None => {
                for &p in &n.points {
                    let d = space.dist_to_vec(p as usize, &parent.pivot, parent.pivot_sq);
                    if d > radius {
                        radius = d;
                    }
                }
            }
            Some((x, y)) => {
                stack.push(x);
                stack.push(y);
            }
        }
    }
    parent.radius = radius;
}

/// Total order for f64 scores (no NaNs by construction).
#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};

    fn random_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 5.0).collect();
        Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
    }

    fn clustered_space(c: usize, per: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for _ in 0..c {
            let center: Vec<f64> = (0..d).map(|_| rng.uniform(-50.0, 50.0)).collect();
            for _ in 0..per {
                rows.push(
                    center
                        .iter()
                        .map(|&cv| (cv + rng.normal()) as f32)
                        .collect::<Vec<f32>>(),
                );
            }
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn builds_valid_tree() {
        let space = random_space(600, 3, 1);
        let tree = build(&space, &MiddleOutConfig { rmin: 12, ..Default::default() });
        tree.validate(&space).unwrap();
        assert_eq!(tree.n_points(), 600);
    }

    #[test]
    fn builds_valid_tree_exact_radii() {
        let space = random_space(400, 2, 2);
        let tree = build(
            &space,
            &MiddleOutConfig { rmin: 10, exact_radii: true, ..Default::default() },
        );
        tree.validate(&space).unwrap();
    }

    #[test]
    fn exact_radii_are_tighter_or_equal() {
        let space = clustered_space(6, 80, 3, 3);
        let loose =
            build(&space, &MiddleOutConfig { rmin: 10, seed: 5, ..Default::default() });
        let tight = build(
            &space,
            &MiddleOutConfig { rmin: 10, seed: 5, exact_radii: true, ..Default::default() },
        );
        assert!(tight.node(tight.root).radius <= loose.node(loose.root).radius + 1e-9);
    }

    #[test]
    fn clustered_data_gives_coherent_leaves() {
        // With well-separated blobs, leaf radii should be much smaller than
        // the root radius (the tree localizes).
        let space = clustered_space(8, 60, 2, 4);
        let tree = build(&space, &MiddleOutConfig { rmin: 20, ..Default::default() });
        tree.validate(&space).unwrap();
        let shape = tree.shape();
        let root_r = tree.node(tree.root).radius;
        assert!(
            shape.mean_leaf_radius < root_r / 5.0,
            "leaves not localized: mean {} vs root {root_r}",
            shape.mean_leaf_radius
        );
    }

    #[test]
    fn duplicates_collapse_to_leaf() {
        let rows: Vec<Vec<f32>> = (0..100).map(|_| vec![1.0, 1.0]).collect();
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let tree = build(&space, &MiddleOutConfig { rmin: 8, ..Default::default() });
        tree.validate(&space).unwrap();
    }

    #[test]
    fn deterministic_given_seed() {
        let space = random_space(300, 2, 6);
        let t1 = build(&space, &MiddleOutConfig { rmin: 15, seed: 9, ..Default::default() });
        let t2 = build(&space, &MiddleOutConfig { rmin: 15, seed: 9, ..Default::default() });
        assert_eq!(t1.nodes.len(), t2.nodes.len());
        assert_eq!(t1.shape(), t2.shape());
    }

    #[test]
    fn subset_build_owns_exactly_subset() {
        let space = random_space(200, 2, 7);
        let subset: Vec<u32> = (0..200).filter(|p| p % 3 == 0).collect();
        let tree = build_subset(&space, subset.clone(), &MiddleOutConfig::default());
        let mut owned = tree.points_under(tree.root).to_vec();
        owned.sort();
        assert_eq!(owned, subset);
    }

    #[test]
    fn cheaper_than_quadratic_on_clustered_data() {
        let space = clustered_space(10, 100, 2, 8);
        space.reset_count();
        let tree = build(&space, &MiddleOutConfig { rmin: 25, ..Default::default() });
        let n = space.n() as u64;
        assert!(
            tree.build_dists < n * n / 10,
            "build used {} dists (n² = {})",
            tree.build_dists,
            n * n
        );
    }
}
