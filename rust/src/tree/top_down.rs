//! Classic top-down metric-tree construction (paper §2).
//!
//! The splitting rule is the simple linear-cost scheme the paper
//! describes: let `f1` be the point farthest from the node's pivot
//! (discovered for free during the radius pass), `f2` the point farthest
//! from `f1`; points go to whichever of `f1`/`f2` they are closer to, and
//! each child's pivot is the centroid of its own points.
//!
//! Once a node's two sides are fixed they share nothing, so
//! [`build_par`] builds the top `⌈log2(threads)⌉ + 1` split levels with
//! one [`Executor::join`] per node (the top split on the persistent
//! pool, deeper ones on scoped spawns), splicing each side's private
//! arena back left-then-right — byte-identical to the sequential
//! recursion at every thread count (the builder uses no randomness at
//! all).

use super::{make_leaf, splice_arena, MetricTree, Node, NodeId};
use crate::metrics::Space;
use crate::parallel::{Executor, Parallelism};

/// Build a top-down metric tree over all points of `space` with leaf
/// threshold `rmin`, single-threaded.
pub fn build(space: &Space, rmin: usize) -> MetricTree {
    build_par(space, rmin, Parallelism::Serial)
}

/// Build a top-down metric tree with the given worker budget. The result
/// is byte-identical to [`build`] for every setting.
pub fn build_par(space: &Space, rmin: usize, parallelism: Parallelism) -> MetricTree {
    build_ex(space, rmin, &Executor::new(parallelism))
}

/// [`build_par`] on an explicit executor, so repeated builds reuse one
/// persistent worker pool (the top split's two sides run via
/// [`Executor::join`]; deeper splits fall back to scoped spawns).
pub fn build_ex(space: &Space, rmin: usize, exec: &Executor) -> MetricTree {
    let points: Vec<u32> = (0..space.n() as u32).collect();
    build_subset_ex(space, points, rmin, exec)
}

/// Build over an explicit subset (used by tests and the coordinator's
/// incremental jobs).
pub fn build_subset(space: &Space, points: Vec<u32>, rmin: usize) -> MetricTree {
    build_subset_par(space, points, rmin, Parallelism::Serial)
}

/// Subset build with a worker budget.
pub fn build_subset_par(
    space: &Space,
    points: Vec<u32>,
    rmin: usize,
    parallelism: Parallelism,
) -> MetricTree {
    build_subset_ex(space, points, rmin, &Executor::new(parallelism))
}

/// Subset build on an explicit executor.
pub fn build_subset_ex(
    space: &Space,
    points: Vec<u32>,
    rmin: usize,
    exec: &Executor,
) -> MetricTree {
    assert!(!points.is_empty(), "empty tree");
    let rmin = rmin.max(1);
    let threads = exec.threads();
    // Fan out the top ⌈log2(threads)⌉ + 1 levels: up to 2·threads leaf
    // tasks, enough to cover imbalance between the two sides of a split.
    let levels = if threads <= 1 {
        0
    } else {
        (usize::BITS - (threads - 1).leading_zeros()) as usize + 1
    };
    let before = space.dist_count();
    let mut nodes: Vec<Node> = Vec::new();
    let root = split(space, points, rmin, &mut nodes, exec, levels);
    // Permute the dataset into tree order (uncounted; see
    // `tree::finalize_layout`).
    let (layout, arena) = super::finalize_layout(space, &mut nodes, root);
    MetricTree {
        nodes,
        root,
        rmin,
        build_dists: space.dist_count() - before,
        layout,
        arena: Some(arena),
    }
}

fn split(
    space: &Space,
    points: Vec<u32>,
    rmin: usize,
    nodes: &mut Vec<Node>,
    exec: &Executor,
    levels: usize,
) -> NodeId {
    // make_leaf performs the radius pass: one counted distance per point,
    // and hands us the farthest point (f1) implicitly via a rescan below.
    let node = make_leaf(space, points);
    if node.count as usize <= rmin || node.radius <= 0.0 {
        nodes.push(node);
        return (nodes.len() - 1) as NodeId;
    }
    let points = node.points.clone();

    // f1: farthest from the pivot. (Distances were already paid for inside
    // make_leaf; recomputing them would double-count, so we re-derive f1
    // with uncounted evaluations of the same quantities.)
    let f1 = *points
        .iter()
        .max_by(|&&a, &&b| {
            // pallas-lint: allow(uncounted-dist, distances already counted in make_leaf; recomputing would double-count)
            let da = space.dist_to_vec_uncounted(a as usize, &node.pivot, node.pivot_sq);
            // pallas-lint: allow(uncounted-dist, distances already counted in make_leaf; recomputing would double-count)
            let db = space.dist_to_vec_uncounted(b as usize, &node.pivot, node.pivot_sq);
            da.partial_cmp(&db).unwrap()
        })
        .unwrap();

    // f2: farthest from f1 (one counted pass).
    let d1: Vec<f64> = points
        .iter()
        .map(|&p| space.dist(p as usize, f1 as usize))
        .collect();
    let f2 = points[d1
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0];

    // Assignment pass: one counted distance per point (to f2; d1 cached).
    let mut left = Vec::new();
    let mut right = Vec::new();
    for (i, &p) in points.iter().enumerate() {
        let d2 = space.dist(p as usize, f2 as usize);
        if d1[i] <= d2 {
            left.push(p);
        } else {
            right.push(p);
        }
    }
    // Degenerate split (heavy duplicates): fall back to an even cut so
    // recursion always terminates.
    if left.is_empty() || right.is_empty() {
        let mut all = points;
        let mid = all.len() / 2;
        right = all.split_off(mid);
        left = all;
    }

    // Two independent sides: build them concurrently while parallel
    // levels remain (and both sides are big enough to be worth a
    // thread), splicing the private arenas back left-then-right so the
    // layout matches the sequential recursion exactly.
    let fan_out =
        levels > 0 && exec.threads() > 1 && left.len() > rmin && right.len() > rmin;
    let (left_id, right_id) = if fan_out {
        // The top split runs on the persistent pool; recursive joins
        // issued from inside pool tasks fall back to scoped spawns
        // (see `Executor::join`).
        let ((lnodes, lroot), (rnodes, rroot)) = exec.join(
            || {
                let mut local = Vec::new();
                let root = split(space, left, rmin, &mut local, exec, levels - 1);
                (local, root)
            },
            || {
                let mut local = Vec::new();
                let root = split(space, right, rmin, &mut local, exec, levels - 1);
                (local, root)
            },
        );
        let left_id = splice_arena(nodes, lnodes, lroot);
        let right_id = splice_arena(nodes, rnodes, rroot);
        (left_id, right_id)
    } else {
        // (levels passes through unchanged: a small side here does not
        // preclude fanning out a bigger split further down.)
        let left_id = split(space, left, rmin, nodes, exec, levels);
        let right_id = split(space, right, rmin, nodes, exec, levels);
        (left_id, right_id)
    };
    let mut parent = node;
    parent.children = Some((left_id, right_id));
    parent.points = Vec::new();
    nodes.push(parent);
    (nodes.len() - 1) as NodeId
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;

    fn random_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 5.0).collect();
        Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
    }

    #[test]
    fn builds_valid_tree() {
        let space = random_space(500, 3, 1);
        let tree = build(&space, 10);
        tree.validate(&space).unwrap();
        assert_eq!(tree.n_points(), 500);
    }

    #[test]
    fn leaves_respect_rmin() {
        let space = random_space(300, 2, 2);
        let tree = build(&space, 25);
        for leaf in tree.leaf_ids() {
            assert!(tree.node(leaf).count as usize <= 25);
        }
    }

    #[test]
    fn single_point_tree() {
        let space = random_space(1, 4, 3);
        let tree = build(&space, 5);
        tree.validate(&space).unwrap();
        assert_eq!(tree.nodes.len(), 1);
    }

    #[test]
    fn duplicate_points_terminate() {
        let rows: Vec<Vec<f32>> = (0..64).map(|_| vec![3.0, -1.0]).collect();
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let tree = build(&space, 4);
        tree.validate(&space).unwrap();
    }

    #[test]
    fn build_counts_distances() {
        let space = random_space(200, 2, 4);
        let tree = build(&space, 10);
        assert!(tree.build_dists > 0);
        assert_eq!(tree.build_dists, space.dist_count());
    }

    #[test]
    fn parallel_build_is_byte_identical() {
        let space = random_space(800, 3, 9);
        let serial = build(&space, 12);
        for threads in [2usize, 8] {
            let par = build_par(&space, 12, Parallelism::Fixed(threads));
            assert_eq!(par.root, serial.root);
            assert_eq!(par.nodes.len(), serial.nodes.len());
            for (a, b) in serial.nodes.iter().zip(&par.nodes) {
                assert_eq!(a.pivot, b.pivot);
                assert_eq!(a.radius.to_bits(), b.radius.to_bits());
                assert_eq!(a.count, b.count);
                assert_eq!(a.children, b.children);
                assert_eq!(a.row_start, b.row_start);
            }
            assert_eq!(par.layout.perm, serial.layout.perm);
            assert_eq!(par.layout.inv, serial.layout.inv);
        }
    }

    #[test]
    fn subset_build() {
        let space = random_space(100, 2, 5);
        let subset: Vec<u32> = (0..100).filter(|p| p % 2 == 0).collect();
        let tree = build_subset(&space, subset.clone(), 8);
        assert_eq!(tree.n_points(), 50);
        let mut owned = tree.points_under(tree.root).to_vec();
        owned.sort();
        assert_eq!(owned, subset);
    }
}
