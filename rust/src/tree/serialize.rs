//! Metric-tree serialization: build once, reuse across processes.
//!
//! Binary format (little-endian), versioned:
//!
//! ```text
//! magic "AHTREE03" | u32 rmin | u64 build_dists | u32 root | u32 n_nodes
//! per node:
//!   u32 dim | f32×dim pivot | f64 pivot_sq | f64 radius | u32 count |
//!   f64×dim sum | f64 sumsq | f64×dim sum2 |
//!   u8 has_children | (u32,u32 children)? | u32 row_start
//! then the tree-order layout:
//!   u32 perm_len (= dataset rows) | u32 n_rows | u32×n_rows inv
//! ```
//!
//! Version 3 adds the per-dimension second moments (`sum2`, the diagonal
//! of the raw scatter — see [`Node::sum2`]) right after `sumsq` in each
//! node record. Version 2 files (identical layout minus the `sum2` run)
//! are still read — [`read_tree`] leaves `sum2` empty and
//! [`MetricTree::attach_arena`] recomputes it bit-exactly from the
//! arena. Version 2 stores leaf point lists as `(row_start, count)`
//! ranges into the tree-order arena plus one `inv` array (arena row →
//! original id), instead of v1's per-leaf id vectors — the on-disk
//! mirror of the in-memory [`super::Layout`]. `perm` is reconstructed
//! from `inv` on load. The cached sufficient statistics are stored
//! verbatim, so a deserialized tree answers queries identically
//! (bit-for-bit) without touching the dataset — **after** the caller
//! re-attaches the permuted arena with [`MetricTree::attach_arena`]
//! (the snapshot persists the permutation, not the data; leaf scans
//! need the rows).

use super::{Layout, MetricTree, Node};
use crate::ids::{self, usize_from_u32};
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"AHTREE03";
const MAGIC_V2: &[u8; 8] = b"AHTREE02";

/// Checked length → u32 for the on-disk header fields: a tree too big
/// for the format is a loud error, never a truncated snapshot.
fn len_u32(n: usize, what: &str) -> Result<u32> {
    ids::u32_from_usize(n, what).map_err(|e| anyhow!(e))
}

/// Serialize into any writer (current format, `AHTREE03`).
pub fn write_tree(tree: &MetricTree, w: &mut impl Write) -> Result<()> {
    write_tree_impl(tree, w, true)
}

/// Serialize in the legacy `AHTREE02` layout (no per-dimension second
/// moments). Kept for backward/forward-compat tests and for feeding
/// older readers; new snapshots should use [`write_tree`].
pub fn write_tree_v2(tree: &MetricTree, w: &mut impl Write) -> Result<()> {
    write_tree_impl(tree, w, false)
}

fn write_tree_impl(tree: &MetricTree, w: &mut impl Write, with_sum2: bool) -> Result<()> {
    w.write_all(if with_sum2 { MAGIC } else { MAGIC_V2 })?;
    w.write_all(&len_u32(tree.rmin, "rmin")?.to_le_bytes())?;
    w.write_all(&tree.build_dists.to_le_bytes())?;
    w.write_all(&tree.root.to_le_bytes())?;
    w.write_all(&len_u32(tree.nodes.len(), "node count")?.to_le_bytes())?;
    for node in &tree.nodes {
        w.write_all(&len_u32(node.pivot.len(), "pivot dim")?.to_le_bytes())?;
        for &v in &node.pivot {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&node.pivot_sq.to_le_bytes())?;
        w.write_all(&node.radius.to_le_bytes())?;
        w.write_all(&node.count.to_le_bytes())?;
        for &v in &node.sum {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&node.sumsq.to_le_bytes())?;
        if with_sum2 {
            if node.sum2.len() != node.pivot.len() {
                bail!(
                    "node has {} sum2 entries for {} dims — legacy tree never re-attached? \
                     (attach_arena recomputes the stats, or use write_tree_v2)",
                    node.sum2.len(),
                    node.pivot.len()
                );
            }
            for &v in &node.sum2 {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        match node.children {
            Some((a, b)) => {
                w.write_all(&[1u8])?;
                w.write_all(&a.to_le_bytes())?;
                w.write_all(&b.to_le_bytes())?;
            }
            None => w.write_all(&[0u8])?,
        }
        w.write_all(&node.row_start.to_le_bytes())?;
    }
    w.write_all(&len_u32(tree.layout.perm.len(), "perm len")?.to_le_bytes())?;
    w.write_all(&len_u32(tree.layout.inv.len(), "inv len")?.to_le_bytes())?;
    for &p in &tree.layout.inv {
        w.write_all(&p.to_le_bytes())?;
    }
    Ok(())
}

/// Deserialize from any reader, with structural sanity checks. The
/// returned tree has its layout but **no arena** — call
/// [`MetricTree::attach_arena`] with the dataset before running any
/// leaf-scanning query.
pub fn read_tree(r: &mut impl Read) -> Result<MetricTree> {
    // Deterministic snapshot-truncation drill ([`crate::faults`],
    // default off): cap the reader at the injected byte limit so every
    // mid-record EOF path below gets exercised as a loud `Err`, never a
    // silently short tree.
    if let Some(limit) = crate::faults::snapshot_truncation() {
        let mut limited = r.take(limit);
        return read_tree_inner(&mut limited);
    }
    read_tree_inner(r)
}

fn read_tree_inner(r: &mut impl Read) -> Result<MetricTree> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let has_sum2 = match &magic {
        m if m == MAGIC => true,
        m if m == MAGIC_V2 => false,
        _ => bail!("not an AHTREE03 (or legacy AHTREE02) file"),
    };
    let rmin = usize_from_u32(read_u32(r)?);
    let build_dists = read_u64(r)?;
    let root = read_u32(r)?;
    let n_nodes = usize_from_u32(read_u32(r)?);
    if n_nodes == 0 || n_nodes > 1 << 28 {
        bail!("implausible node count {n_nodes}");
    }
    let mut nodes = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        let dim = usize_from_u32(read_u32(r)?);
        if dim > 1 << 24 {
            bail!("implausible dim {dim}");
        }
        let mut pivot = vec![0f32; dim];
        for v in pivot.iter_mut() {
            *v = read_f32(r)?;
        }
        let pivot_sq = read_f64(r)?;
        let radius = read_f64(r)?;
        let count = read_u32(r)?;
        let mut sum = vec![0f64; dim];
        for v in sum.iter_mut() {
            *v = read_f64(r)?;
        }
        let sumsq = read_f64(r)?;
        let sum2 = if has_sum2 {
            // Corrupt stat trailers must be refused here, not surface as
            // silently wrong pruning bounds: every entry finite, and the
            // trace consistent with the scalar second moment.
            let mut sum2 = vec![0f64; dim];
            for (i, v) in sum2.iter_mut().enumerate() {
                *v = read_f64(r)?;
                if !v.is_finite() {
                    bail!("non-finite sum2[{i}] = {v} in node stat trailer");
                }
            }
            let trace: f64 = sum2.iter().sum();
            if (trace - sumsq).abs() > 1e-6 * (1.0 + sumsq.abs()) {
                bail!("corrupt stat trailer: sum2 trace {trace} disagrees with sumsq {sumsq}");
            }
            sum2
        } else {
            Vec::new()
        };
        let mut flag = [0u8];
        r.read_exact(&mut flag)?;
        let children = match flag[0] {
            0 => None,
            1 => Some((read_u32(r)?, read_u32(r)?)),
            x => bail!("bad child flag {x}"),
        };
        let row_start = read_u32(r)?;
        nodes.push(Node {
            pivot,
            pivot_sq,
            radius,
            count,
            sum,
            sumsq,
            sum2,
            children,
            points: Vec::new(),
            row_start,
        });
    }
    if usize_from_u32(root) >= nodes.len() {
        bail!("root {root} out of range");
    }
    // Child ids must be in range, the root must not be anyone's child,
    // and each child is referenced at most once. Together these make
    // every node reachable from the root part of a proper tree, so the
    // tile walk below always terminates (any cycle reachable from the
    // root would need a double reference or a root-as-child edge).
    let mut seen = vec![false; nodes.len()];
    for node in &nodes {
        if let Some((a, b)) = node.children {
            for c in [a, b] {
                let ci = usize_from_u32(c);
                if ci >= nodes.len() {
                    bail!("child {c} out of range");
                }
                if c == root {
                    bail!("root {root} referenced as a child");
                }
                if seen[ci] {
                    bail!("node {c} has two parents");
                }
                seen[ci] = true;
            }
        }
    }
    // Layout: inv entries in range and unique (perm reconstruction
    // catches duplicates), row ranges within the arena.
    let perm_len = usize_from_u32(read_u32(r)?);
    let n_rows = usize_from_u32(read_u32(r)?);
    if perm_len > 1 << 31 || n_rows > perm_len {
        bail!("implausible layout sizes perm_len={perm_len} n_rows={n_rows}");
    }
    if n_rows != usize_from_u32(nodes[usize_from_u32(root)].count) {
        bail!(
            "layout holds {n_rows} rows but the root owns {}",
            nodes[usize_from_u32(root)].count
        );
    }
    let mut inv = vec![0u32; n_rows];
    let mut perm = vec![u32::MAX; perm_len];
    for (row, p) in inv.iter_mut().enumerate() {
        let orig = read_u32(r)?;
        let oi = usize_from_u32(orig);
        if oi >= perm_len {
            bail!("inv[{row}] = {orig} out of range (perm_len {perm_len})");
        }
        if perm[oi] != u32::MAX {
            bail!("dataset row {orig} appears twice in the layout");
        }
        // `row < n_rows ≤ perm_len ≤ 2^31` (checked above), so this
        // never saturates.
        perm[oi] = len_u32(row, "arena row")?;
        *p = orig;
    }
    for (id, node) in nodes.iter().enumerate() {
        if usize_from_u32(node.row_start) + usize_from_u32(node.count) > n_rows {
            bail!(
                "node {id}: rows {}..{} run past the {n_rows}-row arena",
                node.row_start,
                u64::from(node.row_start) + u64::from(node.count)
            );
        }
    }
    // Row ranges must actually tile the arena (the same invariant
    // `MetricTree::validate` enforces): leaves consecutive in DFS
    // order covering 0..n_rows, children tiling their parent. Without
    // this, a snapshot with zeroed/corrupt row_start fields would
    // deserialize cleanly and then silently answer queries with the
    // wrong points.
    let mut next = 0usize;
    let mut stack = vec![root];
    while let Some(id) = stack.pop() {
        let node = &nodes[usize_from_u32(id)];
        match node.children {
            None => {
                if usize_from_u32(node.row_start) != next {
                    bail!(
                        "leaf {id}: rows start at {} but the previous leaf ended at {next}",
                        node.row_start
                    );
                }
                next += usize_from_u32(node.count);
            }
            Some((a, b)) => {
                let (ca, cb) = (&nodes[usize_from_u32(a)], &nodes[usize_from_u32(b)]);
                if ca.row_start != node.row_start
                    || u64::from(cb.row_start) != u64::from(ca.row_start) + u64::from(ca.count)
                    || u64::from(ca.count) + u64::from(cb.count) != u64::from(node.count)
                {
                    bail!("node {id}: children don't tile its row range");
                }
                stack.push(b);
                stack.push(a);
            }
        }
    }
    if next != n_rows {
        bail!("leaf ranges cover {next} of {n_rows} arena rows");
    }
    Ok(MetricTree {
        nodes,
        root,
        rmin,
        build_dists,
        layout: Layout { perm, inv },
        arena: None,
    })
}

/// Save to a file path.
pub fn save(tree: &MetricTree, path: impl AsRef<std::path::Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_tree(tree, &mut f)
}

/// Load from a file path. Remember to [`MetricTree::attach_arena`]
/// before querying.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<MetricTree> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .map_err(|e| anyhow!("open {}: {e}", path.as_ref().display()))?,
    );
    read_tree(&mut f)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}
fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::metrics::Space;
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn space(n: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| vec![rng.normal() as f32 * 5.0, rng.normal() as f32 * 5.0, rng.normal() as f32])
            .collect();
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let space = space(300, 1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 12, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        let mut back = read_tree(&mut buf.as_slice()).unwrap();
        assert_eq!(back.root, tree.root);
        assert_eq!(back.rmin, tree.rmin);
        assert_eq!(back.build_dists, tree.build_dists);
        assert_eq!(back.nodes.len(), tree.nodes.len());
        for (a, b) in tree.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.pivot, b.pivot);
            assert_eq!(a.pivot_sq, b.pivot_sq);
            assert_eq!(a.radius, b.radius);
            assert_eq!(a.count, b.count);
            assert_eq!(a.sum, b.sum);
            assert_eq!(a.sumsq, b.sumsq);
            assert_eq!(a.sum2, b.sum2);
            assert_eq!(a.children, b.children);
            assert_eq!(a.row_start, b.row_start);
        }
        assert_eq!(back.layout.perm, tree.layout.perm);
        assert_eq!(back.layout.inv, tree.layout.inv);
        assert!(back.arena.is_none(), "snapshot must not carry the data");
        // After attaching the arena, the tree validates against the
        // original space.
        back.attach_arena(&space);
        back.validate(&space).unwrap();
    }

    #[test]
    fn loaded_tree_answers_queries_identically() {
        use crate::algorithms::kmeans;
        let space = space(400, 2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        let mut back = read_tree(&mut buf.as_slice()).unwrap();
        back.attach_arena(&space);
        let opts = kmeans::KmeansOpts::default();
        let a = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, 5, 5, &opts);
        let b = kmeans::tree_lloyd(&space, &back, kmeans::Init::Random, 5, 5, &opts);
        assert_eq!(a.distortion, b.distortion);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.dists, b.dists);
    }

    #[test]
    fn file_roundtrip() {
        let space = space(100, 3);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let path = std::env::temp_dir().join(format!("ahtree-test-{}.bin", std::process::id()));
        save(&tree, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.nodes.len(), tree.nodes.len());
        assert_eq!(back.layout.inv, tree.layout.inv);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_tree(&mut &b"not a tree"[..]).is_err());
        let mut bad = MAGIC.to_vec();
        bad.extend_from_slice(&[0xFF; 24]); // implausible header
        assert!(read_tree(&mut bad.as_slice()).is_err());
        // v1 snapshots are refused by magic, not misparsed.
        let mut v1 = b"AHTREE01".to_vec();
        v1.extend_from_slice(&[0u8; 24]);
        assert!(read_tree(&mut v1.as_slice()).is_err());
    }

    #[test]
    fn rejects_cyclic_children() {
        // Hand-craft a file where the root's children are identical.
        let space = space(40, 4);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        if tree.node(tree.root).children.is_some() {
            let mut t = read_tree(&mut buf.as_slice()).unwrap();
            let root = t.root as usize;
            if let Some((a, _)) = t.nodes[root].children {
                t.nodes[root].children = Some((a, a));
                let mut buf2 = Vec::new();
                write_tree(&t, &mut buf2).unwrap();
                assert!(read_tree(&mut buf2.as_slice()).is_err());
            }
        }
    }

    #[test]
    fn rejects_corrupt_row_ranges() {
        // Zeroed row_start fields (truncation / writer bug) must be
        // refused at load time, not surface as wrong query answers.
        let space = space(80, 6);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        let mut t = read_tree(&mut buf.as_slice()).unwrap();
        if t.nodes.len() > 1 {
            for node in &mut t.nodes {
                node.row_start = 0;
            }
            let mut buf2 = Vec::new();
            write_tree(&t, &mut buf2).unwrap();
            assert!(read_tree(&mut buf2.as_slice()).is_err());
        }
    }

    #[test]
    fn legacy_v2_loads_and_recomputes_stats_bit_exactly() {
        let space = space(200, 9);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 12, ..Default::default() });
        let mut buf = Vec::new();
        write_tree_v2(&tree, &mut buf).unwrap();
        assert_eq!(&buf[..8], b"AHTREE02");
        let mut back = read_tree(&mut buf.as_slice()).unwrap();
        assert!(
            back.nodes.iter().all(|n| n.sum2.is_empty()),
            "v2 snapshots carry no per-dim second moments"
        );
        // attach_arena recomputes sum2 in the same accumulation order the
        // builder used, so the bits must match the original tree exactly.
        back.attach_arena(&space);
        for (a, b) in tree.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.sum2, b.sum2);
        }
        back.validate(&space).unwrap();
    }

    #[test]
    fn rejects_truncated_stat_trailer() {
        let space = space(60, 10);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        // Header is 28 bytes; the first node's sum2 run starts at
        // 28 + 4 + 3·4 + 8 + 8 + 4 + 3·8 + 8 = 96 for this 3-dim space.
        // Cut mid-trailer and at a few other places: every truncation is
        // an error, never a panic.
        for cut in [96 + 4, buf.len() / 2, buf.len() - 1] {
            assert!(read_tree(&mut &buf[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn rejects_corrupt_stat_trailer() {
        let space = space(90, 11);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });

        // Zeroed sum2 (trace no longer matches sumsq) must be refused.
        let mut t = {
            let mut buf = Vec::new();
            write_tree(&tree, &mut buf).unwrap();
            read_tree(&mut buf.as_slice()).unwrap()
        };
        let root = t.root as usize;
        for v in &mut t.nodes[root].sum2 {
            *v = 0.0;
        }
        let mut buf2 = Vec::new();
        write_tree(&t, &mut buf2).unwrap();
        assert!(read_tree(&mut buf2.as_slice()).is_err());

        // Non-finite entries must be refused too.
        let mut t = {
            let mut buf = Vec::new();
            write_tree(&tree, &mut buf).unwrap();
            read_tree(&mut buf.as_slice()).unwrap()
        };
        t.nodes[root].sum2[0] = f64::NAN;
        let mut buf3 = Vec::new();
        write_tree(&t, &mut buf3).unwrap();
        assert!(read_tree(&mut buf3.as_slice()).is_err());
    }

    #[test]
    fn rejects_duplicate_layout_rows() {
        let space = space(60, 5);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        let mut t = read_tree(&mut buf.as_slice()).unwrap();
        t.layout.inv[1] = t.layout.inv[0];
        let mut buf2 = Vec::new();
        write_tree(&t, &mut buf2).unwrap();
        assert!(read_tree(&mut buf2.as_slice()).is_err());
    }
}
