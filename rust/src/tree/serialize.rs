//! Metric-tree serialization: build once, reuse across processes.
//!
//! Binary format (little-endian), versioned:
//!
//! ```text
//! magic "AHTREE01" | u32 rmin | u64 build_dists | u32 root | u32 n_nodes
//! per node:
//!   u32 dim | f32×dim pivot | f64 pivot_sq | f64 radius | u32 count |
//!   f64×dim sum | f64 sumsq |
//!   u8 has_children | (u32,u32 children)? | u32 n_points | u32×n points
//! ```
//!
//! The format stores the cached sufficient statistics verbatim, so a
//! deserialized tree answers queries identically (bit-for-bit) without
//! touching the dataset.

use super::{MetricTree, Node};
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"AHTREE01";

/// Serialize into any writer.
pub fn write_tree(tree: &MetricTree, w: &mut impl Write) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(tree.rmin as u32).to_le_bytes())?;
    w.write_all(&tree.build_dists.to_le_bytes())?;
    w.write_all(&tree.root.to_le_bytes())?;
    w.write_all(&(tree.nodes.len() as u32).to_le_bytes())?;
    for node in &tree.nodes {
        w.write_all(&(node.pivot.len() as u32).to_le_bytes())?;
        for &v in &node.pivot {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&node.pivot_sq.to_le_bytes())?;
        w.write_all(&node.radius.to_le_bytes())?;
        w.write_all(&node.count.to_le_bytes())?;
        for &v in &node.sum {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&node.sumsq.to_le_bytes())?;
        match node.children {
            Some((a, b)) => {
                w.write_all(&[1u8])?;
                w.write_all(&a.to_le_bytes())?;
                w.write_all(&b.to_le_bytes())?;
            }
            None => w.write_all(&[0u8])?,
        }
        w.write_all(&(node.points.len() as u32).to_le_bytes())?;
        for &p in &node.points {
            w.write_all(&p.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Deserialize from any reader, with structural sanity checks.
pub fn read_tree(r: &mut impl Read) -> Result<MetricTree> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an AHTREE01 file");
    }
    let rmin = read_u32(r)? as usize;
    let build_dists = read_u64(r)?;
    let root = read_u32(r)?;
    let n_nodes = read_u32(r)? as usize;
    if n_nodes == 0 || n_nodes > 1 << 28 {
        bail!("implausible node count {n_nodes}");
    }
    let mut nodes = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        let dim = read_u32(r)? as usize;
        if dim > 1 << 24 {
            bail!("implausible dim {dim}");
        }
        let mut pivot = vec![0f32; dim];
        for v in pivot.iter_mut() {
            *v = read_f32(r)?;
        }
        let pivot_sq = read_f64(r)?;
        let radius = read_f64(r)?;
        let count = read_u32(r)?;
        let mut sum = vec![0f64; dim];
        for v in sum.iter_mut() {
            *v = read_f64(r)?;
        }
        let sumsq = read_f64(r)?;
        let mut flag = [0u8];
        r.read_exact(&mut flag)?;
        let children = match flag[0] {
            0 => None,
            1 => Some((read_u32(r)?, read_u32(r)?)),
            x => bail!("bad child flag {x}"),
        };
        let n_points = read_u32(r)? as usize;
        let mut points = vec![0u32; n_points];
        for p in points.iter_mut() {
            *p = read_u32(r)?;
        }
        nodes.push(Node {
            pivot,
            pivot_sq,
            radius,
            count,
            sum,
            sumsq,
            children,
            points,
        });
    }
    if root as usize >= nodes.len() {
        bail!("root {root} out of range");
    }
    // Child ids must be in range and each child referenced at most once.
    let mut seen = vec![false; nodes.len()];
    for node in &nodes {
        if let Some((a, b)) = node.children {
            for c in [a, b] {
                let ci = c as usize;
                if ci >= nodes.len() {
                    bail!("child {c} out of range");
                }
                if seen[ci] {
                    bail!("node {c} has two parents");
                }
                seen[ci] = true;
            }
        }
    }
    Ok(MetricTree { nodes, root, rmin, build_dists })
}

/// Save to a file path.
pub fn save(tree: &MetricTree, path: impl AsRef<std::path::Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_tree(tree, &mut f)
}

/// Load from a file path.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<MetricTree> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .map_err(|e| anyhow!("open {}: {e}", path.as_ref().display()))?,
    );
    read_tree(&mut f)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}
fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::metrics::Space;
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn space(n: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| vec![rng.normal() as f32 * 5.0, rng.normal() as f32 * 5.0, rng.normal() as f32])
            .collect();
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let space = space(300, 1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 12, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        let back = read_tree(&mut buf.as_slice()).unwrap();
        assert_eq!(back.root, tree.root);
        assert_eq!(back.rmin, tree.rmin);
        assert_eq!(back.build_dists, tree.build_dists);
        assert_eq!(back.nodes.len(), tree.nodes.len());
        for (a, b) in tree.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.pivot, b.pivot);
            assert_eq!(a.pivot_sq, b.pivot_sq);
            assert_eq!(a.radius, b.radius);
            assert_eq!(a.count, b.count);
            assert_eq!(a.sum, b.sum);
            assert_eq!(a.sumsq, b.sumsq);
            assert_eq!(a.children, b.children);
            assert_eq!(a.points, b.points);
        }
        // Deserialized tree validates against the original space.
        back.validate(&space).unwrap();
    }

    #[test]
    fn loaded_tree_answers_queries_identically() {
        use crate::algorithms::kmeans;
        let space = space(400, 2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        let back = read_tree(&mut buf.as_slice()).unwrap();
        let opts = kmeans::KmeansOpts::default();
        let a = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, 5, 5, &opts);
        let b = kmeans::tree_lloyd(&space, &back, kmeans::Init::Random, 5, 5, &opts);
        assert_eq!(a.distortion, b.distortion);
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn file_roundtrip() {
        let space = space(100, 3);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let path = std::env::temp_dir().join(format!("ahtree-test-{}.bin", std::process::id()));
        save(&tree, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.nodes.len(), tree.nodes.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_tree(&mut &b"not a tree"[..]).is_err());
        let mut bad = MAGIC.to_vec();
        bad.extend_from_slice(&[0xFF; 24]); // implausible header
        assert!(read_tree(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn rejects_cyclic_children() {
        // Hand-craft a 2-node file where node 1 is referenced twice.
        let space = space(40, 4);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        let mut buf = Vec::new();
        write_tree(&tree, &mut buf).unwrap();
        // Corrupt: make root's two children identical (if root has kids).
        if tree.node(tree.root).children.is_some() {
            // Find the root node's children bytes — easier: rebuild tree
            // structure manually via read + mutate + write.
            let mut t = read_tree(&mut buf.as_slice()).unwrap();
            let root = t.root as usize;
            if let Some((a, _)) = t.nodes[root].children {
                t.nodes[root].children = Some((a, a));
                let mut buf2 = Vec::new();
                write_tree(&t, &mut buf2).unwrap();
                assert!(read_tree(&mut buf2.as_slice()).is_err());
            }
        }
    }
}
