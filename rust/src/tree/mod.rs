//! Cached-sufficient-statistics metric trees (paper §2, §3.1).
//!
//! Every node stores, besides the ball `(pivot, radius)` required by the
//! metric-tree definition, the *cached sufficient statistics* the paper's
//! algorithms consume:
//!
//! * `count`  — number of owned points,
//! * `sum`    — Σ x (so the centroid is `sum / count`),
//! * `sumsq`  — Σ ||x||² (so within-node distortion against any center c
//!              is exactly `sumsq − 2·c·sum + count·||c||²`, in O(d)),
//! * `sum2`   — Σ xᵢ² per dimension (the diagonal of the raw scatter;
//!              its trace equals `sumsq`, and it turns whole-node ball
//!              queries into exact per-dimension variance reports and
//!              bounds Nadaraya-Watson numerators via Cauchy–Schwarz).
//!
//! Two builders are provided: the classic top-down splitter
//! ([`top_down::build`]) and the paper's middle-out construction via the
//! anchors hierarchy ([`middle_out::build`]); Table 3 compares them.

pub mod kdtree;
pub mod middle_out;
pub mod serialize;
pub mod top_down;

use crate::metrics::{dense_dot, Space};

/// Node id within a [`MetricTree`] arena.
pub type NodeId = u32;

/// One metric-tree node.
#[derive(Clone, Debug)]
pub struct Node {
    /// Ball center. For interior nodes this is the centroid of the owned
    /// points (which requires the sum/scale ability of footnote 1; for
    /// general metrics a datapoint pivot would be used instead).
    pub pivot: Vec<f32>,
    /// Cached ||pivot||² (Euclidean expansion form).
    pub pivot_sq: f64,
    /// Every owned point is within `radius` of `pivot` (eq. 2). Builders
    /// may store a safe upper bound rather than the exact maximum.
    pub radius: f64,
    /// Number of owned points.
    pub count: u32,
    /// Cached Σx over owned points.
    pub sum: Vec<f64>,
    /// Cached Σ||x||² over owned points.
    pub sumsq: f64,
    /// Cached per-dimension second moments Σxᵢ² over owned points — the
    /// diagonal of the raw scatter matrix; its trace equals
    /// [`Node::sumsq`]. Persisted since snapshot format `AHTREE03`;
    /// empty right after loading a legacy `AHTREE02` snapshot until
    /// [`MetricTree::attach_arena`] recomputes it bottom-up.
    pub sum2: Vec<f64>,
    /// Child node ids; `None` for leaves.
    pub children: Option<(NodeId, NodeId)>,
    /// Owned point ids — a **builder-phase** container only. The
    /// builders fill it for leaves while the tree is under
    /// construction; [`finalize_layout`] drains every leaf's list into
    /// [`Layout::inv`] and leaves this empty. Query code must use
    /// [`MetricTree::points_under`] / [`MetricTree::node_rows`] instead.
    pub points: Vec<u32>,
    /// First arena row owned by this node. Because leaves are laid out
    /// in DFS order, **every** node (interior included) owns the
    /// contiguous arena range `row_start .. row_start + count`.
    /// Assigned by [`finalize_layout`]; meaningless before it runs.
    pub row_start: u32,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.children.is_none()
    }

    /// Centroid of the owned points (from the cached statistics).
    pub fn centroid(&self) -> Vec<f32> {
        let inv = if self.count == 0 { 0.0 } else { 1.0 / self.count as f64 };
        self.sum.iter().map(|&s| (s * inv) as f32).collect()
    }

    /// Exact sum of squared distances from the owned points to an
    /// arbitrary center `c` — the cached-sufficient-statistics identity
    /// that lets K-means award whole nodes in O(d).
    pub fn distortion_to(&self, c: &[f32], c_sq: f64) -> f64 {
        let dot: f64 = self
            .sum
            .iter()
            .zip(c)
            .map(|(&s, &cv)| s * cv as f64)
            .sum();
        self.sumsq - 2.0 * dot + self.count as f64 * c_sq
    }
}

/// Statistics describing tree shape (for reports and ablation benches).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TreeShape {
    pub nodes: usize,
    pub leaves: usize,
    pub max_depth: usize,
    pub mean_leaf_size: f64,
    pub mean_leaf_radius: f64,
}

/// The tree-order permutation: after a build finalizes, the dataset is
/// permuted so that every leaf's points occupy one contiguous range of
/// rows (the *arena*), leaves laid out in DFS order. Leaf scans then
/// read one sequential slab instead of gathering scattered rows — the
/// cache-aware node-contiguous storage of Omohundro's ball trees and
/// Ciaccia et al.'s M-tree pages — and a future mmap backend can serve
/// a node's points as a single byte range.
///
/// Conventions: `perm[original_id] = arena_row` (`u32::MAX` for points
/// outside a subset tree) and `inv[arena_row] = original_id`. Because
/// `inv` is exactly the concatenation of the builder's leaf point lists
/// in DFS order, `&inv[node_rows]` *is* the pre-permutation id list of
/// any node — id translation back to dataset ids at the result boundary
/// is a zero-cost slice view, and every scan enumerates points in the
/// identical order the gather path did (results stay bit-identical,
/// distance counts exact).
#[derive(Clone, Debug, Default)]
pub struct Layout {
    /// Original id → arena row (`u32::MAX` if not in the tree).
    pub perm: Vec<u32>,
    /// Arena row → original id (length = points owned by the tree).
    pub inv: Vec<u32>,
}

/// An arena-allocated metric tree.
pub struct MetricTree {
    pub nodes: Vec<Node>,
    pub root: NodeId,
    /// Leaf threshold the tree was built with.
    pub rmin: usize,
    /// Distance computations spent building this tree.
    pub build_dists: u64,
    /// The tree-order permutation (see [`Layout`]).
    pub layout: Layout,
    /// The dataset permuted into tree order, sharing the original
    /// space's distance counter. Always present on freshly built trees;
    /// `None` right after [`serialize::read_tree`] until
    /// [`MetricTree::attach_arena`] rebuilds it from the dataset.
    pub arena: Option<Space>,
}

impl MetricTree {
    #[inline]
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn root_node(&self) -> &Node {
        self.node(self.root)
    }

    pub fn n_points(&self) -> usize {
        self.root_node().count as usize
    }

    /// Ids of all leaves (DFS order).
    pub fn leaf_ids(&self) -> Vec<NodeId> {
        let mut out = Vec::new();
        let mut stack = vec![self.root];
        while let Some(id) = stack.pop() {
            match self.node(id).children {
                None => out.push(id),
                Some((a, b)) => {
                    stack.push(b);
                    stack.push(a);
                }
            }
        }
        out
    }

    /// The contiguous arena rows owned by `id` (leaves *and* interior
    /// nodes — DFS leaf order makes every subtree a single range).
    #[inline]
    pub fn node_rows(&self, id: NodeId) -> std::ops::Range<usize> {
        let n = self.node(id);
        let start = n.row_start as usize;
        start..start + n.count as usize
    }

    /// Every original point id under `id`, as a borrowed view into the
    /// layout — allocation-free for leaves and interior nodes alike
    /// (the slice is exactly the pre-permutation point list, in the
    /// order the builder produced it).
    #[inline]
    pub fn points_under(&self, id: NodeId) -> &[u32] {
        &self.layout.inv[self.node_rows(id)]
    }

    /// The tree-order arena. Panics if the tree was deserialized and
    /// the arena has not been re-attached yet.
    #[inline]
    pub fn arena(&self) -> &Space {
        self.arena
            .as_ref()
            .expect("tree has no arena — call attach_arena(&space) after deserializing")
    }

    /// Rebuild the permuted arena from the original dataset (needed
    /// after [`serialize::read_tree`], which persists the permutation
    /// but not the data). The arena shares `space`'s distance counter.
    pub fn attach_arena(&mut self, space: &Space) {
        assert_eq!(
            self.layout.perm.len(),
            space.n(),
            "tree layout was built for a {}-row dataset, got {} rows",
            self.layout.perm.len(),
            space.n()
        );
        self.arena = Some(space.select_rows(&self.layout.inv));
        // Legacy `AHTREE02` snapshots don't persist per-dimension second
        // moments; rebuild them from the freshly attached arena.
        if space.dim() > 0 && self.nodes.iter().any(|n| n.sum2.is_empty()) {
            self.recompute_sum2();
        }
    }

    /// Recompute every node's per-dimension second moments
    /// ([`Node::sum2`]) from the attached arena. Leaves accumulate their
    /// arena rows in row order — the identical value sequence
    /// [`make_leaf`] visited (the arena is a bit-exact copy of the
    /// builder's point list, in order) — and interiors add their
    /// children elementwise in `(a, b)` order exactly as
    /// [`make_parent`] did, so the recomputed statistics are
    /// bit-identical to what the original build produced. Walks
    /// post-order (children before parents) so it is independent of the
    /// node arena's storage order. Counts no distances.
    fn recompute_sum2(&mut self) {
        let d = self.arena().dim();
        let mut order: Vec<NodeId> = Vec::with_capacity(self.nodes.len());
        let mut stack = vec![self.root];
        while let Some(id) = stack.pop() {
            order.push(id);
            if let Some((a, b)) = self.node(id).children {
                stack.push(a);
                stack.push(b);
            }
        }
        for &id in order.iter().rev() {
            let mut sum2 = vec![0f64; d];
            match self.node(id).children {
                None => {
                    let arena = self.arena();
                    for r in self.node_rows(id) {
                        arena.accumulate_sq(r, &mut sum2);
                    }
                }
                Some((a, b)) => {
                    for i in 0..d {
                        sum2[i] =
                            self.nodes[a as usize].sum2[i] + self.nodes[b as usize].sum2[i];
                    }
                }
            }
            self.nodes[id as usize].sum2 = sum2;
        }
    }

    pub fn shape(&self) -> TreeShape {
        let mut shape = TreeShape { nodes: self.nodes.len(), ..Default::default() };
        let mut stack = vec![(self.root, 1usize)];
        let mut leaf_radius_sum = 0.0;
        let mut leaf_count_sum = 0usize;
        while let Some((id, depth)) = stack.pop() {
            let n = self.node(id);
            shape.max_depth = shape.max_depth.max(depth);
            match n.children {
                None => {
                    shape.leaves += 1;
                    leaf_radius_sum += n.radius;
                    leaf_count_sum += n.count as usize;
                }
                Some((a, b)) => {
                    stack.push((a, depth + 1));
                    stack.push((b, depth + 1));
                }
            }
        }
        if shape.leaves > 0 {
            shape.mean_leaf_size = leaf_count_sum as f64 / shape.leaves as f64;
            shape.mean_leaf_radius = leaf_radius_sum / shape.leaves as f64;
        }
        shape
    }

    /// Check every structural invariant against the backing space —
    /// including the tree-order layout: leaf ranges disjoint, sorted
    /// and covering `0..n_points`, `perm`/`inv` mutual inverses, and
    /// (when attached) the arena bit-consistent with the original
    /// rows. Used by tests and by `--validate` in the CLI. Does NOT
    /// count distances.
    pub fn validate(&self, space: &Space) -> Result<(), String> {
        let n = space.n();
        let n_rows = self.layout.inv.len();

        // --- layout: perm/inv are mutual inverses over the tree's rows.
        if self.layout.perm.len() != n {
            return Err(format!(
                "layout.perm maps {} dataset ids but the space has {n} rows",
                self.layout.perm.len()
            ));
        }
        if n_rows != self.n_points() {
            return Err(format!(
                "layout.inv holds {n_rows} rows but the root owns {} points",
                self.n_points()
            ));
        }
        for (row, &orig) in self.layout.inv.iter().enumerate() {
            if orig as usize >= n {
                return Err(format!("layout.inv[{row}] = {orig} is out of range (n = {n})"));
            }
            if self.layout.perm[orig as usize] != row as u32 {
                return Err(format!(
                    "perm/inv disagree: inv[{row}] = {orig} but perm[{orig}] = {}",
                    self.layout.perm[orig as usize]
                ));
            }
        }
        let mapped = self.layout.perm.iter().filter(|&&r| r != u32::MAX).count();
        if mapped != n_rows {
            return Err(format!(
                "perm maps {mapped} dataset ids into the arena but inv holds {n_rows} rows \
                 — some id is mapped twice or to a dangling row"
            ));
        }

        // --- leaves: DFS ranges are consecutive — hence disjoint,
        // sorted, and covering 0..n_rows exactly — and builder point
        // lists were drained into the layout.
        let mut next = 0usize;
        for leaf in self.leaf_ids() {
            let node = self.node(leaf);
            if !node.points.is_empty() {
                return Err(format!(
                    "leaf {leaf}: builder point list not drained — finalize_layout never ran"
                ));
            }
            let start = node.row_start as usize;
            if start != next {
                return Err(format!(
                    "leaf {leaf}: rows start at {start} but the previous leaf ended at {next} \
                     — leaf ranges must tile 0..{n_rows} in DFS order"
                ));
            }
            next = start + node.count as usize;
            if next > n_rows {
                return Err(format!(
                    "leaf {leaf}: range {start}..{next} runs past the arena ({n_rows} rows)"
                ));
            }
        }
        if next != n_rows {
            return Err(format!(
                "leaf ranges cover {next} rows but the layout holds {n_rows}"
            ));
        }

        // --- arena (when attached): row-for-row copy of the original
        // dataset under the permutation — values and cached norms.
        if let Some(arena) = self.arena.as_ref() {
            if arena.n() != n_rows {
                return Err(format!(
                    "arena holds {} rows but the layout maps {n_rows}",
                    arena.n()
                ));
            }
            use crate::data::Data;
            for (row, &orig) in self.layout.inv.iter().enumerate() {
                let o = orig as usize;
                let same = arena.data.sqnorm(row).to_bits() == space.data.sqnorm(o).to_bits()
                    && match (&arena.data, &space.data) {
                        // pallas-lint: allow(uncounted-dist, arena-copy audit in validate; no distance computed)
                        (Data::Dense(a), Data::Dense(s)) => a.row(row) == s.row(o),
                        // pallas-lint: allow(uncounted-dist, arena-copy audit in validate; no distance computed)
                        (Data::Sparse(a), Data::Sparse(s)) => a.row(row) == s.row(o),
                        _ => false,
                    };
                if !same {
                    return Err(format!(
                        "arena row {row} is not a copy of dataset row {orig}"
                    ));
                }
            }
        }

        // Per-node: ball containment, stats consistency, child partition.
        let mut stack = vec![self.root];
        while let Some(id) = stack.pop() {
            let node = self.node(id);
            let pts = self.points_under(id);
            if pts.len() != node.count as usize {
                return Err(format!(
                    "node {id}: cached count {} but its arena range holds {} rows",
                    node.count,
                    pts.len()
                ));
            }
            // Ball containment (eq. 2) with a small float slack.
            let slack = 1e-4 * (1.0 + node.radius);
            for &p in pts {
                // pallas-lint: allow(uncounted-dist, validate is an audit pass; documented uncounted)
                let d = space.dist_to_vec_uncounted(p as usize, &node.pivot, node.pivot_sq);
                if d > node.radius + slack {
                    return Err(format!(
                        "node {id}: point {p} at {d} outside radius {}",
                        node.radius
                    ));
                }
            }
            // Cached statistics.
            let sum_err: f64 = {
                let mut acc = vec![0f64; space.dim()];
                for &p in pts {
                    space.accumulate(p as usize, &mut acc);
                }
                acc.iter()
                    .zip(&node.sum)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f64::max)
            };
            if sum_err > 1e-3 * (1.0 + node.sumsq.abs()) {
                return Err(format!("node {id}: cached sum off by {sum_err}"));
            }
            let true_sumsq = space.sumsq(pts);
            if (true_sumsq - node.sumsq).abs() > 1e-5 * (1.0 + true_sumsq) {
                return Err(format!(
                    "node {id}: sumsq {} != {true_sumsq}",
                    node.sumsq
                ));
            }
            if node.sum2.len() != space.dim() {
                return Err(format!(
                    "node {id}: sum2 holds {} dims but the space has {} \
                     — legacy snapshot loaded without attach_arena?",
                    node.sum2.len(),
                    space.dim()
                ));
            }
            let sum2_err: f64 = {
                let mut acc = vec![0f64; space.dim()];
                for &p in pts {
                    space.accumulate_sq(p as usize, &mut acc);
                }
                acc.iter()
                    .zip(&node.sum2)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f64::max)
            };
            if sum2_err > 1e-5 * (1.0 + node.sumsq.abs()) {
                return Err(format!("node {id}: cached sum2 off by {sum2_err}"));
            }
            let trace: f64 = node.sum2.iter().sum();
            if (trace - node.sumsq).abs() > 1e-6 * (1.0 + node.sumsq.abs()) {
                return Err(format!(
                    "node {id}: sum2 trace {trace} disagrees with sumsq {}",
                    node.sumsq
                ));
            }
            if let Some((a, b)) = node.children {
                let (ca, cb) = (self.node(a), self.node(b));
                if ca.count + cb.count != node.count {
                    return Err(format!(
                        "node {id}: children own {} + {} points but the parent claims {}",
                        ca.count, cb.count, node.count
                    ));
                }
                // Children tile the parent's arena range: first child's
                // rows start where the parent's do, second child's start
                // where the first's end.
                if ca.row_start != node.row_start
                    || cb.row_start != ca.row_start + ca.count
                {
                    return Err(format!(
                        "node {id}: children rows ({}..{}, {}..{}) don't tile the parent's \
                         {}..{}",
                        ca.row_start,
                        ca.row_start + ca.count,
                        cb.row_start,
                        cb.row_start + cb.count,
                        node.row_start,
                        node.row_start + node.count
                    ));
                }
                stack.push(a);
                stack.push(b);
            }
        }
        Ok(())
    }
}

/// Build a node's cached statistics + exact radius for an explicit point
/// set (costs `|points|` counted distances for the radius pass). Returns
/// the constructed leaf node; the caller decides whether it stays a leaf.
pub(crate) fn make_leaf(space: &Space, points: Vec<u32>) -> Node {
    let d = space.dim();
    let mut sum = vec![0f64; d];
    for &p in &points {
        space.accumulate(p as usize, &mut sum);
    }
    let count = points.len() as u32;
    let inv = if count == 0 { 0.0 } else { 1.0 / count as f64 };
    let pivot: Vec<f32> = sum.iter().map(|&s| (s * inv) as f32).collect();
    // pallas-lint: allow(uncounted-dist, pivot norm staging in make_leaf; the radius distances below are counted)
    let pivot_sq = dense_dot(&pivot, &pivot);
    let sumsq = space.sumsq(&points);
    let mut sum2 = vec![0f64; d];
    for &p in &points {
        space.accumulate_sq(p as usize, &mut sum2);
    }
    let mut radius = 0.0f64;
    for &p in &points {
        let dist = space.dist_to_vec(p as usize, &pivot, pivot_sq);
        if dist > radius {
            radius = dist;
        }
    }
    Node {
        pivot,
        pivot_sq,
        radius,
        count,
        sum,
        sumsq,
        sum2,
        children: None,
        points,
        row_start: 0,
    }
}

/// Merge two sibling nodes into a parent whose pivot is the mass-weighted
/// centroid and whose radius is the triangle-inequality upper bound
/// `max_i D(pivot, child_i.pivot) + child_i.radius` (2 counted distances).
pub(crate) fn make_parent(space: &Space, a: &Node, b: &Node) -> Node {
    let d = a.sum.len();
    let mut sum = vec![0f64; d];
    for i in 0..d {
        sum[i] = a.sum[i] + b.sum[i];
    }
    let count = a.count + b.count;
    let inv = if count == 0 { 0.0 } else { 1.0 / count as f64 };
    let pivot: Vec<f32> = sum.iter().map(|&s| (s * inv) as f32).collect();
    // pallas-lint: allow(uncounted-dist, pivot norm staging in make_parent; the 2 radius distances are counted)
    let pivot_sq = dense_dot(&pivot, &pivot);
    let ra = space.dist_vv(&pivot, &a.pivot) + a.radius;
    let rb = space.dist_vv(&pivot, &b.pivot) + b.radius;
    let mut sum2 = vec![0f64; d];
    for i in 0..d {
        sum2[i] = a.sum2[i] + b.sum2[i];
    }
    Node {
        pivot,
        pivot_sq,
        radius: ra.max(rb),
        count,
        sum,
        sumsq: a.sumsq + b.sumsq,
        sum2,
        children: None, // caller fills in ids
        points: Vec::new(),
        row_start: 0,
    }
}

/// Finalize a freshly built arena of nodes into the tree-order layout:
/// walk the tree DFS left-to-right, drain every leaf's builder point
/// list into `Layout::inv` (assigning the leaf its contiguous row
/// range), propagate `row_start` to interior nodes, invert the
/// permutation, and copy the dataset into tree order. Runs no counted
/// distance work, is independent of thread count (the node arena is
/// already schedule-independent), and preserves per-leaf point order —
/// which is what keeps every downstream scan bit-identical to the
/// pre-layout gather path.
pub(crate) fn finalize_layout(space: &Space, nodes: &mut [Node], root: NodeId) -> (Layout, Space) {
    let mut inv: Vec<u32> = Vec::with_capacity(nodes[root as usize].count as usize);
    let mut stack = vec![root];
    while let Some(id) = stack.pop() {
        let n = &mut nodes[id as usize];
        match n.children {
            None => {
                n.row_start = inv.len() as u32;
                inv.append(&mut n.points);
            }
            Some((a, b)) => {
                stack.push(b);
                stack.push(a);
            }
        }
    }
    // Interior row ranges: children precede parents in arena order (both
    // builders push bottom-up), so one forward pass suffices. The first
    // child's DFS leaves come first, so its start is the parent's.
    for i in 0..nodes.len() {
        if let Some((a, b)) = nodes[i].children {
            let (sa, sb) = (nodes[a as usize].row_start, nodes[b as usize].row_start);
            debug_assert!(
                (a as usize) < i && (b as usize) < i,
                "child pushed after its parent"
            );
            nodes[i].row_start = sa.min(sb);
        }
    }
    let mut perm = vec![u32::MAX; space.n()];
    for (row, &orig) in inv.iter().enumerate() {
        perm[orig as usize] = row as u32;
    }
    let arena = space.select_rows(&inv);
    (Layout { perm, inv }, arena)
}

/// Append a subtree arena built off to the side (by a parallel build
/// task) onto `nodes`, remapping its internal child ids by the insertion
/// offset. Returns the remapped root id. Splicing local arenas in task
/// order reproduces exactly the layout the sequential recursion builds,
/// so parallel and serial builds yield byte-identical trees.
pub(crate) fn splice_arena(nodes: &mut Vec<Node>, mut local: Vec<Node>, root: NodeId) -> NodeId {
    let offset = nodes.len() as NodeId;
    for n in &mut local {
        if let Some((a, b)) = n.children {
            n.children = Some((a + offset, b + offset));
        }
    }
    nodes.extend(local);
    root + offset
}

/// Like [`splice_arena`], but for a local arena built *against* a
/// snapshot of the shared arena: node ids `< base` already point into
/// `nodes` and pass through unchanged, ids `>= base` are offset-encoded
/// locals (`base + position`) and are rebased onto the insertion point.
/// Used by the partitioned agglomeration, whose per-bucket merge tasks
/// create parents over children living in the shared arena. Splicing the
/// buckets in bucket order keeps the layout a pure function of the
/// decomposition — never of the schedule.
pub(crate) fn splice_offset_arena(
    nodes: &mut Vec<Node>,
    mut local: Vec<Node>,
    root: NodeId,
    base: NodeId,
) -> NodeId {
    debug_assert!(nodes.len() >= base as usize, "splice below its own base");
    let shift = nodes.len() as NodeId - base;
    let rebase = |id: NodeId| if id < base { id } else { id + shift };
    for n in &mut local {
        if let Some((a, b)) = n.children {
            n.children = Some((rebase(a), rebase(b)));
        }
    }
    nodes.extend(local);
    rebase(root)
}

/// The "compatibility" score of §3.1: the radius of the smallest ball that
/// is guaranteed to contain both children's balls — smaller is better.
#[inline]
pub(crate) fn enclosing_radius(d: f64, ra: f64, rb: f64) -> f64 {
    // If one ball already contains the other, the big one's radius.
    let nested = (d + ra.min(rb)) <= ra.max(rb);
    if nested {
        ra.max(rb)
    } else {
        (d + ra + rb) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;

    pub(crate) fn random_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 5.0).collect();
        Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
    }

    #[test]
    fn make_leaf_stats_and_radius() {
        let space = random_space(50, 3, 1);
        let pts: Vec<u32> = (0..50).collect();
        let leaf = make_leaf(&space, pts.clone());
        assert_eq!(leaf.count, 50);
        // radius is the exact max distance to the centroid
        let c = leaf.centroid();
        let csq = dense_dot(&c, &c);
        let maxd = pts
            .iter()
            .map(|&p| space.dist_to_vec_uncounted(p as usize, &c, csq))
            .fold(0.0, f64::max);
        assert!((leaf.radius - maxd).abs() < 1e-6);
    }

    #[test]
    fn distortion_identity() {
        // sumsq - 2 c.sum + n||c||^2 == sum of squared distances.
        let space = random_space(30, 4, 2);
        let pts: Vec<u32> = (0..30).collect();
        let leaf = make_leaf(&space, pts.clone());
        let c = vec![0.5f32, -1.0, 2.0, 0.0];
        let c_sq = dense_dot(&c, &c);
        let fast = leaf.distortion_to(&c, c_sq);
        let slow: f64 = pts
            .iter()
            .map(|&p| space.dist_to_vec_uncounted(p as usize, &c, c_sq).powi(2))
            .sum();
        assert!((fast - slow).abs() < 1e-5 * (1.0 + slow), "{fast} vs {slow}");
    }

    #[test]
    fn sum2_trace_matches_sumsq_and_direct_accumulation() {
        let space = random_space(40, 3, 7);
        let a = make_leaf(&space, (0..25).collect());
        let b = make_leaf(&space, (25..40).collect());
        let mut p = make_parent(&space, &a, &b);
        p.children = Some((0, 1));
        let mut direct = vec![0f64; 3];
        for i in 0..40 {
            space.accumulate_sq(i, &mut direct);
        }
        for (x, y) in p.sum2.iter().zip(&direct) {
            assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "{x} vs {y}");
        }
        let trace: f64 = p.sum2.iter().sum();
        assert!(
            (trace - p.sumsq).abs() < 1e-9 * (1.0 + p.sumsq.abs()),
            "trace {trace} vs sumsq {}",
            p.sumsq
        );
    }

    #[test]
    fn make_parent_contains_children() {
        let space = random_space(40, 2, 3);
        let a = make_leaf(&space, (0..20).collect());
        let b = make_leaf(&space, (20..40).collect());
        let p = make_parent(&space, &a, &b);
        assert_eq!(p.count, 40);
        // Every point is inside the parent's (bounded) radius.
        for i in 0..40u32 {
            let d = space.dist_to_vec_uncounted(i as usize, &p.pivot, p.pivot_sq);
            assert!(d <= p.radius + 1e-6, "point {i} escapes parent ball");
        }
    }

    #[test]
    fn enclosing_radius_cases() {
        // Disjoint balls.
        assert!((enclosing_radius(10.0, 1.0, 2.0) - 6.5).abs() < 1e-12);
        // Nested: ball B inside ball A.
        assert_eq!(enclosing_radius(1.0, 5.0, 1.0), 5.0);
        // Identical centers.
        assert_eq!(enclosing_radius(0.0, 2.0, 3.0), 3.0);
    }
}
