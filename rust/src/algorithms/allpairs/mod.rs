//! All-pairs / close-pairs search (paper §4.3).
//!
//! Finds every pair of points with `D(x, y) ≤ τ` — a special case of the
//! dual-tree "all-pairs" family (Gray & Moore 2000; Barnes–Hut). The
//! paper's headline use is *attribute grouping*: standardize each column
//! to zero mean / unit L2 norm, transpose, and pairs of attributes with
//! correlation ≥ ρ are exactly pairs of rows with `D ≤ sqrt(2 − 2ρ)`
//! (eq. 8).

use crate::data::DenseMatrix;
use crate::metrics::{block, Space};
use crate::tree::{MetricTree, NodeId};

/// Result of a close-pairs run.
#[derive(Clone, Debug)]
pub struct PairsResult {
    /// (i, j) with i < j and D(i, j) ≤ τ.
    pub pairs: Vec<(u32, u32)>,
    pub dists: u64,
}

/// Naive O(R²/2) scan — the paper's "regular" baseline for the All-Pairs
/// column of Table 2.
pub fn naive_close_pairs(space: &Space, tau: f64) -> PairsResult {
    let before = space.dist_count();
    let mut pairs = Vec::new();
    let n = space.n();
    let mut dists: Vec<f64> = Vec::new();
    // One contiguous row-tail per point: the same R(R−1)/2 counted
    // distances as the classic double loop, tile-accounted.
    for i in 0..n {
        if i + 1 >= n {
            break;
        }
        space.checkpoint();
        space.obs().leaf_rows(crate::ids::u64_from_usize(n - i - 1));
        block::dists_contig_rows(space, i..i + 1, i + 1..n, &mut dists);
        for (off, &d) in dists.iter().enumerate() {
            if d <= tau {
                pairs.push((i as u32, (i + 1 + off) as u32));
            }
        }
    }
    PairsResult { pairs, dists: space.dist_count() - before }
}

/// Dual-tree close pairs: recurse over node pairs, pruning whenever the
/// balls are provably farther apart than τ.
pub fn tree_close_pairs(space: &Space, tree: &MetricTree, tau: f64) -> PairsResult {
    let before = space.dist_count();
    let mut pairs = Vec::new();
    // Leaf-scan scratch reused by every surviving leaf pair.
    let mut dists: Vec<f64> = Vec::new();
    dual(space, tree, tree.root, tree.root, tau, 0, &mut pairs, &mut dists);
    // Canonical order for comparability with the naive path.
    pairs.sort_unstable();
    pairs.dedup();
    PairsResult { pairs, dists: space.dist_count() - before }
}

#[allow(clippy::too_many_arguments)]
fn dual(
    space: &Space,
    tree: &MetricTree,
    a: NodeId,
    b: NodeId,
    tau: f64,
    depth: usize,
    out: &mut Vec<(u32, u32)>,
    dists: &mut Vec<f64>,
) {
    // Dual-tree telemetry: each call is one node-*pair* visit, and
    // `leaf_rows` counts pair evaluations in the leaf blocks.
    space.checkpoint();
    space.obs().visit(depth);
    let (na, nb) = (tree.node(a), tree.node(b));
    if a != b {
        // Lower bound on any cross distance; one counted pivot-pivot
        // distance buys the possibility of pruning |a|·|b| pairs.
        let d = space.dist_vv(&na.pivot, &nb.pivot);
        if d - na.radius - nb.radius > tau {
            space.obs().prune(crate::obs::PruneRule::Triangle);
            return;
        }
    }
    match (na.children, nb.children) {
        (None, None) => {
            // Leaf blocks run on the tree-order arena: each side is one
            // contiguous row slab, and the `layout.inv` slices give the
            // original ids for the emitted pairs — same distances, same
            // counts, same pair stream as the gather kernels.
            let arena = tree.arena();
            let ra = tree.node_rows(a);
            let ids_a = tree.points_under(a);
            if a == b {
                let len = ra.len();
                space
                    .obs()
                    .leaf_rows(crate::ids::u64_from_usize(len * len.saturating_sub(1) / 2));
                // Upper triangle, one contiguous row-tail per point:
                // the same |L|·(|L|−1)/2 counted distances as the
                // pointwise double loop.
                for (pi, &p) in ids_a.iter().enumerate() {
                    let tail_ids = &ids_a[pi + 1..];
                    if tail_ids.is_empty() {
                        break;
                    }
                    let r = ra.start + pi;
                    block::dists_contig_rows(arena, r..r + 1, r + 1..ra.end, dists);
                    for (&q, &d) in tail_ids.iter().zip(dists.iter()) {
                        if d <= tau {
                            out.push((p.min(q), p.max(q)));
                        }
                    }
                }
            } else {
                // Distinct leaves partition the points (no p == q), so
                // the full |A|·|B| block matches the scalar accounting.
                let rb = tree.node_rows(b);
                let ids_b = tree.points_under(b);
                space
                    .obs()
                    .leaf_rows(crate::ids::u64_from_usize(ra.len() * rb.len()));
                block::dists_contig_rows(arena, ra, rb, dists);
                for (pi, &p) in ids_a.iter().enumerate() {
                    let row = &dists[pi * ids_b.len()..(pi + 1) * ids_b.len()];
                    for (&q, &d) in ids_b.iter().zip(row) {
                        if d <= tau {
                            out.push((p.min(q), p.max(q)));
                        }
                    }
                }
            }
        }
        (Some((a1, a2)), None) => {
            dual(space, tree, a1, b, tau, depth + 1, out, dists);
            dual(space, tree, a2, b, tau, depth + 1, out, dists);
        }
        (None, Some((b1, b2))) => {
            dual(space, tree, a, b1, tau, depth + 1, out, dists);
            dual(space, tree, a, b2, tau, depth + 1, out, dists);
        }
        (Some((a1, a2)), Some((b1, b2))) => {
            if a == b {
                // Self pair: three sub-problems, not four.
                dual(space, tree, a1, a1, tau, depth + 1, out, dists);
                dual(space, tree, a2, a2, tau, depth + 1, out, dists);
                dual(space, tree, a1, a2, tau, depth + 1, out, dists);
            } else if na.radius >= nb.radius {
                dual(space, tree, a1, b, tau, depth + 1, out, dists);
                dual(space, tree, a2, b, tau, depth + 1, out, dists);
            } else {
                dual(space, tree, a, b1, tau, depth + 1, out, dists);
                dual(space, tree, a, b2, tau, depth + 1, out, dists);
            }
        }
    }
}

/// The correlation↔distance bridge of eq. (8): ρ ≥ `rho` ⇔ D ≤ τ.
pub fn rho_to_tau(rho: f64) -> f64 {
    (2.0 - 2.0 * rho).max(0.0).sqrt()
}

/// Inverse of [`rho_to_tau`].
pub fn tau_to_rho(tau: f64) -> f64 {
    1.0 - tau * tau / 2.0
}

/// Prepare an attribute-space view of a dataset for correlation search:
/// standardize every column, transpose, return the attributes-as-points
/// matrix (§4.3).
pub fn attribute_view(data: &DenseMatrix) -> DenseMatrix {
    let mut m = data.clone();
    m.standardize_columns();
    m.transpose()
}

/// Find all attribute pairs of `data` with correlation ≥ `rho`, returning
/// `(i, j, rho_ij)` triples. `use_tree` selects the dual-tree or naive
/// path (both exact).
pub fn correlated_attribute_pairs(
    data: &DenseMatrix,
    rho: f64,
    rmin: usize,
    use_tree: bool,
) -> (Vec<(u32, u32, f64)>, u64) {
    use crate::data::Data;
    let attrs = attribute_view(data);
    let space = Space::euclidean(Data::Dense(attrs));
    let tau = rho_to_tau(rho);
    let result = if use_tree {
        let cfg = crate::tree::middle_out::MiddleOutConfig { rmin, ..Default::default() };
        let tree = crate::tree::middle_out::build(&space, &cfg);
        tree_close_pairs(&space, &tree, tau)
    } else {
        naive_close_pairs(&space, tau)
    };
    let triples = result
        .pairs
        .iter()
        .map(|&(i, j)| {
            // Recomputing the pair distance for the rho output is a fresh
            // distance evaluation, so it joins the eq.-6 accounting (the
            // search result's count alone used to under-report by one per
            // reported pair).
            space.count_bulk(1);
            // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
            let d = space.dist_uncounted(i as usize, j as usize);
            (i, j, tau_to_rho(d))
        })
        .collect();
    (triples, result.dists + result.pairs.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn blobs(c: usize, per: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for ci in 0..c {
            let cx = (ci % 3) as f64 * 60.0;
            let cy = (ci / 3) as f64 * 60.0;
            for _ in 0..per {
                rows.push(vec![(cx + rng.normal()) as f32, (cy + rng.normal()) as f32]);
            }
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn tree_matches_naive() {
        let space = blobs(4, 50, 1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 12, ..Default::default() });
        for tau in [0.5, 1.5, 4.0] {
            let a = naive_close_pairs(&space, tau);
            let b = tree_close_pairs(&space, &tree, tau);
            assert_eq!(a.pairs, b.pairs, "tau={tau}");
        }
    }

    #[test]
    fn tree_saves_distances_when_pairs_are_local() {
        let space = blobs(6, 80, 2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 20, ..Default::default() });
        let a = naive_close_pairs(&space, 1.0);
        let b = tree_close_pairs(&space, &tree, 1.0);
        assert_eq!(a.pairs.len(), b.pairs.len());
        assert!(
            b.dists * 5 < a.dists,
            "tree {} vs naive {}",
            b.dists,
            a.dists
        );
    }

    #[test]
    fn zero_tau_finds_only_duplicates() {
        let rows = vec![
            vec![1.0f32, 1.0],
            vec![1.0, 1.0],
            vec![2.0, 2.0],
        ];
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 2, ..Default::default() });
        let r = tree_close_pairs(&space, &tree, 0.0);
        assert_eq!(r.pairs, vec![(0, 1)]);
    }

    #[test]
    fn huge_tau_finds_all_pairs() {
        let space = blobs(2, 10, 3);
        let n = space.n();
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 4, ..Default::default() });
        let r = tree_close_pairs(&space, &tree, 1e9);
        assert_eq!(r.pairs.len(), n * (n - 1) / 2);
    }

    #[test]
    fn rho_tau_roundtrip() {
        for rho in [-1.0, 0.0, 0.5, 0.9, 1.0] {
            assert!((tau_to_rho(rho_to_tau(rho)) - rho).abs() < 1e-12);
        }
        assert_eq!(rho_to_tau(1.0), 0.0);
        assert!((rho_to_tau(-1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn finds_planted_correlated_attributes() {
        // 6 attributes: 0&1 strongly positively correlated, 2&3 strongly
        // negatively, 4&5 independent.
        let mut rng = Rng::new(4);
        let n = 400;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let base = rng.normal();
            let anti = rng.normal();
            rows.push(vec![
                base as f32,
                (base + 0.1 * rng.normal()) as f32,
                anti as f32,
                (-anti + 0.1 * rng.normal()) as f32,
                rng.normal() as f32,
                rng.normal() as f32,
            ]);
        }
        let data = DenseMatrix::from_rows(&rows);
        let (pairs, _) = correlated_attribute_pairs(&data, 0.9, 4, true);
        let keys: Vec<(u32, u32)> = pairs.iter().map(|&(i, j, _)| (i, j)).collect();
        assert!(keys.contains(&(0, 1)), "missing (0,1): {keys:?}");
        assert!(!keys.contains(&(2, 3)), "negative pair matched at rho=0.9");
        assert_eq!(keys.len(), 1, "{keys:?}");
        assert!(pairs[0].2 > 0.9);
    }

    #[test]
    fn attribute_pairs_count_includes_rho_recomputation() {
        // The reported distance total must cover *every* evaluation,
        // including the per-pair recompute that turns a tau into the
        // output rho (previously uncounted: the total under-reported by
        // one distance per reported pair).
        let mut rng = Rng::new(11);
        let n = 200;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let base = rng.normal();
            rows.push(vec![
                base as f32,
                (base + 0.2 * rng.normal()) as f32,
                (base + 0.3 * rng.normal()) as f32,
                rng.normal() as f32,
            ]);
        }
        let data = DenseMatrix::from_rows(&rows);
        let rho = 0.8;
        let rmin = 3;
        let (pairs, reported) = correlated_attribute_pairs(&data, rho, rmin, true);
        // Replicate the search on an identical attribute space (the
        // build is deterministic) to get the search-only count.
        let attrs = attribute_view(&data);
        let space = Space::euclidean(Data::Dense(attrs));
        let cfg = MiddleOutConfig { rmin, ..Default::default() };
        let tree = middle_out::build(&space, &cfg);
        let search = tree_close_pairs(&space, &tree, rho_to_tau(rho));
        assert_eq!(search.pairs.len(), pairs.len());
        assert!(!pairs.is_empty(), "planted correlations not found");
        assert_eq!(reported, search.dists + pairs.len() as u64);
    }

    #[test]
    fn tree_and_naive_attribute_pairs_agree() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f32>> = (0..150)
            .map(|_| (0..12).map(|_| rng.normal() as f32).collect())
            .collect();
        let data = DenseMatrix::from_rows(&rows);
        let (a, _) = correlated_attribute_pairs(&data, 0.05, 3, false);
        let (b, _) = correlated_attribute_pairs(&data, 0.05, 3, true);
        let ka: Vec<_> = a.iter().map(|&(i, j, _)| (i, j)).collect();
        let kb: Vec<_> = b.iter().map(|&(i, j, _)| (i, j)).collect();
        assert_eq!(ka, kb);
    }
}
