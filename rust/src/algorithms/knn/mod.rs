//! k-nearest-neighbor search on metric trees — the classic use the paper
//! motivates in §2.1 ("a search will only need to visit half the
//! datapoints in a metric tree"). Also serves as the oracle primitive for
//! the MST extension and several property tests.

use crate::metrics::{block, Space};
use crate::tree::{MetricTree, NodeId};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A neighbor hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    pub dist: f64,
}

/// Naive k-NN: scan everything (R counted distances) through the blocked
/// leaf-scan kernel, streamed in fixed chunks (O(chunk) extra memory).
/// The skipped point splits the scan into two ranges, so its distance is
/// neither computed nor counted — exactly the pointwise behavior.
///
/// With the f32 filter tier on, chunks scanned after the heap is full
/// run the filtered kernel against the kth-best-so-far: pruned rows
/// provably satisfy `d > worst` at chunk start, and `worst` only
/// shrinks within a chunk, so the heap evolves through the identical
/// state sequence either way — results are bit-identical, only the
/// (f64, f32) evaluation split changes.
pub fn naive_knn(space: &Space, qrow: &[f32], q_sq: f64, k: usize, skip: Option<u32>) -> Vec<Neighbor> {
    let mut heap: BinaryHeap<HeapItem> = BinaryHeap::new(); // max-heap by dist
    let n = space.n();
    let segments = match skip {
        // Clamped so an out-of-range skip degrades to a full scan
        // (matching the old per-point filter) instead of panicking.
        Some(s) => {
            let s = (s as usize).min(n);
            [0..s, (s + 1).min(n)..n]
        }
        None => [0..n, n..n],
    };
    let filter = block::F32Filter::new(space, qrow);
    let mut dists: Vec<f64> = Vec::new();
    let mut frows: Vec<u32> = Vec::new();
    for seg in segments {
        let mut lo = seg.start;
        while lo < seg.end {
            let hi = (lo + block::SCAN_CHUNK).min(seg.end);
            space.checkpoint();
            // Threshold at chunk start: the kth best so far, only once
            // the heap is full (before that every row must be seen).
            let thr = if heap.len() == k { heap.peek().map(|w| w.dist) } else { None };
            space.obs().leaf_rows(crate::ids::u64_from_usize(hi - lo));
            match (&filter, thr) {
                (Some(f), Some(thr)) => {
                    block::dists_contig_to_vec_f32(
                        space, lo..hi, qrow, q_sq, f, thr, &mut frows, &mut dists,
                    );
                    space.obs().prune_n(
                        crate::obs::PruneRule::F32Reject,
                        crate::ids::u64_from_usize(hi - lo - frows.len()),
                    );
                    for (&row, &d) in frows.iter().zip(&dists) {
                        push_bounded(&mut heap, k, row, d);
                    }
                }
                _ => {
                    block::dists_contig_to_vec(space, lo..hi, qrow, q_sq, &mut dists);
                    for (off, &d) in dists.iter().enumerate() {
                        push_bounded(&mut heap, k, (lo + off) as u32, d);
                    }
                }
            }
            lo = hi;
        }
    }
    into_sorted(heap)
}

/// Tree k-NN: best-first with ball pruning.
pub fn tree_knn(
    space: &Space,
    tree: &MetricTree,
    qrow: &[f32],
    q_sq: f64,
    k: usize,
    skip: Option<u32>,
) -> Vec<Neighbor> {
    let mut result: BinaryHeap<HeapItem> = BinaryHeap::new();
    // Min-heap on the lower bound of each node's distance to q; the
    // trailing usize is the node's depth (root = 0), carried only for
    // fan-out telemetry — it rides behind (lb, id) so it never affects
    // the heap order.
    let mut frontier: BinaryHeap<Reverse<(OrdF64, NodeId, usize)>> = BinaryHeap::new();
    // Leaf scans run on the tree-order arena: a leaf is one contiguous
    // row range, its original ids the matching `layout.inv` slice. The
    // skipped point (a dataset id) is translated to its arena row once;
    // excluding it splits a leaf into two contiguous sub-scans, so its
    // distance is neither computed nor counted — exactly the old
    // filtered-gather behavior, point for point.
    let arena = tree.arena();
    let skip_row: Option<usize> = skip
        .and_then(|p| tree.layout.perm.get(p as usize).copied())
        .filter(|&r| r != u32::MAX)
        .map(|r| r as usize);
    // The filter is built on the arena (which inherits the tier flag and
    // the cached max|x| from the original space) and applied per leaf —
    // see `naive_knn` for why pruning keeps the heap bit-identical.
    let filter = block::F32Filter::new(arena, qrow);
    // Scratch reused across leaf scans.
    let mut dists: Vec<f64> = Vec::new();
    let mut frows: Vec<u32> = Vec::new();
    let obs = space.obs();
    frontier.push(Reverse((
        OrdF64(node_lower_bound(space, tree, tree.root, qrow, q_sq)),
        tree.root,
        0,
    )));
    obs.frontier(frontier.len());
    while let Some(Reverse((OrdF64(lb), node_id, depth))) = frontier.pop() {
        if result.len() == k {
            if let Some(worst) = result.peek() {
                if lb > worst.dist {
                    // Nothing left can improve the result set: the cut
                    // discards this node and the entire remaining
                    // frontier in one triangle-bound stroke.
                    obs.prune_n(
                        crate::obs::PruneRule::Triangle,
                        crate::ids::u64_from_usize(frontier.len() + 1),
                    );
                    break;
                }
            }
        }
        space.checkpoint();
        obs.visit(depth);
        let node = tree.node(node_id);
        match node.children {
            None => {
                let rows = tree.node_rows(node_id);
                let segs = match skip_row {
                    Some(s) if rows.contains(&s) => [rows.start..s, s + 1..rows.end],
                    _ => [rows.clone(), rows.end..rows.end],
                };
                for seg in segs {
                    if seg.is_empty() {
                        continue;
                    }
                    obs.leaf_rows(crate::ids::u64_from_usize(seg.len()));
                    let thr =
                        if result.len() == k { result.peek().map(|w| w.dist) } else { None };
                    match (&filter, thr) {
                        (Some(f), Some(thr)) => {
                            let seg_len = seg.len();
                            block::dists_contig_to_vec_f32(
                                arena, seg, qrow, q_sq, f, thr, &mut frows, &mut dists,
                            );
                            obs.prune_n(
                                crate::obs::PruneRule::F32Reject,
                                crate::ids::u64_from_usize(seg_len - frows.len()),
                            );
                            for (&row, &d) in frows.iter().zip(&dists) {
                                push_bounded(&mut result, k, tree.layout.inv[row as usize], d);
                            }
                        }
                        _ => {
                            let ids = &tree.layout.inv[seg.clone()];
                            block::dists_contig_to_vec(arena, seg, qrow, q_sq, &mut dists);
                            for (&p, &d) in ids.iter().zip(&dists) {
                                push_bounded(&mut result, k, p, d);
                            }
                        }
                    }
                }
            }
            Some((a, b)) => {
                for child in [a, b] {
                    let lb = node_lower_bound(space, tree, child, qrow, q_sq);
                    let prune = result.len() == k
                        && result.peek().map(|w| lb > w.dist).unwrap_or(false);
                    if !prune {
                        frontier.push(Reverse((OrdF64(lb), child, depth + 1)));
                    } else {
                        obs.prune(crate::obs::PruneRule::Triangle);
                    }
                }
                obs.frontier(frontier.len());
            }
        }
    }
    into_sorted(result)
}

/// Lower bound on the distance from q to any point in the node
/// (counted: one pivot distance).
fn node_lower_bound(space: &Space, tree: &MetricTree, id: NodeId, qrow: &[f32], q_sq: f64) -> f64 {
    use crate::metrics::{dense_dot, dense_l1, Metric};
    let node = tree.node(id);
    space.count_bulk(1);
    let d = match space.metric {
        Metric::Euclidean => {
            // pallas-lint: allow(uncounted-dist, counted via count_bulk above)
            let d2 = q_sq + node.pivot_sq - 2.0 * dense_dot(qrow, &node.pivot);
            d2.max(0.0).sqrt()
        }
        // pallas-lint: allow(uncounted-dist, counted via count_bulk above)
        Metric::L1 => dense_l1(qrow, &node.pivot),
    };
    (d - node.radius).max(0.0)
}

#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    id: u32,
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.dist
            .partial_cmp(&other.dist)
            .unwrap()
            .then(self.id.cmp(&other.id))
    }
}

#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

fn push_bounded(heap: &mut BinaryHeap<HeapItem>, k: usize, id: u32, dist: f64) {
    if heap.len() < k {
        heap.push(HeapItem { dist, id });
    } else if let Some(worst) = heap.peek() {
        if dist < worst.dist {
            heap.pop();
            heap.push(HeapItem { dist, id });
        }
    }
}

fn into_sorted(heap: BinaryHeap<HeapItem>) -> Vec<Neighbor> {
    let mut v: Vec<Neighbor> = heap
        .into_iter()
        .map(|h| Neighbor { id: h.id, dist: h.dist })
        .collect();
    v.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.id.cmp(&b.id)));
    v
}

/// Convenience: k-NN of a datapoint (excluding itself).
pub fn tree_knn_point(space: &Space, tree: &MetricTree, q: usize, k: usize) -> Vec<Neighbor> {
    let mut qrow = vec![0f32; space.dim()];
    space.fill_row(q, &mut qrow);
    tree_knn(space, tree, &qrow, space.data.sqnorm(q), k, Some(q as u32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn random_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 3.0).collect();
        Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
    }

    #[test]
    fn tree_matches_naive() {
        let space = random_space(400, 3, 1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let q: Vec<f32> = (0..3).map(|_| rng.normal() as f32 * 3.0).collect();
            let q_sq = q.iter().map(|&v| (v as f64).powi(2)).sum();
            let a = naive_knn(&space, &q, q_sq, 5, None);
            let b = tree_knn(&space, &tree, &q, q_sq, 5, None);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert!((x.dist - y.dist).abs() < 1e-9, "{x:?} vs {y:?}");
            }
        }
    }

    #[test]
    fn skip_excludes_self() {
        let space = random_space(100, 2, 3);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let hits = tree_knn_point(&space, &tree, 7, 3);
        assert!(hits.iter().all(|h| h.id != 7));
        assert!(hits[0].dist > 0.0 || hits[0].id != 7);
    }

    #[test]
    fn k_one_is_nearest() {
        let space = random_space(200, 2, 4);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let q = vec![0.1f32, -0.2];
        let q_sq = q.iter().map(|&v| (v as f64).powi(2)).sum();
        let hit = &tree_knn(&space, &tree, &q, q_sq, 1, None)[0];
        let best = (0..space.n())
            .map(|p| space.dist_to_vec_uncounted(p, &q, q_sq))
            .fold(f64::INFINITY, f64::min);
        assert!((hit.dist - best).abs() < 1e-12);
    }

    #[test]
    fn k_exceeds_n() {
        let space = random_space(5, 2, 5);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let q = vec![0.0f32, 0.0];
        let hits = tree_knn(&space, &tree, &q, 0.0, 50, None);
        assert_eq!(hits.len(), 5);
    }

    #[test]
    fn tree_visits_fewer_points_on_clustered_data() {
        // §2.1's claim. Build well-separated blobs; a query near one blob
        // should not pay distances to the others.
        let mut rng = Rng::new(6);
        let mut rows = Vec::new();
        for c in 0..10 {
            for _ in 0..100 {
                rows.push(vec![
                    (c as f64 * 200.0 + rng.normal()) as f32,
                    rng.normal() as f32,
                ]);
            }
        }
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 20, ..Default::default() });
        space.reset_count();
        let q = vec![0.0f32, 0.0];
        tree_knn(&space, &tree, &q, 0.0, 10, None);
        let used = space.dist_count();
        assert!(used < 300, "tree knn used {used} distances on 1000 points");
    }
}
